//! Deterministic pseudo-random numbers for the whole service.
//!
//! Everything in this crate that needs randomness (random search, slice
//! sampling, the training-platform simulator's failure injection, synthetic
//! objectives) draws from this xoshiro256++ generator so that every figure
//! harness and test is exactly reproducible from a seed. No external RNG
//! crates are used.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. one per training job).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The raw 256-bit generator state. Together with
    /// [`Rng::from_state`] this lets a [`crate::coordinator`] resume
    /// snapshot freeze and thaw a generator mid-stream: the restored
    /// generator continues with exactly the draw sequence the original
    /// would have produced.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Wire form of the generator state: four hex-coded u64 words
    /// ([`crate::json::u64_to_json`] — JSON numbers only carry 53
    /// integer bits). The single RNG codec every resume-snapshot block
    /// (strategy state, platform state) uses.
    pub fn state_to_json(&self) -> crate::json::Json {
        crate::json::Json::Arr(
            self.s.iter().map(|&w| crate::json::u64_to_json(w)).collect(),
        )
    }

    /// Parse a [`Rng::state_to_json`] value.
    pub fn from_state_json(j: &crate::json::Json) -> Option<Rng> {
        let words = j.as_arr()?;
        if words.len() != 4 {
            return None;
        }
        let mut s = [0u64; 4];
        for (slot, w) in s.iter_mut().zip(words) {
            *slot = crate::json::u64_from_json(w)?;
        }
        Some(Rng { s })
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0, 1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-53).
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller (cached second variate omitted for
    /// statelessness; cost is negligible at our scale).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Gamma(shape k > 0, scale 1) via Marsaglia–Tsang.
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            let u = self.uniform().max(1e-300);
            return self.gamma(k + 1.0) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Random unit vector in `dim` dimensions (for slice-sampling directions).
    pub fn unit_vector(&mut self, dim: usize) -> Vec<f64> {
        let mut v = vec![0.0; dim];
        self.unit_vector_into(&mut v);
        v
    }

    /// Fill `out` with a random unit vector without allocating (same draw
    /// sequence as [`Rng::unit_vector`]).
    pub fn unit_vector_into(&mut self, out: &mut [f64]) {
        loop {
            for x in out.iter_mut() {
                *x = self.normal();
            }
            let norm = out.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for x in out.iter_mut() {
                    *x /= norm;
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        for &k in &[0.5, 1.0, 3.0, 9.0] {
            let n = 20_000;
            let m = (0..n).map(|_| r.gamma(k)).sum::<f64>() / n as f64;
            assert!((m - k).abs() / k < 0.07, "k={k} mean={m}");
        }
    }

    #[test]
    fn int_range_covers_bounds() {
        let mut r = Rng::new(17);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.int_range(-2, 2);
            assert!((-2..=2).contains(&v));
            seen_lo |= v == -2;
            seen_hi |= v == 2;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn unit_vector_is_normalized() {
        let mut r = Rng::new(19);
        for dim in [1, 3, 26] {
            let v = r.unit_vector(dim);
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn unit_vector_into_matches_allocating_form() {
        let mut a = Rng::new(31);
        let mut b = Rng::new(31);
        for dim in [1usize, 4, 9] {
            let v = a.unit_vector(dim);
            let mut w = vec![0.0; dim];
            b.unit_vector_into(&mut w);
            assert_eq!(v, w);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Rng::new(99);
        for _ in 0..57 {
            a.next_u64(); // advance mid-stream
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the JSON wire form round-trips the full 64-bit words too
        let text = a.state_to_json().to_string();
        let mut c = Rng::from_state_json(&crate::json::parse(&text).unwrap()).unwrap();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), c.next_u64());
        }
        assert!(Rng::from_state_json(&crate::json::Json::Num(1.0)).is_none());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(3);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
