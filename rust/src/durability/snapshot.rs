//! Per-shard point-in-time snapshots with WAL high-water marks.
//!
//! A snapshot is the compaction half of the durability engine: each
//! store/metrics lock stripe is captured and written to its own file, and
//! a manifest records the WAL LSN up to which each component's effects
//! are contained (`store_hwm` / `metrics_hwm`). Recovery loads the shard
//! files and replays only the WAL records *after* the relevant mark.
//!
//! Point-in-time protocol (the skew fix, with a regression test in
//! `rust/tests/durability_integration.rs`): **all** of a component's
//! shard guards are captured simultaneously before anything is cloned,
//! and its high-water mark is read from the WAL while those guards are
//! held — no writer can be inside a shard critical section at that
//! instant, so every record with `lsn ≤ hwm` is fully contained in the
//! capture and every record after it is fully excluded. The store and
//! metrics captures happen one after the other with *independent* marks,
//! so the two components never need their guards held together (no
//! cross-component lock ordering).
//!
//! Shard files are serialized concurrently ([`crate::parallel::par_map`])
//! after the guards drop, written via temp-file + rename, and the
//! manifest is renamed into place last — a crash mid-snapshot leaves the
//! previous manifest (and a longer WAL replay), never a half snapshot.
//!
//! Each store shard file uses the same `table → key → {version, value}`
//! schema as the legacy single-blob [`crate::store::MetadataStore::snapshot`],
//! which remains accepted on recovery for old `snapshot.json` dumps.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::Path;

use super::wal::Wal;
use super::DurabilityError;
use crate::json::{self, Json};
use crate::metrics::MetricsService;
use crate::parallel;
use crate::store::{MetadataStore, Version};

/// Manifest file name inside a durability directory.
pub const MANIFEST_FILE: &str = "MANIFEST.json";
/// Legacy single-blob snapshot accepted by recovery when no manifest
/// exists (produced by `MetadataStore::snapshot()` in earlier versions).
pub const LEGACY_SNAPSHOT_FILE: &str = "snapshot.json";

/// Snapshot metadata: shard counts and per-component WAL high-water marks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// Store shard files (`store-NN.json`).
    pub store_shards: usize,
    /// Metrics shard files (`metrics-NN.json`).
    pub metric_shards: usize,
    /// Every store mutation with `lsn ≤ store_hwm` is in the snapshot.
    pub store_hwm: u64,
    /// Every metrics mutation with `lsn ≤ metrics_hwm` is in the snapshot.
    pub metrics_hwm: u64,
    /// First LSN the reopened WAL should hand out.
    pub next_lsn: u64,
}

impl Manifest {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::Num(1.0)),
            ("store_shards", Json::Num(self.store_shards as f64)),
            ("metric_shards", Json::Num(self.metric_shards as f64)),
            ("store_hwm", Json::Num(self.store_hwm as f64)),
            ("metrics_hwm", Json::Num(self.metrics_hwm as f64)),
            ("next_lsn", Json::Num(self.next_lsn as f64)),
        ])
    }

    fn from_json(j: &Json) -> Option<Manifest> {
        if j.get("format")?.as_i64()? != 1 {
            return None;
        }
        Some(Manifest {
            store_shards: j.get("store_shards")?.as_i64()? as usize,
            metric_shards: j.get("metric_shards")?.as_i64()? as usize,
            store_hwm: j.get("store_hwm")?.as_i64()? as u64,
            metrics_hwm: j.get("metrics_hwm")?.as_i64()? as u64,
            next_lsn: j.get("next_lsn")?.as_i64()? as u64,
        })
    }
}

fn store_shard_file(i: usize) -> String {
    format!("store-{i:02}.json")
}

fn metrics_shard_file(i: usize) -> String {
    format!("metrics-{i:02}.json")
}

/// Write `text` to `path` atomically (temp file + fsync + rename +
/// directory fsync). The directory sync makes the rename itself durable
/// before the caller proceeds — crucial for the manifest-last protocol:
/// every shard-file rename must hit disk before the manifest rename
/// does, or a power loss could persist a manifest that points at stale
/// shard entries.
fn write_atomic(path: &Path, text: &str) -> std::io::Result<()> {
    let tmp = path.with_extension("json.tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(text.as_bytes())?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(parent) = path.parent() {
        // fsync the directory entry (POSIX); advisory on platforms that
        // refuse to open directories
        if let Ok(d) = File::open(parent) {
            d.sync_all()?;
        }
    }
    Ok(())
}

/// Serialize one store shard's tables in the legacy blob schema.
fn store_shard_to_json(
    tables: &BTreeMap<String, BTreeMap<String, (Version, Json)>>,
) -> Json {
    let mut obj = BTreeMap::new();
    for (name, t) in tables {
        let mut items = BTreeMap::new();
        for (k, (ver, v)) in t {
            items.insert(
                k.clone(),
                Json::obj(vec![("version", Json::Num(*ver as f64)), ("value", v.clone())]),
            );
        }
        obj.insert(name.clone(), Json::Obj(items));
    }
    Json::Obj(obj)
}

/// Apply one store shard file (or a whole legacy blob — same schema) into
/// `store` via raw inserts (exact versions, no WAL emission). Routing by
/// the live store's own hash makes loading shard-count agnostic.
pub fn apply_store_blob(store: &MetadataStore, j: &Json) -> Result<(), DurabilityError> {
    let obj = j
        .as_obj()
        .ok_or_else(|| DurabilityError::Corrupt("store shard: top level must be object".into()))?;
    for (table, items) in obj {
        let items = items
            .as_obj()
            .ok_or_else(|| DurabilityError::Corrupt("store shard: table must be object".into()))?;
        for (key, entry) in items {
            let ver = entry
                .get("version")
                .and_then(Json::as_i64)
                .ok_or_else(|| DurabilityError::Corrupt("store shard: missing version".into()))?;
            let value = entry
                .get("value")
                .cloned()
                .ok_or_else(|| DurabilityError::Corrupt("store shard: missing value".into()))?;
            store.insert_raw(table, key, ver as Version, value);
        }
    }
    Ok(())
}

/// Serialize one metrics shard: `stream → [[time, value], ...]`.
fn metrics_shard_to_json(streams: &BTreeMap<String, Vec<crate::metrics::DataPoint>>) -> Json {
    let mut obj = BTreeMap::new();
    for (name, points) in streams {
        obj.insert(
            name.clone(),
            Json::Arr(
                points
                    .iter()
                    .map(|p| Json::Arr(vec![Json::Num(p.time), Json::Num(p.value)]))
                    .collect(),
            ),
        );
    }
    Json::Obj(obj)
}

fn apply_metrics_blob(metrics: &MetricsService, j: &Json) -> Result<(), DurabilityError> {
    let obj = j
        .as_obj()
        .ok_or_else(|| DurabilityError::Corrupt("metrics shard: top level must be object".into()))?;
    for (stream, points) in obj {
        let points = points
            .as_arr()
            .ok_or_else(|| DurabilityError::Corrupt("metrics shard: stream must be array".into()))?;
        let mut series = Vec::with_capacity(points.len());
        for p in points {
            let pair = p.as_arr().filter(|a| a.len() == 2).ok_or_else(|| {
                DurabilityError::Corrupt("metrics shard: point must be [t, v]".into())
            })?;
            let (Some(t), Some(v)) = (pair[0].as_f64(), pair[1].as_f64()) else {
                return Err(DurabilityError::Corrupt(
                    "metrics shard: non-numeric point".into(),
                ));
            };
            series.push(crate::metrics::DataPoint { time: t, value: v });
        }
        metrics.insert_raw_stream(stream, series);
    }
    Ok(())
}

/// Capture a point-in-time snapshot of `store` + `metrics` and write it
/// under `dir`: per-shard files first, manifest (rename) last.
pub fn write_snapshot(
    dir: &Path,
    store: &MetadataStore,
    metrics: &MetricsService,
    wal: &Wal,
) -> Result<Manifest, DurabilityError> {
    std::fs::create_dir_all(dir)?;
    let (store_shards, store_hwm) = store.capture_for_snapshot();
    let (metric_shards, metrics_hwm) = metrics.capture_for_snapshot();
    let manifest = Manifest {
        store_shards: store_shards.len(),
        metric_shards: metric_shards.len(),
        store_hwm,
        metrics_hwm,
        next_lsn: wal.last_lsn() + 1,
    };

    // guards are released; serialize the captured shards concurrently
    let store_texts = parallel::par_map(&store_shards, |tables| {
        store_shard_to_json(tables).to_pretty()
    });
    let metric_texts =
        parallel::par_map(&metric_shards, |streams| metrics_shard_to_json(streams).to_pretty());

    for (i, text) in store_texts.iter().enumerate() {
        write_atomic(&dir.join(store_shard_file(i)), text)?;
    }
    for (i, text) in metric_texts.iter().enumerate() {
        write_atomic(&dir.join(metrics_shard_file(i)), text)?;
    }
    write_atomic(&dir.join(MANIFEST_FILE), &manifest.to_json().to_pretty())?;
    Ok(manifest)
}

/// Load the snapshot under `dir` (if any) into fresh `store`/`metrics`.
/// Returns the manifest when a per-shard snapshot was loaded, `None` when
/// the directory has neither a manifest nor a legacy blob. A legacy
/// `snapshot.json` (single-blob `MetadataStore::snapshot()` output) is
/// accepted and loaded with zero high-water marks.
pub fn load_snapshot(
    dir: &Path,
    store: &MetadataStore,
    metrics: &MetricsService,
) -> Result<Option<Manifest>, DurabilityError> {
    let manifest_path = dir.join(MANIFEST_FILE);
    if manifest_path.exists() {
        let text = std::fs::read_to_string(&manifest_path)?;
        let parsed = json::parse(&text)
            .map_err(|e| DurabilityError::Corrupt(format!("manifest: {e}")))?;
        let manifest = Manifest::from_json(&parsed)
            .ok_or_else(|| DurabilityError::Corrupt("manifest: bad fields".into()))?;
        for i in 0..manifest.store_shards {
            let text = std::fs::read_to_string(dir.join(store_shard_file(i)))?;
            let parsed = json::parse(&text)
                .map_err(|e| DurabilityError::Corrupt(format!("store shard {i}: {e}")))?;
            apply_store_blob(store, &parsed)?;
        }
        for i in 0..manifest.metric_shards {
            let text = std::fs::read_to_string(dir.join(metrics_shard_file(i)))?;
            let parsed = json::parse(&text)
                .map_err(|e| DurabilityError::Corrupt(format!("metrics shard {i}: {e}")))?;
            apply_metrics_blob(metrics, &parsed)?;
        }
        return Ok(Some(manifest));
    }
    let legacy_path = dir.join(LEGACY_SNAPSHOT_FILE);
    if legacy_path.exists() {
        let text = std::fs::read_to_string(&legacy_path)?;
        let parsed = json::parse(&text)
            .map_err(|e| DurabilityError::Corrupt(format!("legacy snapshot: {e}")))?;
        apply_store_blob(store, &parsed)?;
        return Ok(Some(Manifest {
            store_shards: 0,
            metric_shards: 0,
            store_hwm: 0,
            metrics_hwm: 0,
            next_lsn: 1,
        }));
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "amt-snap-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn snapshot_roundtrip_preserves_store_and_metrics() {
        let dir = tmp("roundtrip");
        let store = MetadataStore::new();
        let metrics = MetricsService::new();
        let wal = Wal::create(&dir).unwrap();
        for i in 0..40 {
            store.put("jobs", &format!("j-{i:02}"), Json::Num(i as f64));
            metrics.emit(&format!("s-{i:02}/loss"), i as f64, -(i as f64));
        }
        store.put("jobs", "j-00", Json::Str("v2".into())); // version 2
        let manifest = write_snapshot(&dir, &store, &metrics, &wal).unwrap();
        assert_eq!(manifest.store_shards, store.shard_count());

        let restored = MetadataStore::new();
        let rmetrics = MetricsService::new();
        let loaded = load_snapshot(&dir, &restored, &rmetrics).unwrap().unwrap();
        assert_eq!(loaded, manifest);
        // byte-identical to the legacy merged snapshot of the original
        assert_eq!(restored.snapshot(), store.snapshot());
        assert_eq!(rmetrics.series("s-07/loss"), metrics.series("s-07/loss"));
        assert_eq!(rmetrics.list_streams(""), metrics.list_streams(""));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_single_blob_still_loads() {
        let dir = tmp("legacy");
        let store = MetadataStore::new();
        store.put("t", "k", Json::obj(vec![("a", Json::Num(2.0))]));
        store.put("t", "k", Json::obj(vec![("a", Json::Num(3.0))]));
        std::fs::write(dir.join(LEGACY_SNAPSHOT_FILE), store.snapshot()).unwrap();

        let restored = MetadataStore::new();
        let metrics = MetricsService::new();
        let manifest = load_snapshot(&dir, &restored, &metrics).unwrap().unwrap();
        assert_eq!(manifest.next_lsn, 1);
        assert_eq!(restored.get("t", "k"), store.get("t", "k"));
        assert_eq!(restored.get("t", "k").unwrap().0, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_dir_loads_nothing() {
        let dir = tmp("empty");
        let store = MetadataStore::new();
        let metrics = MetricsService::new();
        assert!(load_snapshot(&dir, &store, &metrics).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
