//! Write-ahead log: append-only, length-prefixed, checksummed record log
//! of every metadata-store and metrics mutation (plus job checkpoints).
//!
//! The WAL is the incremental half of the durability engine (DESIGN.md
//! §10). Mutations append records to an in-memory buffer from inside the
//! store/metrics shard critical sections — so WAL order equals
//! application order for any single key or stream — and the scheduler
//! **group-commits** the buffer (one `write` + `fsync` for every record
//! accumulated during a poll slice) at heap-drain boundaries. A crash
//! loses at most the records appended since the last commit, and what
//! survives on disk is always a prefix of the logical record stream.
//!
//! On-disk framing, per record:
//!
//! ```text
//! [len: u32 LE] [crc32(payload): u32 LE] [payload: len bytes]
//! ```
//!
//! where the payload is the compact JSON of the record (including its
//! LSN). Replay stops at the first frame that is truncated, oversized,
//! fails its checksum or fails to parse — a torn tail is *dropped*, never
//! an error (`scan` reports `dropped_tail` so recovery can truncate the
//! file back to the valid prefix before appending).

use std::cell::RefCell;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock, RwLockReadGuard};
use std::time::Duration;

use crate::json::{self, Json};
use crate::store::Version;

/// WAL file name inside a durability directory.
pub const WAL_FILE: &str = "wal.log";

/// Upper bound on one record's payload (corruption guard: a garbage
/// length prefix must not trigger a giant allocation).
const MAX_RECORD_BYTES: u32 = 1 << 26;

/// One logged mutation. `Put` carries the *resulting* version so replay
/// restores exact item versions without re-deriving them.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// Store write (unconditional or conditional) that succeeded.
    Put { table: String, key: String, version: Version, value: Json },
    /// Store delete that removed an existing item.
    Delete { table: String, key: String },
    /// Metric data point published to a stream.
    Emit { stream: String, time: f64, value: f64 },
    /// Bulk removal of every metric stream with a name prefix (used when
    /// recovery resets a job's partial state before deterministic replay).
    RemoveStreams { prefix: String },
    /// Job-actor checkpoint: the serialized [`crate::workflow::ExecutionState`]
    /// cursor at a `Parked`/`Pending` boundary. Informational for
    /// recovery (progress reporting); resume correctness comes from
    /// deterministic replay, not from the cursor.
    Checkpoint { job: String, exec: Json },
}

impl WalRecord {
    /// Wire JSON of the record with its LSN — also the payload format of
    /// the distributed plane's `StoreDelta` messages (the WAL record
    /// format *is* the cross-process wire format, DESIGN.md §11).
    pub fn to_json(&self, lsn: u64) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![("lsn", Json::Num(lsn as f64))];
        match self {
            WalRecord::Put { table, key, version, value } => {
                fields.push(("op", Json::Str("put".into())));
                fields.push(("table", Json::Str(table.clone())));
                fields.push(("key", Json::Str(key.clone())));
                fields.push(("ver", Json::Num(*version as f64)));
                fields.push(("value", value.clone()));
            }
            WalRecord::Delete { table, key } => {
                fields.push(("op", Json::Str("del".into())));
                fields.push(("table", Json::Str(table.clone())));
                fields.push(("key", Json::Str(key.clone())));
            }
            WalRecord::Emit { stream, time, value } => {
                fields.push(("op", Json::Str("emit".into())));
                fields.push(("stream", Json::Str(stream.clone())));
                fields.push(("t", Json::Num(*time)));
                fields.push(("v", Json::Num(*value)));
            }
            WalRecord::RemoveStreams { prefix } => {
                fields.push(("op", Json::Str("rmstreams".into())));
                fields.push(("prefix", Json::Str(prefix.clone())));
            }
            WalRecord::Checkpoint { job, exec } => {
                fields.push(("op", Json::Str("ckpt".into())));
                fields.push(("job", Json::Str(job.clone())));
                fields.push(("exec", exec.clone()));
            }
        }
        Json::obj(fields)
    }

    /// Parse the wire JSON back into `(lsn, record)`.
    pub fn from_json(j: &Json) -> Option<(u64, WalRecord)> {
        let lsn = j.get("lsn")?.as_i64()? as u64;
        let op = j.get("op")?.as_str()?;
        let rec = match op {
            "put" => WalRecord::Put {
                table: j.get("table")?.as_str()?.to_string(),
                key: j.get("key")?.as_str()?.to_string(),
                version: j.get("ver")?.as_i64()? as Version,
                value: j.get("value")?.clone(),
            },
            "del" => WalRecord::Delete {
                table: j.get("table")?.as_str()?.to_string(),
                key: j.get("key")?.as_str()?.to_string(),
            },
            "emit" => WalRecord::Emit {
                stream: j.get("stream")?.as_str()?.to_string(),
                time: j.get("t")?.as_f64()?,
                value: j.get("v")?.as_f64()?,
            },
            "rmstreams" => {
                WalRecord::RemoveStreams { prefix: j.get("prefix")?.as_str()?.to_string() }
            }
            "ckpt" => WalRecord::Checkpoint {
                job: j.get("job")?.as_str()?.to_string(),
                exec: j.get("exec")?.clone(),
            },
            _ => return None,
        };
        Some((lsn, rec))
    }

    /// Append this record's on-disk frame (`[len][crc][payload]`) to
    /// `out` — the single frame-encoding site for live appends
    /// ([`Wal::append`]/[`Wal::append_batch`]) and batch rewrites
    /// ([`Wal::compact`] and recovery's incremental-resume rewrite), so
    /// the framing discipline cannot drift between them. The JSON
    /// payload is serialized through a reusable thread-local `String`
    /// (no per-record `String`/`Vec` allocation on the hot path).
    pub fn encode_frame(&self, lsn: u64, out: &mut Vec<u8>) {
        thread_local! {
            static PAYLOAD: RefCell<String> = RefCell::new(String::new());
        }
        PAYLOAD.with(|cell| {
            let mut payload = cell.borrow_mut();
            payload.clear();
            self.to_json(lsn).write_compact(&mut payload);
            let bytes = payload.as_bytes();
            out.reserve(8 + bytes.len());
            out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            out.extend_from_slice(&crc32(bytes).to_le_bytes());
            out.extend_from_slice(bytes);
        });
    }
}

thread_local! {
    /// Reusable frame scratch for [`Wal::append`] / [`Wal::append_batch`]:
    /// frames are serialized here *outside* the buffer mutex, then copied
    /// into the group-commit buffer in a single locked extend.
    static FRAME_SCRATCH: RefCell<Vec<u8>> = RefCell::new(Vec::new());
}

/// IEEE CRC-32 (reflected, poly 0xEDB88320) over a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

struct WalInner {
    file: File,
    /// Appended-but-uncommitted frames (group-commit buffer).
    buf: Vec<u8>,
    /// Bytes known durably on disk (file length after the last
    /// successful commit).
    synced_len: u64,
    /// A previous commit failed partway: the file may end in a torn
    /// fragment past `synced_len`; the next commit rewinds before
    /// writing, so committed frames are never stranded behind a gap.
    dirty: bool,
}

/// Cross-caller group-commit coordination (see [`Wal::commit`]): one
/// *leader* runs the physical `write`+`fsync`; concurrent callers whose
/// records are already covered by the in-flight buffer become
/// *followers* and just wait for the leader's result.
struct GcState {
    /// A leader is currently inside [`Wal::commit_leader`].
    committing: bool,
    /// The in-flight leader has captured the buffer (acquired the inner
    /// mutex): records appended after this point are NOT covered by the
    /// in-flight write, so later callers must not piggyback on it.
    sealed: bool,
    /// Completed commit attempts (generation counter, success or not).
    gen: u64,
    /// Generation of the most recent *successful* commit. A follower
    /// waiting on generation `g` is durable once `last_ok_gen >= g`:
    /// failed commits retain the buffer, so any later successful commit
    /// covers every earlier caller's records too.
    last_ok_gen: u64,
}

/// The append-only log. `append` is infallible and lock-cheap: the LSN
/// comes from an atomic counter and the payload is serialized *outside*
/// the inner mutex, which only guards the buffer push — so the 16-way
/// sharded store does not re-serialize behind one serialization lock.
/// `commit` writes and fsyncs whatever accumulated; on failure the
/// buffer is retained and the file is rewound to the last durable
/// length on the next attempt (no records are lost while the process
/// lives, and the on-disk log never contains a frame gap). The inner
/// mutex is always the innermost lock in the system: store/metrics
/// shard guards may be held while appending, never the other way
/// around. The `unit` RwLock sits *outside* the inner mutex
/// ([`Wal::begin_unit`] guards are acquired before any append they
/// cover and must be dropped before the holder itself commits).
///
/// **Atomic units.** Some multi-record sequences must reach disk
/// all-or-nothing *relative to concurrent committers* — e.g. a job
/// reset's deletes followed by its reseed puts: a commit (from another
/// thread's poll slice) landing between them would persist the deletes
/// without the re-creates, and a crash right after leaves the job
/// deleted but not re-created. [`Wal::begin_unit`] returns a guard
/// (shared side of an RwLock) that [`Wal::commit`] excludes (write
/// side): appends made while holding the guard cannot be split across
/// two commits. Units exclude *commits*, not each other — concurrent
/// units interleave their appends freely, which is fine because
/// atomicity is only needed per job and one job's reset runs on one
/// thread.
///
/// Frames enter the file in buffer-push order, which for any single key
/// or stream equals mutation order (appends happen inside the shard
/// critical section); across independent keys LSNs may interleave
/// non-monotonically, which replay tolerates (records are filtered by
/// LSN individually, never assumed sorted).
pub struct Wal {
    path: PathBuf,
    fsync: AtomicBool,
    next_lsn: AtomicU64,
    /// Atomic-unit gate: readers are open units (multi-record append
    /// sequences), the writer is `commit`. See the struct docs.
    unit: RwLock<()>,
    inner: Mutex<WalInner>,
    /// Group-commit coordination. Lock order: `gc` is taken either on
    /// its own, or *after* `inner` (the seal point inside
    /// [`Wal::commit_leader`]) — never the other way around.
    gc: Mutex<GcState>,
    gc_cv: Condvar,
    /// This WAL's metric registry (per-instance). The handles below are
    /// cached into it under `wal.*` names.
    telemetry: crate::telemetry::Registry,
    /// Physical commits performed (non-empty `write`+`fsync` batches).
    /// Registry name: `wal.commits`.
    commits: Arc<crate::telemetry::Counter>,
    /// Callers whose commit piggybacked on another caller's in-flight
    /// write+fsync instead of issuing their own. Registry name:
    /// `wal.coalesced`.
    coalesced: Arc<crate::telemetry::Counter>,
    /// Latency of the physical commit leg (`write`+`fsync`, µs),
    /// recorded per leader commit. Registry name: `wal.commit_us`.
    commit_us: Arc<crate::telemetry::Histogram>,
    /// Bounded coalescing window in nanoseconds: how long a commit
    /// leader waits before capturing the buffer, giving concurrent
    /// drivers time to fan in. 0 (default) commits immediately.
    window_nanos: AtomicU64,
}

/// An open atomic append unit (see [`Wal::begin_unit`]): while this
/// guard lives, no commit can run, so every record appended under it
/// reaches disk in one group commit. Drop it *before* committing on the
/// same thread, or the commit deadlocks on its own unit.
pub struct AtomicUnit<'a> {
    _guard: RwLockReadGuard<'a, ()>,
}

/// Result of scanning a WAL file: the valid record prefix, the byte
/// offset where each frame ends, and whether a torn/corrupt tail was
/// dropped.
pub struct WalScan {
    /// `(lsn, record)` pairs in file order.
    pub records: Vec<(u64, WalRecord)>,
    /// Byte offset just past each valid frame (`frame_ends[i]` is the
    /// file length that contains exactly records `0..=i`).
    pub frame_ends: Vec<u64>,
    /// Total valid prefix length in bytes.
    pub valid_len: u64,
    /// True if bytes past `valid_len` were ignored (torn write or
    /// corruption).
    pub dropped_tail: bool,
}

impl Wal {
    /// Open (creating if absent) the WAL at `dir/wal.log`, truncate it to
    /// `valid_len` (discarding any torn tail) and position appends after
    /// it. `next_lsn` seeds the LSN counter (1 for a fresh log).
    pub fn open_at(dir: &Path, next_lsn: u64, valid_len: u64) -> std::io::Result<Wal> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(WAL_FILE);
        let mut file =
            OpenOptions::new().read(true).write(true).create(true).open(&path)?;
        file.set_len(valid_len)?;
        file.seek(SeekFrom::End(0))?;
        let reg = crate::telemetry::Registry::new();
        Ok(Wal {
            path,
            fsync: AtomicBool::new(true),
            next_lsn: AtomicU64::new(next_lsn.max(1)),
            unit: RwLock::new(()),
            inner: Mutex::new(WalInner {
                file,
                buf: Vec::new(),
                synced_len: valid_len,
                dirty: false,
            }),
            gc: Mutex::new(GcState {
                committing: false,
                sealed: false,
                gen: 0,
                last_ok_gen: 0,
            }),
            gc_cv: Condvar::new(),
            commits: reg.counter("wal.commits"),
            coalesced: reg.counter("wal.coalesced"),
            commit_us: reg.histogram("wal.commit_us"),
            telemetry: reg,
            window_nanos: AtomicU64::new(0),
        })
    }

    /// Fresh WAL in `dir` (LSNs from 1, any existing file truncated).
    pub fn create(dir: &Path) -> std::io::Result<Wal> {
        Self::open_at(dir, 1, 0)
    }

    /// Toggle fsync-on-commit (bench mode: off measures append/write cost
    /// without physical-disk latency). Durability tests keep the default.
    pub fn set_fsync(&self, fsync: bool) {
        self.fsync.store(fsync, Ordering::Relaxed);
    }

    /// Bounded coalescing window for group commit: a commit leader
    /// sleeps this long before capturing the buffer, so concurrent lane
    /// drivers finishing slices at nearly the same time share one
    /// `write`+`fsync` instead of queueing N of them. The default (zero)
    /// commits immediately — correct in all cases, just less coalesced.
    pub fn set_commit_window(&self, window: Duration) {
        self.window_nanos.store(window.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Physical commits performed (non-empty `write`+`fsync` batches).
    /// Shim over registry metric `wal.commits`; prefer
    /// [`Wal::telemetry_metrics`].
    pub fn commits(&self) -> u64 {
        self.commits.get()
    }

    /// Commit calls that piggybacked on another caller's in-flight
    /// write+fsync (group-commit fan-in; see [`Wal::commit`]). Shim
    /// over registry metric `wal.coalesced`.
    pub fn coalesced(&self) -> u64 {
        self.coalesced.get()
    }

    /// Point-in-time snapshot of this WAL's metric registry (names
    /// under `wal.*`, including the `wal.commit_us` physical-commit
    /// latency histogram) — one part of
    /// [`crate::api::AmtService::telemetry_snapshot`].
    pub fn telemetry_metrics(&self) -> Vec<crate::telemetry::MetricSnapshot> {
        self.telemetry.snapshot()
    }

    /// Path of the log file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record to the group-commit buffer; returns its LSN.
    /// Infallible: I/O happens at [`Wal::commit`]. Serialization and
    /// checksumming run outside the buffer mutex (into a reusable
    /// thread-local scratch); the mutex only guards one buffer extend.
    pub fn append(&self, rec: &WalRecord) -> u64 {
        let lsn = self.next_lsn.fetch_add(1, Ordering::Relaxed);
        FRAME_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.clear();
            rec.encode_frame(lsn, &mut scratch);
            self.inner.lock().unwrap().buf.extend_from_slice(&scratch);
        });
        lsn
    }

    /// Append a batch of records in order; returns the LSN of the last
    /// one (or [`Wal::last_lsn`] for an empty batch). Byte-identical to
    /// N sequential [`Wal::append`] calls — the batch reserves a
    /// contiguous LSN block up front, serializes every frame outside the
    /// buffer mutex into the thread-local scratch, and extends the
    /// commit buffer in ONE locked operation (one lock acquisition and
    /// one copy instead of N).
    pub fn append_batch(&self, recs: &[WalRecord]) -> u64 {
        if recs.is_empty() {
            return self.last_lsn();
        }
        let first = self.next_lsn.fetch_add(recs.len() as u64, Ordering::Relaxed);
        FRAME_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.clear();
            for (i, rec) in recs.iter().enumerate() {
                rec.encode_frame(first + i as u64, &mut scratch);
            }
            self.inner.lock().unwrap().buf.extend_from_slice(&scratch);
        });
        first + recs.len() as u64 - 1
    }

    /// Open an atomic append unit: until the returned guard drops,
    /// [`Wal::commit`] blocks, so a multi-record sequence (e.g. a job
    /// reset's deletes + its reseed puts) cannot be torn across two
    /// group commits by a concurrent committer — and therefore cannot
    /// be torn across a crash between them. The holder must drop the
    /// guard before committing on its own thread.
    pub fn begin_unit(&self) -> AtomicUnit<'_> {
        AtomicUnit { _guard: self.unit.read().unwrap() }
    }

    /// Last LSN handed out (0 if none yet).
    pub fn last_lsn(&self) -> u64 {
        self.next_lsn.load(std::sync::atomic::Ordering::Relaxed) - 1
    }

    /// Bytes durably on disk after the last successful commit — the size
    /// signal `DurabilityOptions::auto_checkpoint_bytes` triggers on.
    pub fn synced_len(&self) -> u64 {
        self.inner.lock().unwrap().synced_len
    }

    /// Drain the group-commit buffer *without* touching the file,
    /// returning the accumulated frames verbatim. This is how a remote
    /// worker's capture WAL turns a poll slice's mutations into a
    /// `StoreDelta`: the buffered frames are decoded
    /// ([`Wal::decode_frames`]) and shipped to the leader instead of
    /// being committed locally. Not for use on a WAL that also commits —
    /// taken frames will never reach this WAL's file.
    pub fn take_buffer(&self) -> Vec<u8> {
        std::mem::take(&mut self.inner.lock().unwrap().buf)
    }

    /// Group commit: write every buffered frame and fsync. No-op when the
    /// buffer is empty (cheap to call at every scheduler tick).
    ///
    /// **Cross-caller coalescing.** Any records a caller appended are in
    /// the buffer *before* it calls `commit`, so when another caller's
    /// commit is already in flight and has not yet captured the buffer
    /// (`sealed == false`), this caller's records are guaranteed to ride
    /// in that write — it just waits for the in-flight result instead of
    /// issuing a second `write`+`fsync` (counted in [`Wal::coalesced`]).
    /// If the in-flight commit has already sealed, the caller waits for
    /// it to finish and then retries, typically becoming the next
    /// leader. An optional [`Wal::set_commit_window`] makes the leader
    /// linger before sealing so near-simultaneous drivers fan in.
    ///
    /// Failure-safe: on error the buffer is **kept** (the records retry
    /// at the next commit) and the file is marked dirty, so the next
    /// attempt first rewinds to the last durable length — a partial
    /// `write` can never strand later frames behind a torn fragment.
    /// A follower observing its covering commit fail gets an error too;
    /// because failed commits retain the buffer, any *later* successful
    /// commit also makes the follower's records durable.
    pub fn commit(&self) -> std::io::Result<()> {
        loop {
            let mut gc = self.gc.lock().unwrap();
            if !gc.committing {
                // become the leader for the next physical commit
                gc.committing = true;
                gc.sealed = false;
                drop(gc);
                let window = self.window_nanos.load(Ordering::Relaxed);
                if window > 0 {
                    std::thread::sleep(Duration::from_nanos(window));
                }
                let commit_t0 = crate::telemetry::enabled()
                    .then(std::time::Instant::now);
                let result = self.commit_leader();
                if let (Some(t0), Ok(())) = (commit_t0, &result) {
                    self.commit_us.record_duration(t0.elapsed());
                }
                let mut gc = self.gc.lock().unwrap();
                gc.gen += 1;
                if result.is_ok() {
                    gc.last_ok_gen = gc.gen;
                }
                gc.committing = false;
                gc.sealed = false;
                self.gc_cv.notify_all();
                return result;
            }
            if !gc.sealed {
                // piggyback: our records were buffered before this point
                // and the in-flight leader has not captured the buffer
                // yet (`sealed` flips only under the inner mutex), so
                // its write is guaranteed to cover them.
                self.coalesced.inc();
                let target = gc.gen + 1;
                loop {
                    if gc.last_ok_gen >= target {
                        return Ok(());
                    }
                    if gc.gen >= target && !gc.committing {
                        // the covering commit (and no successor) ran and
                        // failed; the buffer was retained — surface the
                        // failure so the caller's retry path engages
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::Other,
                            "group commit failed",
                        ));
                    }
                    gc = self.gc_cv.wait(gc).unwrap();
                }
            }
            // sealed: the in-flight write no longer covers new records —
            // wait for it to finish, then retry (possibly as leader)
            let target = gc.gen + 1;
            while gc.gen < target && gc.committing {
                gc = self.gc_cv.wait(gc).unwrap();
            }
        }
    }

    /// The physical half of [`Wal::commit`], run by the group-commit
    /// leader only: capture the buffer (sealing the group), rewind a
    /// dirty tail, then one `write_all` + `sync_all` for everything
    /// accumulated.
    fn commit_leader(&self) -> std::io::Result<()> {
        // wait out open atomic units so their appends land whole
        let _excl = self.unit.write().unwrap();
        let mut inner = self.inner.lock().unwrap();
        // seal point: from here on, newly appended records are not part
        // of the buffer this commit writes (gc after inner — see the
        // lock-order note on the `gc` field)
        self.gc.lock().unwrap().sealed = true;
        let WalInner { file, buf, synced_len, dirty } = &mut *inner;
        if *dirty {
            file.set_len(*synced_len)?;
            file.seek(SeekFrom::Start(*synced_len))?;
            *dirty = false;
        }
        if buf.is_empty() {
            return Ok(());
        }
        let mut result = file.write_all(buf);
        if result.is_ok() && self.fsync.load(Ordering::Relaxed) {
            result = file.sync_all();
        }
        match result {
            Ok(()) => {
                self.commits.inc();
                *synced_len += buf.len() as u64;
                buf.clear();
                Ok(())
            }
            Err(e) => {
                *dirty = true;
                Err(e)
            }
        }
    }

    /// Scan a WAL file, returning the valid record prefix. A truncated,
    /// oversized, checksum-failing or unparseable frame ends the scan
    /// (the tail is dropped); this function never fails on torn writes —
    /// only on I/O errors reading the file.
    pub fn scan(path: &Path) -> std::io::Result<WalScan> {
        let mut bytes = Vec::new();
        match File::open(path) {
            Ok(mut f) => {
                f.read_to_end(&mut bytes)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(e),
        }
        Ok(Self::decode_frames(&bytes))
    }

    /// Decode a byte buffer of `[len][crc][payload]` frames into its
    /// valid record prefix — the in-memory core of [`Wal::scan`], also
    /// used to turn a capture buffer ([`Wal::take_buffer`]) into the
    /// records a `StoreDelta` carries.
    pub fn decode_frames(bytes: &[u8]) -> WalScan {
        let mut records = Vec::new();
        let mut frame_ends = Vec::new();
        let mut pos = 0usize;
        loop {
            if pos + 8 > bytes.len() {
                break; // no room for a header: end (or torn header)
            }
            let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
            let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
            if len > MAX_RECORD_BYTES {
                break; // corrupt length prefix
            }
            let start = pos + 8;
            let end = start + len as usize;
            if end > bytes.len() {
                break; // torn payload
            }
            let payload = &bytes[start..end];
            if crc32(payload) != crc {
                break; // checksum failure
            }
            let Ok(text) = std::str::from_utf8(payload) else { break };
            let Ok(parsed) = json::parse(text) else { break };
            let Some((lsn, rec)) = WalRecord::from_json(&parsed) else { break };
            records.push((lsn, rec));
            frame_ends.push(end as u64);
            pos = end;
        }
        let valid_len = *frame_ends.last().unwrap_or(&0);
        let dropped_tail = (valid_len as usize) < bytes.len();
        WalScan { records, frame_ends, valid_len, dropped_tail }
    }

    /// Compact the on-disk log after a successful snapshot: drop every
    /// record the snapshot's high-water marks already cover (store
    /// records with `lsn ≤ store_hwm`, metrics records with
    /// `lsn ≤ metrics_hwm`, checkpoints at or below both marks) and
    /// rewrite the survivors, preserving their LSNs and order. Returns
    /// `(bytes_before, bytes_after)`.
    ///
    /// Checkpoint-retention invariant (DESIGN.md §12): recovery's
    /// snapshot fast path only trusts a job's last checkpoint when its
    /// LSN clears **both** hwm marks, so dropping checkpoints at or
    /// below `min(store_hwm, metrics_hwm)` can never delete a
    /// fast-path-eligible one — it only removes progress hints whose
    /// jobs would scratch-replay anyway. Do not loosen the retention
    /// rule (e.g. keep only the newest checkpoint regardless of hwm)
    /// without also revisiting that gate: a retained checkpoint that
    /// predates snapshot-captured state would resume from the wrong
    /// store contents.
    ///
    /// Crash-safe: survivors are written to a temp file that is fsynced
    /// and renamed over the log (then the directory is fsynced), so a
    /// crash leaves either the old full log (harmless — replay skips
    /// covered records by LSN) or the compacted one. Uncommitted
    /// buffered frames are untouched and land after the compacted
    /// prefix at the next commit. Appends and commits are blocked for
    /// the duration (the inner mutex is held).
    pub fn compact(&self, store_hwm: u64, metrics_hwm: u64) -> std::io::Result<(u64, u64)> {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.synced_len;
        // mark dirty up front: if anything below fails the handle's
        // position is unspecified, and the next commit must rewind to
        // `synced_len` before writing (cleared again on success)
        inner.dirty = true;
        let mut bytes = vec![0u8; before as usize];
        inner.file.seek(SeekFrom::Start(0))?;
        inner.file.read_exact(&mut bytes)?;
        let scan = Self::decode_frames(&bytes);
        let ckpt_hwm = store_hwm.min(metrics_hwm);
        let mut kept = Vec::new();
        for (lsn, rec) in &scan.records {
            let keep = match rec {
                WalRecord::Put { .. } | WalRecord::Delete { .. } => *lsn > store_hwm,
                WalRecord::Emit { .. } | WalRecord::RemoveStreams { .. } => {
                    *lsn > metrics_hwm
                }
                WalRecord::Checkpoint { .. } => *lsn > ckpt_hwm,
            };
            if keep {
                rec.encode_frame(*lsn, &mut kept);
            }
        }
        let after = kept.len() as u64;
        let tmp = self.path.with_extension("log.tmp");
        let mut tmp_file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp)?;
        tmp_file.write_all(&kept)?;
        tmp_file.sync_all()?;
        std::fs::rename(&tmp, &self.path)?;
        // install the tmp handle as the log handle *before* anything else
        // can fail: after the rename it IS the inode `path` names, so no
        // reopen-by-path (which could error and strand a handle on the
        // replaced inode) is ever needed. Its position is already at the
        // end (we just wrote the whole content through it).
        inner.file = tmp_file;
        inner.synced_len = after;
        inner.dirty = false;
        // directory fsync last (makes the rename durable); an error here
        // surfaces to the caller but the in-memory state already matches
        // what `path` names
        if let Some(parent) = self.path.parent() {
            if let Ok(d) = File::open(parent) {
                d.sync_all()?;
            }
        }
        Ok((before, after))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "amt-wal-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Put {
                table: "jobs".into(),
                key: "a".into(),
                version: 3,
                value: Json::obj(vec![("x", Json::Num(1.5))]),
            },
            WalRecord::Emit { stream: "a/loss".into(), time: 2.25, value: -0.125 },
            WalRecord::Delete { table: "jobs".into(), key: "a".into() },
            WalRecord::RemoveStreams { prefix: "a/".into() },
            WalRecord::Checkpoint {
                job: "a".into(),
                exec: Json::obj(vec![("clock", Json::Num(7.5))]),
            },
        ]
    }

    #[test]
    fn append_commit_scan_roundtrip() {
        let dir = tmp("roundtrip");
        let wal = Wal::create(&dir).unwrap();
        let recs = sample_records();
        for r in &recs {
            wal.append(r);
        }
        wal.commit().unwrap();
        let scan = Wal::scan(&wal.path().to_path_buf()).unwrap();
        assert_eq!(scan.records.len(), recs.len());
        assert!(!scan.dropped_tail);
        for (i, (lsn, rec)) in scan.records.iter().enumerate() {
            assert_eq!(*lsn, i as u64 + 1);
            assert_eq!(rec, &recs[i]);
        }
        // uncommitted appends are not on disk
        wal.append(&recs[0]);
        let scan2 = Wal::scan(&dir.join(WAL_FILE)).unwrap();
        assert_eq!(scan2.records.len(), recs.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn floats_roundtrip_bit_exact() {
        let dir = tmp("bits");
        let wal = Wal::create(&dir).unwrap();
        let vals = [1.0 / 3.0, 1e-300, 123456.789012345, f64::MIN_POSITIVE];
        for (i, &v) in vals.iter().enumerate() {
            wal.append(&WalRecord::Emit { stream: format!("s{i}"), time: v, value: -v });
        }
        wal.commit().unwrap();
        let scan = Wal::scan(&dir.join(WAL_FILE)).unwrap();
        for (i, (_, rec)) in scan.records.iter().enumerate() {
            let WalRecord::Emit { time, value, .. } = rec else { panic!("wrong op") };
            assert_eq!(time.to_bits(), vals[i].to_bits());
            assert_eq!(value.to_bits(), (-vals[i]).to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_and_corruption_drop_cleanly() {
        let dir = tmp("torn");
        let wal = Wal::create(&dir).unwrap();
        for r in sample_records() {
            wal.append(&r);
        }
        wal.commit().unwrap();
        let path = dir.join(WAL_FILE);
        let full = std::fs::read(&path).unwrap();
        let clean = Wal::scan(&path).unwrap();

        // torn mid-record: cut 3 bytes into the third frame's payload
        let cut = clean.frame_ends[1] as usize + 11;
        std::fs::write(&path, &full[..cut]).unwrap();
        let scan = Wal::scan(&path).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert!(scan.dropped_tail);
        assert_eq!(scan.valid_len, clean.frame_ends[1]);

        // checksum corruption in the middle: records before survive,
        // everything from the bad frame on is dropped
        let mut corrupt = full.clone();
        let victim = clean.frame_ends[2] as usize + 12; // inside frame 4
        corrupt[victim] ^= 0xFF;
        std::fs::write(&path, &corrupt).unwrap();
        let scan = Wal::scan(&path).unwrap();
        assert_eq!(scan.records.len(), 3);
        assert!(scan.dropped_tail);

        // reopening at the valid prefix truncates the bad tail and
        // continues the LSN sequence
        let last = scan.records.last().unwrap().0;
        let wal = Wal::open_at(&dir, last + 1, scan.valid_len).unwrap();
        let lsn = wal.append(&WalRecord::Delete { table: "t".into(), key: "k".into() });
        assert_eq!(lsn, 4);
        wal.commit().unwrap();
        let scan = Wal::scan(&path).unwrap();
        assert_eq!(scan.records.len(), 4);
        assert!(!scan.dropped_tail);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_missing_file_is_empty() {
        let dir = tmp("missing");
        let scan = Wal::scan(&dir.join(WAL_FILE)).unwrap();
        assert!(scan.records.is_empty());
        assert_eq!(scan.valid_len, 0);
        assert!(!scan.dropped_tail);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn take_buffer_drains_without_touching_disk() {
        let dir = tmp("takebuf");
        let wal = Wal::create(&dir).unwrap();
        for r in sample_records() {
            wal.append(&r);
        }
        let frames = wal.take_buffer();
        assert!(!frames.is_empty());
        let decoded = Wal::decode_frames(&frames);
        assert_eq!(decoded.records.len(), sample_records().len());
        assert!(!decoded.dropped_tail);
        for (i, (lsn, rec)) in decoded.records.iter().enumerate() {
            assert_eq!(*lsn, i as u64 + 1);
            assert_eq!(rec, &sample_records()[i]);
        }
        // the buffer is gone: a commit writes nothing
        wal.commit().unwrap();
        assert_eq!(wal.synced_len(), 0);
        assert!(Wal::scan(&dir.join(WAL_FILE)).unwrap().records.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_drops_covered_records_and_keeps_the_tail() {
        let dir = tmp("compact");
        let wal = Wal::create(&dir).unwrap();
        for r in sample_records() {
            wal.append(&r); // lsns 1..=5
        }
        wal.commit().unwrap();
        let full = wal.synced_len();
        // marks as if a snapshot captured store records through lsn 3 and
        // metrics through lsn 2 (checkpoint lsn 5 > min(3,2) survives)
        let (before, after) = wal.compact(3, 2).unwrap();
        assert_eq!(before, full);
        assert!(after < before);
        let scan = Wal::scan(&wal.path().to_path_buf()).unwrap();
        assert!(!scan.dropped_tail);
        let lsns: Vec<u64> = scan.records.iter().map(|(l, _)| *l).collect();
        // survivors: RemoveStreams (lsn 4 > metrics_hwm 2) and the
        // checkpoint (lsn 5); Put(1)/Delete(3) ≤ store_hwm, Emit(2) ≤
        // metrics_hwm are dropped
        assert_eq!(lsns, vec![4, 5]);
        assert!(matches!(scan.records[0].1, WalRecord::RemoveStreams { .. }));
        assert!(matches!(scan.records[1].1, WalRecord::Checkpoint { .. }));
        // appends continue cleanly after compaction
        let lsn = wal.append(&WalRecord::Delete { table: "t".into(), key: "k".into() });
        assert_eq!(lsn, 6);
        wal.commit().unwrap();
        let scan = Wal::scan(&wal.path().to_path_buf()).unwrap();
        assert_eq!(scan.records.len(), 3);
        assert_eq!(scan.records[2].0, 6);
        assert_eq!(wal.synced_len(), scan.valid_len);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_with_zero_marks_is_identity() {
        let dir = tmp("compact-id");
        let wal = Wal::create(&dir).unwrap();
        for r in sample_records() {
            wal.append(&r);
        }
        wal.commit().unwrap();
        let original = std::fs::read(wal.path()).unwrap();
        let (before, after) = wal.compact(0, 0).unwrap();
        assert_eq!(before, after);
        assert_eq!(std::fs::read(wal.path()).unwrap(), original);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Regression for the torn scratch-reset bug: a reset's Delete and
    /// its reseed Put are separate appends, and a concurrent commit
    /// landing between them used to persist the delete without the
    /// re-create (a crash right after leaves the job deleted, gone from
    /// recovery's inventory). Under an atomic unit the committer blocks
    /// until both records are buffered, so any commit that persists the
    /// Delete persists the Put with it.
    #[test]
    fn atomic_unit_excludes_commit_between_appends() {
        let dir = tmp("unit");
        let wal = Arc::new(Wal::create(&dir).unwrap());
        let unit = wal.begin_unit();
        wal.append(&WalRecord::Delete { table: "tuning_jobs".into(), key: "j".into() });
        // a committer arriving mid-unit must not split the sequence
        let committer = {
            let wal = Arc::clone(&wal);
            std::thread::spawn(move || wal.commit().unwrap())
        };
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(
            wal.synced_len(),
            0,
            "commit must not land while the reset unit is open"
        );
        wal.append(&WalRecord::Put {
            table: "tuning_jobs".into(),
            key: "j".into(),
            version: 1,
            value: Json::obj(vec![("status", Json::Str("InProgress".into()))]),
        });
        drop(unit);
        committer.join().unwrap();
        // whichever commit won, the disk now has both records or —
        // had the process crashed before any commit — neither
        wal.commit().unwrap();
        let scan = Wal::scan(&wal.path().to_path_buf()).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert!(matches!(scan.records[0].1, WalRecord::Delete { .. }));
        assert!(matches!(scan.records[1].1, WalRecord::Put { .. }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `append_batch` must be byte-identical to N sequential `append`s:
    /// same LSNs, same frame bytes, same file contents after commit.
    #[test]
    fn append_batch_is_byte_identical_to_sequential_appends() {
        let dir_a = tmp("batch-a");
        let dir_b = tmp("batch-b");
        let wal_a = Wal::create(&dir_a).unwrap();
        let wal_b = Wal::create(&dir_b).unwrap();
        let recs = sample_records();
        let mut last = 0;
        for r in &recs {
            last = wal_a.append(r);
        }
        let batch_last = wal_b.append_batch(&recs);
        assert_eq!(batch_last, last, "batch must hand out the same LSN block");
        wal_a.commit().unwrap();
        wal_b.commit().unwrap();
        let bytes_a = std::fs::read(wal_a.path()).unwrap();
        let bytes_b = std::fs::read(wal_b.path()).unwrap();
        assert_eq!(bytes_a, bytes_b, "on-disk log must be bit-identical");
        // empty batch: no LSNs consumed, nothing buffered
        assert_eq!(wal_b.append_batch(&[]), batch_last);
        assert_eq!(wal_b.last_lsn(), batch_last);
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    /// Group-commit fan-in, deterministically: an open atomic unit
    /// blocks the first committer (the leader) *before* it seals the
    /// buffer, so every further concurrent committer piggybacks on its
    /// write. One physical commit, N-1 coalesced callers.
    #[test]
    fn concurrent_commits_coalesce_into_one_write() {
        let dir = tmp("coalesce");
        let wal = Arc::new(Wal::create(&dir).unwrap());
        let unit = wal.begin_unit();
        wal.append(&WalRecord::Delete { table: "t".into(), key: "k0".into() });
        const N: usize = 4;
        let committers: Vec<_> = (0..N)
            .map(|i| {
                let wal = Arc::clone(&wal);
                std::thread::spawn(move || {
                    wal.append(&WalRecord::Delete {
                        table: "t".into(),
                        key: format!("k{}", i + 1),
                    });
                    wal.commit().unwrap();
                })
            })
            .collect();
        // let every committer reach the group-commit gate: the leader is
        // parked in `unit.write()` (pre-seal), the rest are followers
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while wal.coalesced() < (N - 1) as u64 {
            assert!(std::time::Instant::now() < deadline, "followers never queued");
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(unit);
        for c in committers {
            c.join().unwrap();
        }
        assert_eq!(wal.commits(), 1, "one physical write+fsync for all callers");
        assert_eq!(wal.coalesced(), (N - 1) as u64);
        let scan = Wal::scan(&wal.path().to_path_buf()).unwrap();
        assert_eq!(scan.records.len(), N + 1, "every caller's record is durable");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crc32_known_vector() {
        // standard test vector: CRC-32 of "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
