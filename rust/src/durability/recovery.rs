//! Crash recovery: snapshot load → WAL tail replay → job inventory.
//!
//! `open(dir)` rebuilds the durable service state in three steps:
//!
//! 1. **Load shard snapshots** (or a legacy single-blob `snapshot.json`)
//!    into a fresh store/metrics pair via raw inserts — exact versions,
//!    no WAL emission.
//! 2. **Replay the WAL tail**: store records with `lsn > store_hwm` and
//!    metric records with `lsn > metrics_hwm` are applied through the
//!    *same* code paths the live service uses (raw version-preserving
//!    inserts for puts, the ordinary `emit` insertion logic for points),
//!    so the rebuilt structures are byte-identical to the pre-crash
//!    in-memory state up to the last group commit. A torn tail is
//!    truncated, never an error. Checkpoint records are collected
//!    regardless of the marks (they describe job progress, not store
//!    state) — the last one per job wins.
//!
//!    **Incremental-resume alignment (DESIGN.md §12):** for every job
//!    whose last checkpoint is a v1
//!    [`crate::coordinator::ResumeSnapshot`] that postdates the shard
//!    snapshot, the replay *skips* that job's own namespace records
//!    appearing **after** the checkpoint in file order — the partial
//!    poll slice a crash cut short. The rebuilt store/metrics state for
//!    that job is then exactly the checkpoint's state, so the API layer
//!    can rebuild the actor straight from the snapshot and resume with
//!    O(remaining work): the skipped mutations are re-produced by the
//!    resumed execution itself, with identical values *and* versions.
//!    The skipped records are also removed from the on-disk log
//!    (compact-style rewrite, LSNs preserved) — the resumed run
//!    re-appends the same mutations, and keeping both copies would
//!    double-apply metric emits on a second recovery.
//! 3. **Inventory tuning jobs** from the rebuilt store: every
//!    `tuning_jobs` record becomes a [`RecoveredJob`] with its persisted
//!    request, its last-checkpoint cursor (progress reporting) and —
//!    when step 2 aligned its state — the resume snapshot payload. The
//!    API layer resumes `InProgress` jobs from the snapshot when one is
//!    present, and falls back to deterministic scratch replay (reset +
//!    re-create, the pre-v1 path) otherwise — see `DESIGN.md` §10/§12.
//!
//! The WAL is then reopened for append at the end of its valid prefix
//! with a continuing LSN sequence, and attached to the store/metrics so
//! every post-recovery mutation is logged again.

use std::path::Path;
use std::sync::Arc;

use super::snapshot::{self, Manifest};
use super::wal::{Wal, WalRecord};
use super::DurabilityError;
use crate::coordinator::{checkpoint_cursor, is_resume_snapshot};
use crate::json::Json;
use crate::metrics::MetricsService;
use crate::store::{MetadataStore, StoreBatchOp};
use crate::workflow::ExecutionState;

/// One tuning job found in the recovered store.
pub struct RecoveredJob {
    /// Tuning-job name (`tuning_jobs` key).
    pub name: String,
    /// Persisted status: "InProgress" jobs are non-terminal and need
    /// resumption; anything else is left as recovered.
    pub status: String,
    /// The persisted `TuningJobRequest` wire JSON, when present.
    pub request: Option<Json>,
    /// Cursor rebuilt from the job's last WAL checkpoint, when present.
    /// Progress reporting only.
    pub checkpoint: Option<ExecutionState>,
    /// The job's last v1 resume-snapshot payload, present only when the
    /// replay aligned the store/metrics state to exactly that checkpoint
    /// (see the module docs). `Some` ⇒ the job can resume with
    /// O(remaining work); `None` ⇒ scratch replay.
    pub resume: Option<Json>,
}

/// Which job's namespace a store record belongs to, per the record
/// layout `crate::api::reset_job_records` owns: `tuning_jobs` /
/// `warm_start` keys are job names, `training_jobs` keys are
/// `{job}-train-NNNN` (and job names may not contain `-train-`, so the
/// split is unambiguous). Unknown tables belong to no job and are never
/// skipped.
fn store_key_owner(table: &str, key: &str) -> Option<&str> {
    match table {
        "tuning_jobs" | "warm_start" => Some(key),
        "training_jobs" => key.find("-train-").map(|i| &key[..i]),
        _ => None,
    }
}

/// Which job's namespace a metric stream (or removal prefix) belongs
/// to: `{job}-train-NNNN/...` or `{job}/...`.
fn stream_owner(name: &str) -> Option<&str> {
    if let Some(i) = name.find("-train-") {
        return Some(&name[..i]);
    }
    name.find('/').map(|i| &name[..i])
}

/// Borrow a checkpoint record's payload in place. The payloads are
/// O(job state), so the gating/inventory passes never clone them —
/// only each resumable job's single winning payload is cloned, once.
fn ckpt_payload(records: &[(u64, WalRecord)], idx: usize) -> &Json {
    match &records[idx].1 {
        WalRecord::Checkpoint { exec, .. } => exec,
        _ => unreachable!("checkpoint indices point at checkpoint records"),
    }
}

/// Owning job of any WAL record, if it belongs to one.
fn record_owner(rec: &WalRecord) -> Option<&str> {
    match rec {
        WalRecord::Put { table, key, .. } | WalRecord::Delete { table, key } => {
            store_key_owner(table, key)
        }
        WalRecord::Emit { stream, .. } => stream_owner(stream),
        WalRecord::RemoveStreams { prefix } => stream_owner(prefix),
        WalRecord::Checkpoint { job, .. } => Some(job),
    }
}

/// Everything `open` rebuilds from a durability directory.
pub struct RecoveredState {
    /// Store rebuilt from snapshot + WAL tail, WAL already attached.
    pub store: Arc<MetadataStore>,
    /// Metrics rebuilt the same way, WAL already attached.
    pub metrics: Arc<MetricsService>,
    /// The WAL, reopened for append after its valid prefix.
    pub wal: Arc<Wal>,
    /// Manifest of the snapshot that seeded recovery, if one existed.
    pub manifest: Option<Manifest>,
    /// WAL records applied during replay (after high-water-mark
    /// filtering; checkpoints count).
    pub replayed_records: usize,
    /// WAL records *skipped* by incremental-resume alignment: partial
    /// post-checkpoint slices of jobs that will resume from snapshots
    /// (the resumed execution re-produces them exactly).
    pub skipped_records: usize,
    /// True if a torn/corrupt WAL tail was truncated.
    pub dropped_tail: bool,
    /// Every tuning job present in the recovered store, name-sorted.
    pub jobs: Vec<RecoveredJob>,
}

/// Rebuild durable state from `dir` (which may be empty or absent: that
/// yields a fresh store, a fresh WAL and no jobs).
pub fn open(dir: &Path) -> Result<RecoveredState, DurabilityError> {
    std::fs::create_dir_all(dir)?;
    let store = Arc::new(MetadataStore::new());
    let metrics = Arc::new(MetricsService::new());

    let manifest = snapshot::load_snapshot(dir, &store, &metrics)?;
    let (store_hwm, metrics_hwm, mut next_lsn) = match &manifest {
        Some(m) => (m.store_hwm, m.metrics_hwm, m.next_lsn),
        None => (0, 0, 1),
    };

    let wal_path = dir.join(super::wal::WAL_FILE);
    let scan = Wal::scan(&wal_path)?;

    // pass 1 — last checkpoint per job (file order). A job qualifies for
    // incremental resume when that checkpoint is a v1 ResumeSnapshot AND
    // it postdates the shard snapshot on both components: a shard
    // snapshot can capture a job mid-slice (state past the job's last
    // committed checkpoint), which only the hwm comparison can rule out
    // — the conservative cases fall back to scratch replay, which is
    // always exact.
    struct LastCkpt {
        idx: usize,
        lsn: u64,
    }
    let mut last_ckpt: std::collections::BTreeMap<String, LastCkpt> = Default::default();
    let mut finished: std::collections::BTreeSet<String> = Default::default();
    for (idx, (lsn, rec)) in scan.records.iter().enumerate() {
        match rec {
            WalRecord::Checkpoint { job, .. } => {
                last_ckpt.insert(job.clone(), LastCkpt { idx, lsn: *lsn });
            }
            // a terminal tuning_jobs record means the job finished: its
            // completion must never be unwound by the skip below (it
            // would re-run and re-acknowledge on every open)
            WalRecord::Put { table, key, value, .. } if table == "tuning_jobs" => {
                if value.get("status").and_then(Json::as_str) != Some("InProgress") {
                    finished.insert(key.clone());
                }
            }
            _ => {}
        }
    }
    let mut resume_at: std::collections::BTreeMap<String, usize> = Default::default();
    for (job, c) in &last_ckpt {
        let v1 = is_resume_snapshot(ckpt_payload(&scan.records, c.idx));
        let past_snapshot =
            manifest.is_none() || (c.lsn > store_hwm && c.lsn > metrics_hwm);
        if v1 && past_snapshot && !finished.contains(job) {
            resume_at.insert(job.clone(), c.idx);
        }
    }

    // pass 2 — replay, skipping each resumable job's post-checkpoint
    // tail (the partial slice the crash cut short; the resumed
    // execution re-produces it bit-identically, versions included)
    let skip: Vec<bool> = scan
        .records
        .iter()
        .enumerate()
        .map(|(idx, (_, rec))| {
            record_owner(rec)
                .and_then(|job| resume_at.get(job))
                .is_some_and(|ckpt_idx| idx > *ckpt_idx)
        })
        .collect();
    let mut replayed = 0usize;
    let mut skipped = 0usize;
    // Replay is batched: raw-put/delete and emit runs accumulate and
    // flush through `put_batch` / `emit_batch` — one shard-lock
    // acquisition per touched shard per run, with the per-key /
    // per-stream application order (and hence final state) identical to
    // the old record-at-a-time loop. The WAL is not attached yet, so
    // nothing re-logs; `PutRaw` preserves versions exactly and `emit`'s
    // insertion logic is shared with `emit_batch`. `RemoveStreams` is a
    // barrier: the emits before it must land before the removal runs.
    let mut store_ops: Vec<StoreBatchOp<'_>> = Vec::new();
    let mut emits: Vec<(&str, f64, f64)> = Vec::new();
    for (idx, (lsn, rec)) in scan.records.iter().enumerate() {
        next_lsn = next_lsn.max(lsn + 1);
        if skip[idx] {
            skipped += 1;
            continue;
        }
        match rec {
            WalRecord::Put { table, key, version, value } if *lsn > store_hwm => {
                store_ops.push(StoreBatchOp::PutRaw {
                    table,
                    key,
                    version: *version,
                    value,
                });
                replayed += 1;
            }
            WalRecord::Delete { table, key } if *lsn > store_hwm => {
                store_ops.push(StoreBatchOp::Delete { table, key });
                replayed += 1;
            }
            WalRecord::Emit { stream, time, value } if *lsn > metrics_hwm => {
                emits.push((stream, *time, *value));
                replayed += 1;
            }
            WalRecord::RemoveStreams { prefix } if *lsn > metrics_hwm => {
                if !store_ops.is_empty() {
                    store.put_batch(&store_ops);
                    store_ops.clear();
                }
                if !emits.is_empty() {
                    metrics.emit_batch(&emits);
                    emits.clear();
                }
                metrics.remove_streams(prefix);
                replayed += 1;
            }
            WalRecord::Checkpoint { .. } => {
                replayed += 1; // payloads already collected in pass 1
            }
            _ => {} // already contained in the snapshot
        }
    }
    if !store_ops.is_empty() {
        store.put_batch(&store_ops);
    }
    if !emits.is_empty() {
        metrics.emit_batch(&emits);
    }

    // Skipped records must leave the on-disk log too: the resumed
    // execution re-appends the same mutations, so keeping both copies
    // would double-apply metric emits on a *second* recovery. Rewrite
    // the log without them (LSNs and order preserved, compact-style
    // tmp + fsync + rename + dir fsync) so the WAL always equals the
    // applied history; otherwise just truncate any torn tail.
    let valid_len = if skipped > 0 {
        let mut kept = Vec::new();
        for (idx, (lsn, rec)) in scan.records.iter().enumerate() {
            if skip[idx] {
                continue;
            }
            rec.encode_frame(*lsn, &mut kept);
        }
        let tmp = wal_path.with_extension("log.tmp");
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&kept)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &wal_path)?;
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all()?;
        }
        kept.len() as u64
    } else {
        scan.valid_len
    };

    // reopen for append after the valid prefix, truncating any torn tail
    let wal = Arc::new(Wal::open_at(dir, next_lsn, valid_len)?);
    store.attach_wal(Arc::clone(&wal));
    metrics.attach_wal(Arc::clone(&wal));

    // inventory tuning jobs (scan is key-sorted ⇒ deterministic order)
    let jobs = store
        .scan("tuning_jobs", "")
        .into_iter()
        .map(|(name, rec)| {
            let checkpoint = last_ckpt
                .get(&name)
                .and_then(|c| checkpoint_cursor(ckpt_payload(&scan.records, c.idx)));
            let resume = resume_at
                .get(&name)
                .map(|idx| ckpt_payload(&scan.records, *idx).clone());
            RecoveredJob {
                status: rec
                    .get("status")
                    .and_then(Json::as_str)
                    .unwrap_or("Unknown")
                    .to_string(),
                request: rec.get("request").cloned(),
                checkpoint,
                resume,
                name,
            }
        })
        .collect();

    Ok(RecoveredState {
        store,
        metrics,
        wal,
        manifest,
        replayed_records: replayed,
        skipped_records: skipped,
        dropped_tail: scan.dropped_tail,
        jobs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "amt-rec-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn empty_dir_yields_fresh_state() {
        let dir = tmp("empty");
        let r = open(&dir).unwrap();
        assert!(r.jobs.is_empty());
        assert_eq!(r.replayed_records, 0);
        assert!(!r.dropped_tail);
        assert!(r.manifest.is_none());
        // the reopened WAL is live: mutations are logged and survive
        r.store.put("t", "k", Json::Num(1.0));
        r.wal.commit().unwrap();
        let again = open(&dir).unwrap();
        assert_eq!(again.replayed_records, 1);
        assert_eq!(again.store.get("t", "k").unwrap(), (1, Json::Num(1.0)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_only_recovery_restores_versions_and_series() {
        let dir = tmp("walonly");
        {
            let r = open(&dir).unwrap();
            r.store.put("jobs", "a", Json::Num(1.0));
            r.store.put("jobs", "a", Json::Num(2.0)); // version 2
            r.store.put("jobs", "gone", Json::Null);
            r.store.delete("jobs", "gone");
            r.metrics.emit("a/loss", 5.0, 0.5);
            r.metrics.emit("a/loss", 2.0, 0.9); // out-of-order insert
            r.wal.commit().unwrap();
        }
        let r = open(&dir).unwrap();
        assert_eq!(r.store.get("jobs", "a").unwrap(), (2, Json::Num(2.0)));
        assert!(r.store.get("jobs", "gone").is_none());
        let times: Vec<f64> = r.metrics.series("a/loss").iter().map(|p| p.time).collect();
        assert_eq!(times, vec![2.0, 5.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn fake_v1_snapshot() -> Json {
        crate::json::parse(
            r#"{"v": 1,
                "cursor": {"current": 1, "attempt": 1, "transitions": 9,
                           "clock": 1.5, "steps_recorded": 9, "finished": null},
                "strategy": {"kind": "random"},
                "platform": {},
                "coord": {}}"#,
        )
        .unwrap()
    }

    /// Incremental-resume alignment: a resumable job's records *after*
    /// its last v1 checkpoint (the partial slice a crash cut short) are
    /// skipped during replay, so the rebuilt state is exactly the
    /// checkpoint's — while other jobs' records replay untouched.
    #[test]
    fn post_checkpoint_tail_is_skipped_for_resumable_jobs() {
        let dir = tmp("skiptail");
        {
            let r = open(&dir).unwrap();
            r.store.put(
                "tuning_jobs",
                "j",
                crate::json::parse(r#"{"status": "InProgress", "request": {"name": "j"}}"#)
                    .unwrap(),
            );
            r.store.put("training_jobs", "j-train-0000", Json::Num(1.0));
            r.metrics.emit("j-train-0000/objective", 1.0, 0.5);
            r.wal.append(&WalRecord::Checkpoint { job: "j".into(), exec: fake_v1_snapshot() });
            // the partial slice after the checkpoint: must not survive
            r.store.put("training_jobs", "j-train-0001", Json::Num(2.0));
            r.metrics.emit("j-train-0001/objective", 2.0, 0.7);
            r.metrics.emit("j/evaluations", 2.0, 0.7);
            // an unrelated job's record after j's checkpoint: must survive
            r.store.put("tuning_jobs", "other", Json::Num(3.0));
            r.wal.commit().unwrap();
        }
        let r = open(&dir).unwrap();
        assert_eq!(r.skipped_records, 3, "partial slice must be skipped");
        assert!(r.store.get("training_jobs", "j-train-0000").is_some());
        assert!(r.store.get("training_jobs", "j-train-0001").is_none(), "tail applied");
        assert!(r.metrics.series("j-train-0001/objective").is_empty());
        assert!(r.metrics.series("j/evaluations").is_empty());
        assert_eq!(r.store.get("tuning_jobs", "other").unwrap().1, Json::Num(3.0));
        let job = r.jobs.iter().find(|j| j.name == "j").unwrap();
        assert!(job.resume.is_some(), "v1 checkpoint must be offered for resume");
        assert!(job.checkpoint.is_some(), "cursor parses for progress reporting");
        drop(r);
        // the skipped tail was rewritten out of the on-disk log: a
        // second recovery sees a clean, already-aligned history
        let scan = Wal::scan(&dir.join(super::super::wal::WAL_FILE)).unwrap();
        assert!(
            !scan.records.iter().any(|(_, rec)| matches!(
                rec,
                WalRecord::Put { key, .. } if key == "j-train-0001"
            )),
            "skipped records must leave the log"
        );
        let r2 = open(&dir).unwrap();
        assert_eq!(r2.skipped_records, 0, "second recovery must find nothing to skip");
        assert!(r2.store.get("training_jobs", "j-train-0001").is_none());
        // legacy v0 (bare-cursor) checkpoints never align/skip
        let dir0 = tmp("skiptail-v0");
        {
            let r = open(&dir0).unwrap();
            r.store.put(
                "tuning_jobs",
                "j",
                crate::json::parse(r#"{"status": "InProgress"}"#).unwrap(),
            );
            let cursor = fake_v1_snapshot().get("cursor").unwrap().clone();
            r.wal.append(&WalRecord::Checkpoint { job: "j".into(), exec: cursor });
            r.store.put("training_jobs", "j-train-0001", Json::Num(2.0));
            r.wal.commit().unwrap();
        }
        let r = open(&dir0).unwrap();
        assert_eq!(r.skipped_records, 0);
        let job = r.jobs.iter().find(|j| j.name == "j").unwrap();
        assert!(job.resume.is_none(), "v0 checkpoints recover via scratch replay");
        assert!(job.checkpoint.is_some());
        assert!(r.store.get("training_jobs", "j-train-0001").is_some());
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir0);
    }

    /// A job whose terminal record postdates its last checkpoint is
    /// finished: the skip must not unwind its completion.
    #[test]
    fn terminal_jobs_are_never_unwound_by_the_skip() {
        let dir = tmp("terminal");
        {
            let r = open(&dir).unwrap();
            r.store.put(
                "tuning_jobs",
                "done",
                crate::json::parse(r#"{"status": "InProgress"}"#).unwrap(),
            );
            r.wal
                .append(&WalRecord::Checkpoint { job: "done".into(), exec: fake_v1_snapshot() });
            r.store.put(
                "tuning_jobs",
                "done",
                crate::json::parse(r#"{"status": "Completed"}"#).unwrap(),
            );
            r.wal.commit().unwrap();
        }
        let r = open(&dir).unwrap();
        assert_eq!(r.skipped_records, 0);
        let job = r.jobs.iter().find(|j| j.name == "done").unwrap();
        assert_eq!(job.status, "Completed");
        assert!(job.resume.is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_plus_tail_skips_contained_records() {
        let dir = tmp("hwm");
        {
            let r = open(&dir).unwrap();
            r.store.put("t", "before", Json::Num(1.0));
            r.metrics.emit("s", 1.0, 1.0);
            r.wal.commit().unwrap();
            super::super::snapshot::write_snapshot(&dir, &r.store, &r.metrics, &r.wal)
                .unwrap();
            r.store.put("t", "after", Json::Num(2.0));
            r.metrics.emit("s", 2.0, 2.0);
            r.wal.commit().unwrap();
        }
        let r = open(&dir).unwrap();
        // only the post-snapshot records replay; pre-snapshot ones load
        // from the shard files and must not double-apply
        assert_eq!(r.replayed_records, 2);
        assert_eq!(r.store.get("t", "before").unwrap().0, 1);
        assert_eq!(r.store.get("t", "after").unwrap().0, 1);
        assert_eq!(r.metrics.series("s").len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
