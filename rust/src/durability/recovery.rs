//! Crash recovery: snapshot load → WAL tail replay → job inventory.
//!
//! `open(dir)` rebuilds the durable service state in three steps:
//!
//! 1. **Load shard snapshots** (or a legacy single-blob `snapshot.json`)
//!    into a fresh store/metrics pair via raw inserts — exact versions,
//!    no WAL emission.
//! 2. **Replay the WAL tail**: store records with `lsn > store_hwm` and
//!    metric records with `lsn > metrics_hwm` are applied through the
//!    *same* code paths the live service uses (raw version-preserving
//!    inserts for puts, the ordinary `emit` insertion logic for points),
//!    so the rebuilt structures are byte-identical to the pre-crash
//!    in-memory state up to the last group commit. A torn tail is
//!    truncated, never an error. Checkpoint records are collected
//!    regardless of the marks (they describe job progress, not store
//!    state) — the last one per job wins.
//! 3. **Inventory tuning jobs** from the rebuilt store: every
//!    `tuning_jobs` record becomes a [`RecoveredJob`] with its persisted
//!    request and, when available, the deserialized
//!    [`crate::workflow::ExecutionState`] cursor from its last
//!    checkpoint. The API layer re-`activate`s the non-terminal ones
//!    (status `InProgress`) on the scheduler via deterministic replay —
//!    see `DESIGN.md` §10 for why replay-from-seed is exact.
//!
//! The WAL is then reopened for append at the end of its valid prefix
//! with a continuing LSN sequence, and attached to the store/metrics so
//! every post-recovery mutation is logged again.

use std::path::Path;
use std::sync::Arc;

use super::snapshot::{self, Manifest};
use super::wal::{Wal, WalRecord};
use super::DurabilityError;
use crate::json::Json;
use crate::metrics::MetricsService;
use crate::store::MetadataStore;
use crate::workflow::ExecutionState;

/// One tuning job found in the recovered store.
pub struct RecoveredJob {
    /// Tuning-job name (`tuning_jobs` key).
    pub name: String,
    /// Persisted status: "InProgress" jobs are non-terminal and need
    /// resumption; anything else is left as recovered.
    pub status: String,
    /// The persisted `TuningJobRequest` wire JSON, when present.
    pub request: Option<Json>,
    /// Cursor rebuilt from the job's last WAL checkpoint, when present.
    /// Progress reporting only — resumption replays deterministically.
    pub checkpoint: Option<ExecutionState>,
}

/// Everything `open` rebuilds from a durability directory.
pub struct RecoveredState {
    /// Store rebuilt from snapshot + WAL tail, WAL already attached.
    pub store: Arc<MetadataStore>,
    /// Metrics rebuilt the same way, WAL already attached.
    pub metrics: Arc<MetricsService>,
    /// The WAL, reopened for append after its valid prefix.
    pub wal: Arc<Wal>,
    /// Manifest of the snapshot that seeded recovery, if one existed.
    pub manifest: Option<Manifest>,
    /// WAL records applied during replay (after high-water-mark
    /// filtering; checkpoints count).
    pub replayed_records: usize,
    /// True if a torn/corrupt WAL tail was truncated.
    pub dropped_tail: bool,
    /// Every tuning job present in the recovered store, name-sorted.
    pub jobs: Vec<RecoveredJob>,
}

/// Rebuild durable state from `dir` (which may be empty or absent: that
/// yields a fresh store, a fresh WAL and no jobs).
pub fn open(dir: &Path) -> Result<RecoveredState, DurabilityError> {
    std::fs::create_dir_all(dir)?;
    let store = Arc::new(MetadataStore::new());
    let metrics = Arc::new(MetricsService::new());

    let manifest = snapshot::load_snapshot(dir, &store, &metrics)?;
    let (store_hwm, metrics_hwm, mut next_lsn) = match &manifest {
        Some(m) => (m.store_hwm, m.metrics_hwm, m.next_lsn),
        None => (0, 0, 1),
    };

    let wal_path = dir.join(super::wal::WAL_FILE);
    let scan = Wal::scan(&wal_path)?;
    let mut replayed = 0usize;
    let mut checkpoints: std::collections::BTreeMap<String, Json> = Default::default();
    for (lsn, rec) in &scan.records {
        match rec {
            WalRecord::Put { table, key, version, value } if *lsn > store_hwm => {
                store.insert_raw(table, key, *version, value.clone());
                replayed += 1;
            }
            WalRecord::Delete { table, key } if *lsn > store_hwm => {
                // WAL not yet attached: applies without re-logging
                store.delete(table, key);
                replayed += 1;
            }
            WalRecord::Emit { stream, time, value } if *lsn > metrics_hwm => {
                // same insertion logic as the live path ⇒ identical series
                metrics.emit(stream, *time, *value);
                replayed += 1;
            }
            WalRecord::RemoveStreams { prefix } if *lsn > metrics_hwm => {
                metrics.remove_streams(prefix);
                replayed += 1;
            }
            WalRecord::Checkpoint { job, exec } => {
                checkpoints.insert(job.clone(), exec.clone());
                replayed += 1;
            }
            _ => {} // already contained in the snapshot
        }
        next_lsn = next_lsn.max(lsn + 1);
    }

    // reopen for append after the valid prefix, truncating any torn tail
    let wal = Arc::new(Wal::open_at(dir, next_lsn, scan.valid_len)?);
    store.attach_wal(Arc::clone(&wal));
    metrics.attach_wal(Arc::clone(&wal));

    // inventory tuning jobs (scan is key-sorted ⇒ deterministic order)
    let jobs = store
        .scan("tuning_jobs", "")
        .into_iter()
        .map(|(name, rec)| {
            let checkpoint =
                checkpoints.remove(&name).as_ref().and_then(ExecutionState::from_json);
            RecoveredJob {
                status: rec
                    .get("status")
                    .and_then(Json::as_str)
                    .unwrap_or("Unknown")
                    .to_string(),
                request: rec.get("request").cloned(),
                checkpoint,
                name,
            }
        })
        .collect();

    Ok(RecoveredState {
        store,
        metrics,
        wal,
        manifest,
        replayed_records: replayed,
        dropped_tail: scan.dropped_tail,
        jobs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "amt-rec-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn empty_dir_yields_fresh_state() {
        let dir = tmp("empty");
        let r = open(&dir).unwrap();
        assert!(r.jobs.is_empty());
        assert_eq!(r.replayed_records, 0);
        assert!(!r.dropped_tail);
        assert!(r.manifest.is_none());
        // the reopened WAL is live: mutations are logged and survive
        r.store.put("t", "k", Json::Num(1.0));
        r.wal.commit().unwrap();
        let again = open(&dir).unwrap();
        assert_eq!(again.replayed_records, 1);
        assert_eq!(again.store.get("t", "k").unwrap(), (1, Json::Num(1.0)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_only_recovery_restores_versions_and_series() {
        let dir = tmp("walonly");
        {
            let r = open(&dir).unwrap();
            r.store.put("jobs", "a", Json::Num(1.0));
            r.store.put("jobs", "a", Json::Num(2.0)); // version 2
            r.store.put("jobs", "gone", Json::Null);
            r.store.delete("jobs", "gone");
            r.metrics.emit("a/loss", 5.0, 0.5);
            r.metrics.emit("a/loss", 2.0, 0.9); // out-of-order insert
            r.wal.commit().unwrap();
        }
        let r = open(&dir).unwrap();
        assert_eq!(r.store.get("jobs", "a").unwrap(), (2, Json::Num(2.0)));
        assert!(r.store.get("jobs", "gone").is_none());
        let times: Vec<f64> = r.metrics.series("a/loss").iter().map(|p| p.time).collect();
        assert_eq!(times, vec![2.0, 5.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_plus_tail_skips_contained_records() {
        let dir = tmp("hwm");
        {
            let r = open(&dir).unwrap();
            r.store.put("t", "before", Json::Num(1.0));
            r.metrics.emit("s", 1.0, 1.0);
            r.wal.commit().unwrap();
            super::super::snapshot::write_snapshot(&dir, &r.store, &r.metrics, &r.wal)
                .unwrap();
            r.store.put("t", "after", Json::Num(2.0));
            r.metrics.emit("s", 2.0, 2.0);
            r.wal.commit().unwrap();
        }
        let r = open(&dir).unwrap();
        // only the post-snapshot records replay; pre-snapshot ones load
        // from the shard files and must not double-apply
        assert_eq!(r.replayed_records, 2);
        assert_eq!(r.store.get("t", "before").unwrap().0, 1);
        assert_eq!(r.store.get("t", "after").unwrap().0, 1);
        assert_eq!(r.metrics.series("s").len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
