//! Durability engine (DESIGN.md §10): write-ahead log, per-shard
//! snapshots and crash recovery for the tuning service.
//!
//! The paper's AMT is *fully managed*: the metadata/state layer must
//! survive process restarts without losing tuning-job progress (§3.2's
//! DynamoDB-backed store is the backbone of that guarantee). This module
//! is the reproduction's stand-in for that persistence tier:
//!
//! * [`wal`] — append-only, length-prefixed + CRC-checksummed record log
//!   of every store/metrics mutation, group-committed per scheduler tick;
//! * [`snapshot`] — per-shard point-in-time snapshots written with a WAL
//!   high-water mark, replacing the merge-everything
//!   [`crate::store::MetadataStore::snapshot`] blob for service
//!   persistence (the legacy blob remains accepted on recovery);
//! * [`recovery`] — `open(dir)` loads the shard snapshots, replays the
//!   WAL tail, rebuilds [`crate::workflow::ExecutionState`] cursors from
//!   job checkpoints and inventories every tuning job so the API layer
//!   can re-`activate` the non-terminal ones.
//!
//! A job interrupted at an arbitrary WAL offset and recovered through
//! [`crate::api::AmtService::open`] finishes with exactly the trajectory
//! of an uninterrupted run (property-tested in
//! `rust/tests/durability_integration.rs`): every tuning job is a pure
//! function of its request seed on its own discrete-event timeline, so
//! recovery resets the job's partial records and replays it
//! deterministically from the start — same puts in the same order ⇒ same
//! values *and* versions. The on-disk format defined here is also the
//! wire format the planned distributed backend will ship between
//! processes (ROADMAP).

pub mod recovery;
pub mod snapshot;
pub mod wal;

/// Tuning knobs for a durable service opened via
/// [`crate::api::AmtService::open_with_durability`].
#[derive(Clone, Copy, Debug, Default)]
pub struct DurabilityOptions {
    /// When `Some(n)`: after a scheduler group commit leaves more than
    /// `n` bytes durably in the WAL, the service automatically runs a
    /// `checkpoint()` (per-shard snapshot + WAL compaction) from the
    /// committing worker thread, so a long-running service's log stays
    /// bounded without any API-side discipline. `None` (the default)
    /// keeps checkpoints purely manual.
    pub auto_checkpoint_bytes: Option<u64>,
}

/// Durability-layer failure: an I/O error or a corrupt snapshot/manifest.
/// Torn WAL tails are *not* errors — they are truncated during recovery.
#[derive(Debug)]
pub enum DurabilityError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// Unparseable or schema-violating snapshot/manifest content.
    Corrupt(String),
}

impl From<std::io::Error> for DurabilityError {
    fn from(e: std::io::Error) -> Self {
        DurabilityError::Io(e)
    }
}

impl std::fmt::Display for DurabilityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DurabilityError::Io(e) => write!(f, "durability io error: {e}"),
            DurabilityError::Corrupt(m) => write!(f, "durability corruption: {m}"),
        }
    }
}

impl std::error::Error for DurabilityError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DurabilityError::Io(e) => Some(e),
            DurabilityError::Corrupt(_) => None,
        }
    }
}
