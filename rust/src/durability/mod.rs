//! Durability engine (DESIGN.md §10): write-ahead log, per-shard
//! snapshots and crash recovery for the tuning service.
//!
//! The paper's AMT is *fully managed*: the metadata/state layer must
//! survive process restarts without losing tuning-job progress (§3.2's
//! DynamoDB-backed store is the backbone of that guarantee). This module
//! is the reproduction's stand-in for that persistence tier:
//!
//! * [`wal`] — append-only, length-prefixed + CRC-checksummed record log
//!   of every store/metrics mutation, group-committed per scheduler tick;
//! * [`snapshot`] — per-shard point-in-time snapshots written with a WAL
//!   high-water mark, replacing the merge-everything
//!   [`crate::store::MetadataStore::snapshot`] blob for service
//!   persistence (the legacy blob remains accepted on recovery);
//! * [`recovery`] — `open(dir)` loads the shard snapshots, replays the
//!   WAL tail, rebuilds [`crate::workflow::ExecutionState`] cursors from
//!   job checkpoints and inventories every tuning job so the API layer
//!   can re-`activate` the non-terminal ones.
//!
//! A job interrupted at an arbitrary WAL offset and recovered through
//! [`crate::api::AmtService::open`] finishes with exactly the trajectory
//! of an uninterrupted run (property-tested in
//! `rust/tests/durability_integration.rs`): every tuning job is a pure
//! function of its request seed on its own discrete-event timeline, so
//! recovery resets the job's partial records and replays it
//! deterministically from the start — same puts in the same order ⇒ same
//! values *and* versions. The on-disk format defined here is also the
//! wire format the planned distributed backend will ship between
//! processes (ROADMAP).

pub mod recovery;
pub mod snapshot;
pub mod wal;

/// Tuning knobs for a durable service opened via
/// [`crate::api::AmtService::open_with_durability`].
#[derive(Clone, Copy, Debug, Default)]
pub struct DurabilityOptions {
    /// When `Some(n)`: after a scheduler group commit leaves more than
    /// `n` bytes durably in the WAL, the service automatically runs a
    /// `checkpoint()` (per-shard snapshot + WAL compaction) from the
    /// committing worker thread, so a long-running service's log stays
    /// bounded without any API-side discipline. `None` (the default)
    /// keeps checkpoints purely manual.
    pub auto_checkpoint_bytes: Option<u64>,
    /// When `Some(w)`: a WAL group-commit leader waits `w` before
    /// capturing the buffer ([`wal::Wal::set_commit_window`]), so
    /// concurrent lane drivers and scheduler workers finishing slices at
    /// nearly the same time share one `write`+`fsync`. `None` (the
    /// default) commits immediately — coalescing still happens whenever
    /// commits genuinely overlap, just without the extra linger.
    pub group_commit_window: Option<std::time::Duration>,
}

/// Group-commit `wal`, retrying once on failure. The shared
/// commit-and-count discipline of both execution planes (the in-process
/// scheduler's heap-drain boundary and the distributed leader's slice
/// boundary): a persistent failure is counted in `failures` and never
/// propagated — the records stay buffered inside the WAL (which rewinds
/// any torn fragment first) and retry at the next commit, so no mutation
/// is dropped while the process lives. `post_commit` runs only after a
/// *successful* commit (the durable service's auto-checkpoint trigger).
pub fn commit_with_retry(
    wal: &wal::Wal,
    failures: &std::sync::atomic::AtomicU64,
    post_commit: Option<&std::sync::Arc<dyn Fn() + Send + Sync>>,
) {
    if wal.commit().is_err() && wal.commit().is_err() {
        failures.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    } else if let Some(hook) = post_commit {
        (**hook)();
    }
}

/// Durability-layer failure: an I/O error or a corrupt snapshot/manifest.
/// Torn WAL tails are *not* errors — they are truncated during recovery.
#[derive(Debug)]
pub enum DurabilityError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// Unparseable or schema-violating snapshot/manifest content.
    Corrupt(String),
}

impl From<std::io::Error> for DurabilityError {
    fn from(e: std::io::Error) -> Self {
        DurabilityError::Io(e)
    }
}

impl std::fmt::Display for DurabilityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DurabilityError::Io(e) => write!(f, "durability io error: {e}"),
            DurabilityError::Corrupt(m) => write!(f, "durability corruption: {m}"),
        }
    }
}

impl std::error::Error for DurabilityError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DurabilityError::Io(e) => Some(e),
            DurabilityError::Corrupt(_) => None,
        }
    }
}
