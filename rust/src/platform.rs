//! SageMaker Training platform simulator (§3.2–§3.3).
//!
//! Every hyperparameter evaluation runs as a separate *training job* on
//! this platform, exactly as in AMT. The simulator is a deterministic
//! discrete-event system on a virtual clock and reproduces the cost
//! structure the paper's experiments depend on:
//!
//! * **cluster provisioning overhead** — "a training job involves setting
//!   up a new cluster of EC2 instances, waiting for the setup to complete,
//!   and downloading algorithm images", with the §3.3 *compute
//!   provisioning optimizations* available as a toggle;
//! * **per-epoch metric emission** — intermediate objective values drive
//!   the §5.2 early stopper;
//! * **failure injection** — dependency failures at provisioning and
//!   OOM-style crashes mid-training (§3.3's example failure scenarios),
//!   which the workflow engine's retry mechanism must absorb;
//! * **distributed training mode** — multi-instance clusters shorten
//!   epochs with imperfect scaling efficiency (Fig 4 right).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

use crate::json::Json;
use crate::objectives::Objective;
use crate::rng::Rng;
use crate::space::Config;

/// Platform tuning knobs.
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    /// Mean EC2 cluster provisioning time (seconds).
    pub provisioning_mean: f64,
    /// Provisioning jitter (uniform ± this).
    pub provisioning_jitter: f64,
    /// §3.3 compute-provisioning optimizations: cuts provisioning time.
    pub fast_provisioning: bool,
    /// Algorithm-image download time (seconds).
    pub image_download_seconds: f64,
    /// Probability a job fails during provisioning (dependency issues).
    pub provisioning_failure_rate: f64,
    /// Probability a job crashes at a random epoch (e.g. OOM).
    pub training_failure_rate: f64,
    /// Marginal speedup per extra instance (1.0 = perfect scaling).
    pub distributed_efficiency: f64,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            provisioning_mean: 120.0,
            provisioning_jitter: 30.0,
            fast_provisioning: true,
            image_download_seconds: 45.0,
            provisioning_failure_rate: 0.01,
            training_failure_rate: 0.01,
            distributed_efficiency: 0.8,
        }
    }
}

impl PlatformConfig {
    /// Deterministic, failure-free platform for unit tests and benches.
    pub fn noiseless() -> Self {
        PlatformConfig {
            provisioning_jitter: 0.0,
            provisioning_failure_rate: 0.0,
            training_failure_rate: 0.0,
            ..Default::default()
        }
    }

    /// JSON wire form (the distributed plane ships the leader's platform
    /// configuration to remote workers so their simulated timelines are
    /// bit-identical to an in-process run).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("provisioning_mean", Json::Num(self.provisioning_mean)),
            ("provisioning_jitter", Json::Num(self.provisioning_jitter)),
            ("fast_provisioning", Json::Bool(self.fast_provisioning)),
            ("image_download_seconds", Json::Num(self.image_download_seconds)),
            ("provisioning_failure_rate", Json::Num(self.provisioning_failure_rate)),
            ("training_failure_rate", Json::Num(self.training_failure_rate)),
            ("distributed_efficiency", Json::Num(self.distributed_efficiency)),
        ])
    }

    /// Parse the JSON wire form (missing fields take defaults).
    pub fn from_json(j: &Json) -> PlatformConfig {
        let d = PlatformConfig::default();
        let num = |k: &str, dv: f64| j.get(k).and_then(Json::as_f64).unwrap_or(dv);
        PlatformConfig {
            provisioning_mean: num("provisioning_mean", d.provisioning_mean),
            provisioning_jitter: num("provisioning_jitter", d.provisioning_jitter),
            fast_provisioning: j
                .get("fast_provisioning")
                .and_then(Json::as_bool)
                .unwrap_or(d.fast_provisioning),
            image_download_seconds: num("image_download_seconds", d.image_download_seconds),
            provisioning_failure_rate: num(
                "provisioning_failure_rate",
                d.provisioning_failure_rate,
            ),
            training_failure_rate: num("training_failure_rate", d.training_failure_rate),
            distributed_efficiency: num("distributed_efficiency", d.distributed_efficiency),
        }
    }
}

/// Identifier of a training job within one platform instance.
pub type JobId = usize;

/// Submission request for one training job.
pub struct TrainingJobSpec {
    /// Job name (unique per tuning job; used as the metric stream key).
    pub name: String,
    /// Hyperparameter configuration under evaluation.
    pub config: Config,
    /// Workload to train.
    pub objective: Arc<dyn Objective>,
    /// Seed for the evaluation noise.
    pub seed: u64,
    /// EC2 instances in the cluster (>1 = distributed mode).
    pub instance_count: u32,
}

/// Lifecycle states (mirrors the SageMaker training-job status values).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainingJobStatus {
    /// Cluster being set up.
    Provisioning,
    /// Training in progress.
    InProgress,
    /// Ran its full epoch budget.
    Completed,
    /// Crashed (provisioning or training).
    Failed,
    /// Stopped by the tuning workflow (early stopping or Stop API).
    Stopped,
}

impl TrainingJobStatus {
    /// Stable wire name (shared by the distributed protocol and resume
    /// snapshots).
    pub fn as_str(self) -> &'static str {
        match self {
            TrainingJobStatus::Provisioning => "Provisioning",
            TrainingJobStatus::InProgress => "InProgress",
            TrainingJobStatus::Completed => "Completed",
            TrainingJobStatus::Failed => "Failed",
            TrainingJobStatus::Stopped => "Stopped",
        }
    }

    /// Parse a [`TrainingJobStatus::as_str`] name.
    pub fn parse(s: &str) -> Option<TrainingJobStatus> {
        Some(match s {
            "Provisioning" => TrainingJobStatus::Provisioning,
            "InProgress" => TrainingJobStatus::InProgress,
            "Completed" => TrainingJobStatus::Completed,
            "Failed" => TrainingJobStatus::Failed,
            "Stopped" => TrainingJobStatus::Stopped,
            _ => return None,
        })
    }
}

/// Why a job failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureReason {
    /// Dependency problems while setting up the cluster.
    ProvisioningError,
    /// Out-of-memory-style crash mid-training (e.g. the BO engine suggested
    /// an over-large configuration, §3.3).
    TrainingCrash,
}

impl FailureReason {
    /// Stable wire name (resume snapshots).
    pub fn as_str(self) -> &'static str {
        match self {
            FailureReason::ProvisioningError => "ProvisioningError",
            FailureReason::TrainingCrash => "TrainingCrash",
        }
    }

    /// Parse a [`FailureReason::as_str`] name.
    pub fn parse(s: &str) -> Option<FailureReason> {
        Some(match s {
            "ProvisioningError" => FailureReason::ProvisioningError,
            "TrainingCrash" => FailureReason::TrainingCrash,
            _ => return None,
        })
    }
}

/// Observable job record.
#[derive(Clone, Debug)]
pub struct TrainingJobInfo {
    /// Job name from the spec.
    pub name: String,
    /// Evaluated configuration.
    pub config: Config,
    /// Current status.
    pub status: TrainingJobStatus,
    /// Metric values for epochs completed so far.
    pub curve: Vec<f64>,
    /// Virtual submission time.
    pub submitted_at: f64,
    /// Virtual time training started (provisioning done).
    pub started_at: Option<f64>,
    /// Virtual terminal time.
    pub ended_at: Option<f64>,
    /// Failure cause, if failed.
    pub failure: Option<FailureReason>,
    /// Total epochs the job would run if never stopped.
    pub max_epochs: u32,
    /// Billable seconds (provisioned-to-terminal), populated at the end.
    pub billable_seconds: f64,
}

/// Events surfaced to the workflow engine, in virtual-time order.
#[derive(Clone, Debug, PartialEq)]
pub enum PlatformEvent {
    /// Provisioning finished; training began.
    JobStarted { job: JobId, time: f64 },
    /// One epoch finished with an intermediate metric value.
    EpochCompleted { job: JobId, epoch: u32, value: f64, time: f64 },
    /// All epochs done.
    JobCompleted { job: JobId, final_value: f64, time: f64 },
    /// Job crashed.
    JobFailed { job: JobId, reason: FailureReason, time: f64 },
}

impl PlatformEvent {
    /// Event timestamp.
    pub fn time(&self) -> f64 {
        match self {
            PlatformEvent::JobStarted { time, .. }
            | PlatformEvent::EpochCompleted { time, .. }
            | PlatformEvent::JobCompleted { time, .. }
            | PlatformEvent::JobFailed { time, .. } => *time,
        }
    }

    /// Job the event belongs to.
    pub fn job(&self) -> JobId {
        match self {
            PlatformEvent::JobStarted { job, .. }
            | PlatformEvent::EpochCompleted { job, .. }
            | PlatformEvent::JobCompleted { job, .. }
            | PlatformEvent::JobFailed { job, .. } => *job,
        }
    }
}

#[derive(Debug)]
enum Queued {
    Start { job: JobId },
    Epoch { job: JobId, epoch: u32 },
    ProvisionFail { job: JobId },
}

struct HeapEntry {
    time: f64,
    seq: u64,
    item: Queued,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

struct JobState {
    info: TrainingJobInfo,
    full_curve: Vec<f64>,
    epoch_seconds: f64,
    crash_at_epoch: Option<u32>,
    cancelled: bool,
}

/// The discrete-event training platform.
pub struct TrainingPlatform {
    config: PlatformConfig,
    rng: Rng,
    now: f64,
    seq: u64,
    queue: BinaryHeap<Reverse<HeapEntry>>,
    jobs: HashMap<JobId, JobState>,
    next_id: JobId,
}

impl TrainingPlatform {
    /// New platform with its own virtual clock at t = 0.
    pub fn new(config: PlatformConfig, seed: u64) -> Self {
        TrainingPlatform {
            config,
            rng: Rng::new(seed ^ 0x9E3779B97F4A7C15),
            now: 0.0,
            seq: 0,
            queue: BinaryHeap::new(),
            jobs: HashMap::new(),
            next_id: 0,
        }
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total training jobs ever submitted to this platform (including
    /// retries). The cache-dedupe tests assert on this: an evaluation
    /// served from the cross-job evaluation cache never submits here.
    pub fn submitted_jobs(&self) -> usize {
        self.next_id as usize
    }

    /// Read a job record.
    pub fn job(&self, id: JobId) -> Option<&TrainingJobInfo> {
        self.jobs.get(&id).map(|s| &s.info)
    }

    /// Number of jobs in non-terminal states.
    pub fn active_jobs(&self) -> usize {
        self.jobs
            .values()
            .filter(|s| {
                matches!(
                    s.info.status,
                    TrainingJobStatus::Provisioning | TrainingJobStatus::InProgress
                )
            })
            .count()
    }

    fn push(&mut self, time: f64, item: Queued) {
        self.seq += 1;
        self.queue.push(Reverse(HeapEntry { time, seq: self.seq, item }));
    }

    /// Submit a training job; returns its id. Provisioning begins now.
    pub fn submit(&mut self, spec: TrainingJobSpec) -> JobId {
        let id = self.next_id;
        self.next_id += 1;

        let mut rng = self.rng.fork(id as u64);
        let full_curve = spec.objective.curve(&spec.config, spec.seed);
        let max_epochs = full_curve.len() as u32;

        let speedup = 1.0
            + self.config.distributed_efficiency * (spec.instance_count.max(1) - 1) as f64;
        let epoch_seconds =
            (spec.objective.epoch_seconds(&spec.config) / speedup).max(1e-3);

        let prov_scale = if self.config.fast_provisioning { 0.4 } else { 1.0 };
        let provisioning = (self.config.provisioning_mean * prov_scale
            + rng.uniform_range(-1.0, 1.0) * self.config.provisioning_jitter * prov_scale)
            .max(1.0)
            + self.config.image_download_seconds;

        let crash_at_epoch = (rng.uniform() < self.config.training_failure_rate)
            .then(|| 1 + rng.below(max_epochs as usize) as u32);

        let info = TrainingJobInfo {
            name: spec.name,
            config: spec.config,
            status: TrainingJobStatus::Provisioning,
            curve: Vec::new(),
            submitted_at: self.now,
            started_at: None,
            ended_at: None,
            failure: None,
            max_epochs,
            billable_seconds: 0.0,
        };
        self.jobs.insert(
            id,
            JobState { info, full_curve, epoch_seconds, crash_at_epoch, cancelled: false },
        );

        if rng.uniform() < self.config.provisioning_failure_rate {
            let t = self.now + provisioning * rng.uniform_range(0.3, 1.0);
            self.push(t, Queued::ProvisionFail { job: id });
        } else {
            self.push(self.now + provisioning, Queued::Start { job: id });
        }
        id
    }

    /// Stop a running/provisioning job (early stopping or the Stop API).
    pub fn stop_job(&mut self, id: JobId) {
        if let Some(state) = self.jobs.get_mut(&id) {
            if matches!(
                state.info.status,
                TrainingJobStatus::Provisioning | TrainingJobStatus::InProgress
            ) {
                state.cancelled = true;
                state.info.status = TrainingJobStatus::Stopped;
                state.info.ended_at = Some(self.now);
                state.info.billable_seconds =
                    self.now - state.info.submitted_at;
            }
        }
    }

    /// Pop the next event, advancing the virtual clock. `None` ⇒ idle.
    pub fn next_event(&mut self) -> Option<PlatformEvent> {
        while let Some(Reverse(entry)) = self.queue.pop() {
            let (time, item) = (entry.time, entry.item);
            let id = match &item {
                Queued::Start { job }
                | Queued::Epoch { job, .. }
                | Queued::ProvisionFail { job } => *job,
            };
            let cancelled = self.jobs.get(&id).map(|s| s.cancelled).unwrap_or(true);
            if cancelled {
                continue; // stopped jobs drop their scheduled events
            }
            self.now = self.now.max(time);

            match item {
                Queued::ProvisionFail { job } => {
                    let s = self.jobs.get_mut(&job).unwrap();
                    s.info.status = TrainingJobStatus::Failed;
                    s.info.failure = Some(FailureReason::ProvisioningError);
                    s.info.ended_at = Some(self.now);
                    s.info.billable_seconds = self.now - s.info.submitted_at;
                    s.cancelled = true;
                    return Some(PlatformEvent::JobFailed {
                        job,
                        reason: FailureReason::ProvisioningError,
                        time: self.now,
                    });
                }
                Queued::Start { job } => {
                    let jitter = 1.0 + 0.1 * (self.rng.uniform() - 0.5);
                    let s = self.jobs.get_mut(&job).unwrap();
                    s.info.status = TrainingJobStatus::InProgress;
                    s.info.started_at = Some(self.now);
                    let dt = s.epoch_seconds * jitter;
                    let next = self.now + dt;
                    self.push(next, Queued::Epoch { job, epoch: 1 });
                    return Some(PlatformEvent::JobStarted { job, time: self.now });
                }
                Queued::Epoch { job, epoch } => {
                    let jitter = 1.0 + 0.1 * (self.rng.uniform() - 0.5);
                    let s = self.jobs.get_mut(&job).unwrap();
                    if s.crash_at_epoch == Some(epoch) {
                        s.info.status = TrainingJobStatus::Failed;
                        s.info.failure = Some(FailureReason::TrainingCrash);
                        s.info.ended_at = Some(self.now);
                        s.info.billable_seconds = self.now - s.info.submitted_at;
                        s.cancelled = true;
                        return Some(PlatformEvent::JobFailed {
                            job,
                            reason: FailureReason::TrainingCrash,
                            time: self.now,
                        });
                    }
                    let value = s.full_curve[epoch as usize - 1];
                    s.info.curve.push(value);
                    if epoch == s.info.max_epochs {
                        s.info.status = TrainingJobStatus::Completed;
                        s.info.ended_at = Some(self.now);
                        s.info.billable_seconds = self.now - s.info.submitted_at;
                        s.cancelled = true;
                        return Some(PlatformEvent::JobCompleted {
                            job,
                            final_value: value,
                            time: self.now,
                        });
                    }
                    let dt = s.epoch_seconds * jitter;
                    let next = self.now + dt;
                    self.push(next, Queued::Epoch { job, epoch: epoch + 1 });
                    return Some(PlatformEvent::EpochCompleted {
                        job,
                        epoch,
                        value,
                        time: self.now,
                    });
                }
            }
        }
        None
    }
}

impl TrainingPlatform {
    /// Freeze the entire discrete-event state — RNG words, virtual
    /// clock, event queue, per-job records with their precomputed metric
    /// curves — into JSON, the platform half of a
    /// [`crate::coordinator`] resume snapshot (schema v1, DESIGN.md
    /// §12). Every f64 round-trips bit-exactly and the queue is stored
    /// in pop order, so a thawed platform emits exactly the remaining
    /// event sequence of the original: no objective re-evaluation, no
    /// replayed provisioning draws. The `PlatformConfig` rides along, so
    /// the snapshot is self-sufficient.
    pub fn state_to_json(&self) -> Json {
        let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        let curve = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());

        let mut entries: Vec<&HeapEntry> = self.queue.iter().map(|r| &r.0).collect();
        entries.sort_by(|a, b| a.time.total_cmp(&b.time).then(a.seq.cmp(&b.seq)));
        let queue = Json::Arr(
            entries
                .into_iter()
                .map(|e| {
                    let (kind, job, epoch) = match &e.item {
                        Queued::Start { job } => ("start", *job, None),
                        Queued::Epoch { job, epoch } => ("epoch", *job, Some(*epoch)),
                        Queued::ProvisionFail { job } => ("pfail", *job, None),
                    };
                    Json::obj(vec![
                        ("t", Json::Num(e.time)),
                        ("seq", crate::json::u64_to_json(e.seq)),
                        ("kind", Json::Str(kind.into())),
                        ("job", Json::Num(job as f64)),
                        (
                            "epoch",
                            epoch.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null),
                        ),
                    ])
                })
                .collect(),
        );

        let mut ids: Vec<JobId> = self.jobs.keys().copied().collect();
        ids.sort_unstable();
        let jobs = Json::Arr(
            ids.into_iter()
                .map(|id| {
                    let s = &self.jobs[&id];
                    Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        ("name", Json::Str(s.info.name.clone())),
                        ("config", crate::space::config_to_json_typed(&s.info.config)),
                        ("status", Json::Str(s.info.status.as_str().into())),
                        ("curve", curve(&s.info.curve)),
                        ("submitted_at", Json::Num(s.info.submitted_at)),
                        ("started_at", opt_num(s.info.started_at)),
                        ("ended_at", opt_num(s.info.ended_at)),
                        (
                            "failure",
                            s.info
                                .failure
                                .map(|f| Json::Str(f.as_str().into()))
                                .unwrap_or(Json::Null),
                        ),
                        ("max_epochs", Json::Num(s.info.max_epochs as f64)),
                        ("billable_seconds", Json::Num(s.info.billable_seconds)),
                        ("full_curve", curve(&s.full_curve)),
                        ("epoch_seconds", Json::Num(s.epoch_seconds)),
                        (
                            "crash_at_epoch",
                            s.crash_at_epoch
                                .map(|v| Json::Num(v as f64))
                                .unwrap_or(Json::Null),
                        ),
                        ("cancelled", Json::Bool(s.cancelled)),
                    ])
                })
                .collect(),
        );

        Json::obj(vec![
            ("config", self.config.to_json()),
            ("rng", self.rng.state_to_json()),
            ("now", Json::Num(self.now)),
            ("seq", crate::json::u64_to_json(self.seq)),
            ("next_id", Json::Num(self.next_id as f64)),
            ("queue", queue),
            ("jobs", jobs),
        ])
    }

    /// Thaw a platform from [`TrainingPlatform::state_to_json`]. Returns
    /// `None` on any schema mismatch (the caller falls back to scratch
    /// replay).
    pub fn from_state_json(j: &Json) -> Option<TrainingPlatform> {
        let floats = |v: &Json| -> Option<Vec<f64>> {
            v.as_arr()?.iter().map(Json::as_f64).collect()
        };
        let rng = Rng::from_state_json(j.get("rng")?)?;

        let mut queue = BinaryHeap::new();
        for e in j.get("queue")?.as_arr()? {
            let job = e.get("job")?.as_i64()? as JobId;
            let item = match e.get("kind")?.as_str()? {
                "start" => Queued::Start { job },
                "epoch" => Queued::Epoch { job, epoch: e.get("epoch")?.as_i64()? as u32 },
                "pfail" => Queued::ProvisionFail { job },
                _ => return None,
            };
            queue.push(Reverse(HeapEntry {
                time: e.get("t")?.as_f64()?,
                seq: crate::json::u64_from_json(e.get("seq")?)?,
                item,
            }));
        }

        let mut jobs = HashMap::new();
        for rec in j.get("jobs")?.as_arr()? {
            let id = rec.get("id")?.as_i64()? as JobId;
            let info = TrainingJobInfo {
                name: rec.get("name")?.as_str()?.to_string(),
                config: crate::space::config_from_json_typed(rec.get("config")?)?,
                status: TrainingJobStatus::parse(rec.get("status")?.as_str()?)?,
                curve: floats(rec.get("curve")?)?,
                submitted_at: rec.get("submitted_at")?.as_f64()?,
                started_at: rec.get("started_at").and_then(Json::as_f64),
                ended_at: rec.get("ended_at").and_then(Json::as_f64),
                failure: match rec.get("failure")? {
                    Json::Null => None,
                    f => Some(FailureReason::parse(f.as_str()?)?),
                },
                max_epochs: rec.get("max_epochs")?.as_i64()? as u32,
                billable_seconds: rec.get("billable_seconds")?.as_f64()?,
            };
            jobs.insert(
                id,
                JobState {
                    info,
                    full_curve: floats(rec.get("full_curve")?)?,
                    epoch_seconds: rec.get("epoch_seconds")?.as_f64()?,
                    crash_at_epoch: rec
                        .get("crash_at_epoch")
                        .and_then(Json::as_i64)
                        .map(|v| v as u32),
                    cancelled: rec.get("cancelled")?.as_bool()?,
                },
            );
        }

        Some(TrainingPlatform {
            config: PlatformConfig::from_json(j.get("config")?),
            rng,
            now: j.get("now")?.as_f64()?,
            seq: crate::json::u64_from_json(j.get("seq")?)?,
            queue,
            jobs,
            next_id: j.get("next_id")?.as_i64()? as JobId,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objectives::by_name;

    fn spec(name: &str, seed: u64) -> TrainingJobSpec {
        let obj = by_name("branin").unwrap();
        let mut rng = Rng::new(seed);
        let config = obj.space().sample(&mut rng);
        TrainingJobSpec {
            name: name.into(),
            config,
            objective: obj.into(),
            seed,
            instance_count: 1,
        }
    }

    fn drain(p: &mut TrainingPlatform) -> Vec<PlatformEvent> {
        let mut out = Vec::new();
        while let Some(e) = p.next_event() {
            out.push(e);
        }
        out
    }

    #[test]
    fn job_runs_through_lifecycle() {
        let mut p = TrainingPlatform::new(PlatformConfig::noiseless(), 1);
        let id = p.submit(spec("j1", 1));
        let events = drain(&mut p);
        assert!(matches!(events[0], PlatformEvent::JobStarted { .. }));
        assert!(matches!(events.last().unwrap(), PlatformEvent::JobCompleted { .. }));
        let info = p.job(id).unwrap();
        assert_eq!(info.status, TrainingJobStatus::Completed);
        assert_eq!(info.curve.len(), info.max_epochs as usize);
        assert!(info.billable_seconds > 0.0);
        // provisioning overhead is visible: started_at > submitted_at
        assert!(info.started_at.unwrap() > info.submitted_at);
    }

    #[test]
    fn events_are_time_ordered() {
        let mut p = TrainingPlatform::new(PlatformConfig::default(), 2);
        for i in 0..5 {
            p.submit(spec(&format!("j{i}"), i));
        }
        let events = drain(&mut p);
        for w in events.windows(2) {
            assert!(w[0].time() <= w[1].time());
        }
    }

    #[test]
    fn stop_job_halts_events() {
        let mut p = TrainingPlatform::new(PlatformConfig::noiseless(), 3);
        let id = p.submit(spec("j", 1));
        // run past start + 2 epochs
        let mut epochs = 0;
        while let Some(e) = p.next_event() {
            if matches!(e, PlatformEvent::EpochCompleted { .. }) {
                epochs += 1;
                if epochs == 2 {
                    p.stop_job(id);
                }
            }
        }
        let info = p.job(id).unwrap();
        assert_eq!(info.status, TrainingJobStatus::Stopped);
        assert_eq!(info.curve.len(), 2);
    }

    #[test]
    fn provisioning_failures_injected() {
        let mut p = TrainingPlatform::new(
            PlatformConfig {
                provisioning_failure_rate: 1.0,
                ..PlatformConfig::noiseless()
            },
            4,
        );
        let id = p.submit(spec("j", 9));
        let events = drain(&mut p);
        assert_eq!(events.len(), 1);
        assert!(matches!(
            events[0],
            PlatformEvent::JobFailed { reason: FailureReason::ProvisioningError, .. }
        ));
        assert_eq!(p.job(id).unwrap().status, TrainingJobStatus::Failed);
    }

    #[test]
    fn training_crashes_injected() {
        let mut p = TrainingPlatform::new(
            PlatformConfig { training_failure_rate: 1.0, ..PlatformConfig::noiseless() },
            5,
        );
        p.submit(spec("j", 11));
        let events = drain(&mut p);
        assert!(matches!(
            events.last().unwrap(),
            PlatformEvent::JobFailed { reason: FailureReason::TrainingCrash, .. }
        ));
    }

    #[test]
    fn fast_provisioning_reduces_overhead() {
        let run = |fast: bool| {
            let mut p = TrainingPlatform::new(
                PlatformConfig { fast_provisioning: fast, ..PlatformConfig::noiseless() },
                6,
            );
            let id = p.submit(spec("j", 2));
            drain(&mut p);
            let info = p.job(id).unwrap();
            info.started_at.unwrap() - info.submitted_at
        };
        assert!(run(true) < run(false));
    }

    #[test]
    fn distributed_mode_shortens_epochs() {
        let run = |instances: u32| {
            let mut p = TrainingPlatform::new(PlatformConfig::noiseless(), 7);
            let mut s = spec("j", 3);
            s.instance_count = instances;
            let id = p.submit(s);
            drain(&mut p);
            let info = p.job(id).unwrap();
            info.ended_at.unwrap() - info.started_at.unwrap()
        };
        let single = run(1);
        let distributed = run(4);
        assert!(
            distributed < 0.5 * single,
            "4 instances should cut epoch time >2x: {distributed} vs {single}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut p = TrainingPlatform::new(PlatformConfig::default(), 42);
            for i in 0..3 {
                p.submit(spec(&format!("j{i}"), i));
            }
            drain(&mut p)
                .iter()
                .map(|e| (e.job(), e.time()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn state_roundtrip_mid_drain_emits_identical_remaining_events() {
        // freeze after a handful of events with failures + jitter live,
        // thaw, and require the exact remaining event sequence bit-for-bit
        let cfg = PlatformConfig {
            provisioning_failure_rate: 0.2,
            training_failure_rate: 0.2,
            ..PlatformConfig::default()
        };
        let mut p = TrainingPlatform::new(cfg, 21);
        for i in 0..6 {
            p.submit(spec(&format!("j{i}"), i));
        }
        for _ in 0..7 {
            p.next_event();
        }
        p.stop_job(2); // a cancelled job's dropped events must survive the trip
        let frozen = p.state_to_json().to_string();
        let mut thawed =
            TrainingPlatform::from_state_json(&crate::json::parse(&frozen).unwrap()).unwrap();
        assert_eq!(thawed.now().to_bits(), p.now().to_bits());
        loop {
            let a = p.next_event();
            let b = thawed.next_event();
            match (&a, &b) {
                (None, None) => break,
                (Some(x), Some(y)) => {
                    assert_eq!(x.job(), y.job());
                    assert_eq!(x.time().to_bits(), y.time().to_bits());
                    assert_eq!(x, y);
                }
                _ => panic!("event streams diverged: {a:?} vs {b:?}"),
            }
        }
        // submissions after the thaw also agree (RNG + next_id restored)
        let ia = p.submit(spec("late", 9));
        let ib = thawed.submit(spec("late", 9));
        assert_eq!(ia, ib);
        assert_eq!(
            p.next_event().map(|e| e.time().to_bits()),
            thawed.next_event().map(|e| e.time().to_bits())
        );
    }

    #[test]
    fn status_and_failure_wire_names_roundtrip() {
        for s in [
            TrainingJobStatus::Provisioning,
            TrainingJobStatus::InProgress,
            TrainingJobStatus::Completed,
            TrainingJobStatus::Failed,
            TrainingJobStatus::Stopped,
        ] {
            assert_eq!(TrainingJobStatus::parse(s.as_str()), Some(s));
        }
        assert_eq!(TrainingJobStatus::parse("nope"), None);
        for f in [FailureReason::ProvisioningError, FailureReason::TrainingCrash] {
            assert_eq!(FailureReason::parse(f.as_str()), Some(f));
        }
        assert_eq!(FailureReason::parse("nope"), None);
    }

    #[test]
    fn active_job_counting() {
        let mut p = TrainingPlatform::new(PlatformConfig::noiseless(), 8);
        let a = p.submit(spec("a", 1));
        let _b = p.submit(spec("b", 2));
        assert_eq!(p.active_jobs(), 2);
        p.stop_job(a);
        assert_eq!(p.active_jobs(), 1);
        drain(&mut p);
        assert_eq!(p.active_jobs(), 0);
    }
}
