//! Sobol low-discrepancy sequences (§4.3 of the paper).
//!
//! AMT uses a Sobol generator to populate the search space with a dense,
//! well-spread pseudo-random grid of anchor points that (a) seed the
//! Thompson-style marginal sampling and (b) initialize the local
//! optimization of the expected improvement. This is a Gray-code
//! implementation with the Joe–Kuo (new-joe-kuo-6) direction numbers for the
//! first [`MAX_DIM`] dimensions — comfortably above the encoded-configuration
//! dimension used by the HLO artifacts (D = 8).

/// Maximum supported dimensionality.
pub const MAX_DIM: usize = 21;

const BITS: u32 = 52; // enough for f64 mantissa use

/// (s, a, m[..s]) rows of the Joe–Kuo direction-number table, dimensions
/// 2..=21 (dimension 1 is the van der Corput sequence).
const JOE_KUO: &[(u32, u32, &[u32])] = &[
    (1, 0, &[1]),
    (2, 1, &[1, 3]),
    (3, 1, &[1, 3, 1]),
    (3, 2, &[1, 1, 1]),
    (4, 1, &[1, 1, 3, 3]),
    (4, 4, &[1, 3, 5, 13]),
    (5, 2, &[1, 1, 5, 5, 17]),
    (5, 4, &[1, 1, 5, 5, 5]),
    (5, 7, &[1, 1, 7, 11, 19]),
    (5, 11, &[1, 1, 5, 1, 1]),
    (5, 13, &[1, 1, 1, 3, 11]),
    (5, 14, &[1, 3, 5, 5, 31]),
    (6, 1, &[1, 3, 3, 9, 7, 49]),
    (6, 13, &[1, 1, 1, 15, 21, 21]),
    (6, 16, &[1, 3, 1, 13, 27, 49]),
    (6, 19, &[1, 1, 1, 15, 7, 5]),
    (6, 22, &[1, 3, 1, 15, 13, 25]),
    (6, 25, &[1, 1, 5, 5, 19, 61]),
    (7, 1, &[1, 3, 7, 11, 23, 15, 103]),
    (7, 4, &[1, 3, 7, 13, 13, 15, 69]),
];

/// Sobol sequence generator over the unit hypercube.
pub struct Sobol {
    dim: usize,
    /// direction numbers, `v[d][k]`, scaled to BITS bits
    v: Vec<[u64; BITS as usize]>,
    x: Vec<u64>,
    index: u64,
}

impl Sobol {
    /// New generator for `dim` dimensions (1..=MAX_DIM).
    pub fn new(dim: usize) -> Self {
        assert!(
            (1..=MAX_DIM).contains(&dim),
            "sobol: dim {dim} out of range 1..={MAX_DIM}"
        );
        let mut v = Vec::with_capacity(dim);
        // dimension 1: van der Corput, v_k = 2^(BITS - k - 1)
        let mut v0 = [0u64; BITS as usize];
        for (k, slot) in v0.iter_mut().enumerate() {
            *slot = 1u64 << (BITS - 1 - k as u32);
        }
        v.push(v0);
        for d in 1..dim {
            let (s, a, m) = JOE_KUO[d - 1];
            let s = s as usize;
            let mut vd = [0u64; BITS as usize];
            for k in 0..BITS as usize {
                if k < s {
                    vd[k] = (m[k] as u64) << (BITS - 1 - k as u32);
                } else {
                    let mut val = vd[k - s] ^ (vd[k - s] >> s);
                    for j in 1..s {
                        if (a >> (s - 1 - j)) & 1 == 1 {
                            val ^= vd[k - j];
                        }
                    }
                    vd[k] = val;
                }
            }
            v.push(vd);
        }
        Sobol { dim, v, x: vec![0; dim], index: 0 }
    }

    /// Dimensionality of the sequence.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The generator cursor: `(index, x)` — everything that changes as
    /// points are drawn (the direction numbers are a pure function of
    /// `dim`). Used by [`crate::coordinator`] resume snapshots.
    pub fn state(&self) -> (u64, &[u64]) {
        (self.index, &self.x)
    }

    /// Rebuild a generator mid-sequence from a captured [`Sobol::state`].
    /// Returns `None` when the state does not fit the dimension.
    pub fn from_state(dim: usize, index: u64, x: &[u64]) -> Option<Sobol> {
        if x.len() != dim {
            return None;
        }
        let mut s = Sobol::new(dim);
        s.index = index;
        s.x.copy_from_slice(x);
        Some(s)
    }

    /// Next point in [0, 1)^dim (Gray-code order; the first emitted point is
    /// the origin-skipped point 0.5,…).
    pub fn next_point(&mut self) -> Vec<f64> {
        // skip index 0 (the all-zeros point) like common implementations
        self.index += 1;
        let c = self.index.trailing_zeros().min(BITS - 1);
        let scale = 1.0 / (1u64 << BITS) as f64;
        for d in 0..self.dim {
            self.x[d] ^= self.v[d][c as usize];
        }
        self.x.iter().map(|&u| u as f64 * scale).collect()
    }

    /// Generate the next `n` points.
    pub fn take_points(&mut self, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| self.next_point()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_points_dimension_one_are_van_der_corput() {
        let mut s = Sobol::new(1);
        let got: Vec<f64> = (0..7).map(|_| s.next_point()[0]).collect();
        let want = [0.5, 0.75, 0.25, 0.375, 0.875, 0.625, 0.125];
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-12, "{got:?}");
        }
    }

    #[test]
    fn first_points_dimension_two() {
        let mut s = Sobol::new(2);
        let got: Vec<Vec<f64>> = s.take_points(4);
        // standard Sobol (origin skipped): (.5,.5), (.75,.25), (.25,.75), (.375,.375)
        let want = [[0.5, 0.5], [0.75, 0.25], [0.25, 0.75], [0.375, 0.375]];
        for (g, w) in got.iter().zip(want.iter()) {
            for (a, b) in g.iter().zip(w.iter()) {
                assert!((a - b).abs() < 1e-12, "{got:?}");
            }
        }
    }

    #[test]
    fn points_in_unit_cube_all_dims() {
        for dim in 1..=MAX_DIM {
            let mut s = Sobol::new(dim);
            for p in s.take_points(256) {
                assert_eq!(p.len(), dim);
                for &c in &p {
                    assert!((0.0..1.0).contains(&c));
                }
            }
        }
    }

    #[test]
    fn no_duplicate_points_in_prefix() {
        let mut s = Sobol::new(8);
        let pts = s.take_points(1024);
        let mut keys: Vec<String> = pts
            .iter()
            .map(|p| {
                p.iter()
                    .map(|c| format!("{c:.15}"))
                    .collect::<Vec<_>>()
                    .join(",")
            })
            .collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), 1024);
    }

    #[test]
    fn coverage_better_than_random_grid_gap() {
        // every axis should have points in each of 16 equal bins after 256 draws
        let mut s = Sobol::new(6);
        let pts = s.take_points(256);
        for d in 0..6 {
            let mut bins = [0u32; 16];
            for p in &pts {
                bins[(p[d] * 16.0) as usize] += 1;
            }
            assert!(bins.iter().all(|&b| b > 0), "dim {d}: {bins:?}");
        }
    }

    #[test]
    #[should_panic]
    fn rejects_oversized_dim() {
        let _ = Sobol::new(MAX_DIM + 1);
    }

    #[test]
    fn state_roundtrip_continues_the_sequence() {
        let mut a = Sobol::new(5);
        a.take_points(37); // advance mid-sequence
        let (index, x) = a.state();
        let mut b = Sobol::from_state(5, index, &x.to_vec()).unwrap();
        for _ in 0..64 {
            assert_eq!(a.next_point(), b.next_point());
        }
        assert!(Sobol::from_state(5, 1, &[0; 4]).is_none(), "dim mismatch rejected");
    }
}
