//! `amt` — CLI for the SageMaker-AMT reproduction.
//!
//! Commands:
//!   amt tune --objective <name> [--strategy bayesian] [--max-jobs 20]
//!            [--parallel 1] [--early-stopping off] [--backend native|hlo]
//!            [--instances 1] [--seed 0]
//!   amt objectives                 list built-in workloads
//!   amt artifacts-check [dir]      compile & smoke-run every HLO artifact
//!   amt snapshot <path>            run a small job and dump the store
//!
//! (The vendored offline crate set has no clap; argument parsing is a small
//! hand-rolled layer over std::env.)

use std::collections::HashMap;
use std::sync::Arc;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::gp::{NativeBackend, SurrogateBackend, Theta};
use amt::platform::PlatformConfig;
use amt::rng::Rng;
use amt::runtime::{HloBackend, HloRuntime};

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

fn backend_by_name(name: &str) -> anyhow::Result<Arc<dyn SurrogateBackend>> {
    Ok(match name {
        "native" => Arc::new(NativeBackend),
        "hlo" => Arc::new(HloBackend::new(HloRuntime::open_default()?)),
        other => anyhow::bail!("unknown backend '{other}' (native|hlo)"),
    })
}

fn cmd_tune(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let objective = flag(flags, "objective", "branin").to_string();
    let request = TuningJobRequest {
        name: flag(flags, "name", &format!("tune-{objective}")).to_string(),
        objective: objective.clone(),
        strategy: flag(flags, "strategy", "bayesian").to_string(),
        max_training_jobs: flag(flags, "max-jobs", "20").parse()?,
        max_parallel_jobs: flag(flags, "parallel", "1").parse()?,
        early_stopping: flag(flags, "early-stopping", "off").to_string(),
        instance_count: flag(flags, "instances", "1").parse()?,
        seed: flag(flags, "seed", "0").parse()?,
        ..Default::default()
    };
    let backend = backend_by_name(flag(flags, "backend", "native"))?;
    let service = AmtService::with_backend(PlatformConfig::default(), backend);
    let obj = amt::objectives::by_name(&objective)
        .ok_or_else(|| anyhow::anyhow!("unknown objective"))?;

    println!(
        "tuning '{}' with {} ({} evaluations, {} parallel, early stopping: {})",
        request.objective,
        request.strategy,
        request.max_training_jobs,
        request.max_parallel_jobs,
        request.early_stopping
    );
    let name = service
        .create_tuning_job(request)
        .map_err(|e| anyhow::anyhow!("create: {e}"))?;
    let outcome = service.wait(&name).map_err(|e| anyhow::anyhow!("wait: {e}"))?;

    println!(
        "\ntuning job '{}' finished: {:?} | {} evaluations | {} retries | {:.0}s simulated",
        outcome.name,
        outcome.status,
        outcome.evaluations.len(),
        outcome.retries,
        outcome.total_seconds
    );
    let stopped = outcome.evaluations.iter().filter(|e| e.stopped_early).count();
    if stopped > 0 {
        println!("early-stopped evaluations: {stopped}");
    }
    if let Some((config, value)) = &outcome.best {
        println!("best {} = {:.6}", if obj.minimize() { "min" } else { "max" }, value);
        for (k, v) in config {
            println!("  {k} = {v:?}");
        }
    }
    Ok(())
}

fn cmd_objectives() {
    println!("built-in objectives (workloads):");
    for name in amt::objectives::all_names() {
        let obj = amt::objectives::by_name(name).unwrap();
        println!(
            "  {name:<22} dims={:<2} epochs={:<3} {}",
            obj.space().encoded_dim(),
            obj.max_epochs(),
            if obj.minimize() { "minimize" } else { "maximize" }
        );
    }
}

fn cmd_artifacts_check(dir: &str) -> anyhow::Result<()> {
    let rt = HloRuntime::open(dir)?;
    println!(
        "manifest: buckets {:?}, D = {}, M = {}, mlp widths {:?}",
        rt.manifest.buckets,
        rt.manifest.encoded_dim,
        rt.manifest.cand_batch,
        rt.manifest.mlp_widths
    );
    let backend = HloBackend::new(Arc::clone(&rt));
    let mut rng = Rng::new(0);
    for &b in &rt.manifest.buckets.clone() {
        let n = (b * 3 / 4).max(1); // a live size inside this bucket
        let d = rt.manifest.encoded_dim;
        let x = amt::gp::Dataset::from_fn(n, d, |_, _| rng.uniform());
        let theta = Theta::default_for_dim(d);
        let k = amt::gp::SurrogateBackend::gram(&backend, &x, &theta);
        anyhow::ensure!(k.rows == n, "bad gram shape for bucket {b}");
        println!("kernel_matrix_n{b}: OK ({n} live rows)");
    }
    for &h in &rt.manifest.mlp_widths.clone() {
        let mut trainer = amt::runtime::mlp::MlpTrainer::new(Arc::clone(&rt), h, 0)?;
        let data = amt::runtime::mlp::MlpDataset::generate(&rt, 0);
        let loss = trainer.train_epoch(&data, 0.05, 1e-4)?;
        anyhow::ensure!(loss.is_finite());
        println!("mlp_train_h{h}/mlp_eval_h{h}: OK (train loss {loss:.4})");
    }
    println!(
        "all artifacts healthy ({} executions)",
        rt.executions.load(std::sync::atomic::Ordering::Relaxed)
    );
    Ok(())
}

fn cmd_snapshot(path: &str) -> anyhow::Result<()> {
    let service = AmtService::new(PlatformConfig::default());
    let request = TuningJobRequest {
        name: "snapshot-demo".into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 5,
        ..Default::default()
    };
    let name = service.create_tuning_job(request).map_err(|e| anyhow::anyhow!("{e}"))?;
    service.wait(&name).map_err(|e| anyhow::anyhow!("{e}"))?;
    std::fs::write(path, service.store().snapshot())?;
    println!("metadata-store snapshot written to {path}");
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "tune" => cmd_tune(&flags),
        "objectives" => {
            cmd_objectives();
            Ok(())
        }
        "artifacts-check" => {
            cmd_artifacts_check(pos.get(1).map(String::as_str).unwrap_or("artifacts"))
        }
        "snapshot" => cmd_snapshot(pos.get(1).map(String::as_str).unwrap_or("store.json")),
        _ => {
            println!(
                "usage: amt <tune|objectives|artifacts-check|snapshot> [--flags]\n\
                 see module docs in rust/src/main.rs"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
