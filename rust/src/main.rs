//! `amt` — CLI for the SageMaker-AMT reproduction.
//!
//! Commands:
//!   amt tune --objective <name> [--strategy bayesian] [--max-jobs 20]
//!            [--parallel 1] [--early-stopping off] [--backend native|hlo]
//!            [--instances 1] [--seed 0]
//!   amt objectives                 list built-in workloads
//!   amt artifacts-check [dir]      compile & smoke-run every HLO artifact
//!   amt snapshot <path>            run a small job and dump the store
//!   amt worker --listen <addr>     host tuning jobs for a remote leader
//!                                  (addr: host:port or unix:/path)
//!   amt worker --connect <addr>    dial an `amt serve --listen` leader
//!                                  instead; reconnects with capped
//!                                  exponential backoff + jitter when the
//!                                  leader is down or the link dies
//!                                  (DESIGN.md §13)
//!   amt serve --workers a,b,...    run a tuning spike with evaluations
//!            [--listen <addr>] [--jobs 16] [--objective branin]
//!            [--strategy random] [--max-jobs 5] [--parallel 2] [--seed 0]
//!                                  fanned out over remote workers; with
//!                                  --listen, workers may also join the
//!                                  fleet mid-run (DESIGN.md §11, §13);
//!                                  prints one telemetry line per
//!                                  subsystem at shutdown (DESIGN.md §15)
//!   amt stats [--jobs 4] [--distributed 0] [--json]
//!                                  run a short spike against an
//!                                  in-process (or loopback-distributed)
//!                                  fleet and print the full telemetry
//!                                  snapshot: counters, gauges, and
//!                                  latency histograms (p50/p99/p999)
//!   amt trace [job] [--workers 2] [--max-jobs 4]
//!                                  run one job over loopback workers and
//!                                  print its slice lifecycle: propose →
//!                                  dispatch → worker_poll → delta_apply
//!                                  → group_commit → outcome
//!   amt load <workload.json> [--report-every 5] [--json] [--seed N]
//!   amt load --canned [--scale 1]  run a declarative mixed workload with
//!                                  chaos injection (DESIGN.md §16): per-op
//!                                  SLO histograms (load.*_us), live
//!                                  one-line stats, and invariant observers;
//!                                  exits non-zero if any observer fails.
//!                                  --print-canned dumps the canned spec's
//!                                  JSON as a starting template.
//!
//! (The vendored offline crate set has no clap; argument parsing is a small
//! hand-rolled layer over std::env.)

use std::collections::HashMap;
use std::sync::Arc;

use amt::api::AmtService;
use amt::config::TuningJobRequest;
use amt::gp::{NativeBackend, SurrogateBackend, Theta};
use amt::platform::PlatformConfig;
use amt::rng::Rng;
use amt::runtime::{HloBackend, HloRuntime};

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

fn backend_by_name(name: &str) -> anyhow::Result<Arc<dyn SurrogateBackend>> {
    Ok(match name {
        "native" => Arc::new(NativeBackend),
        "hlo" => Arc::new(HloBackend::new(HloRuntime::open_default()?)),
        other => anyhow::bail!("unknown backend '{other}' (native|hlo)"),
    })
}

fn cmd_tune(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let objective = flag(flags, "objective", "branin").to_string();
    let request = TuningJobRequest {
        name: flag(flags, "name", &format!("tune-{objective}")).to_string(),
        objective: objective.clone(),
        strategy: flag(flags, "strategy", "bayesian").to_string(),
        max_training_jobs: flag(flags, "max-jobs", "20").parse()?,
        max_parallel_jobs: flag(flags, "parallel", "1").parse()?,
        early_stopping: flag(flags, "early-stopping", "off").to_string(),
        instance_count: flag(flags, "instances", "1").parse()?,
        seed: flag(flags, "seed", "0").parse()?,
        ..Default::default()
    };
    let backend = backend_by_name(flag(flags, "backend", "native"))?;
    let service = AmtService::with_backend(PlatformConfig::default(), backend);
    let obj = amt::objectives::by_name(&objective)
        .ok_or_else(|| anyhow::anyhow!("unknown objective"))?;

    println!(
        "tuning '{}' with {} ({} evaluations, {} parallel, early stopping: {})",
        request.objective,
        request.strategy,
        request.max_training_jobs,
        request.max_parallel_jobs,
        request.early_stopping
    );
    let name = service
        .create_tuning_job(request)
        .map_err(|e| anyhow::anyhow!("create: {e}"))?;
    let outcome = service.wait(&name).map_err(|e| anyhow::anyhow!("wait: {e}"))?;

    println!(
        "\ntuning job '{}' finished: {:?} | {} evaluations | {} retries | {:.0}s simulated",
        outcome.name,
        outcome.status,
        outcome.evaluations.len(),
        outcome.retries,
        outcome.total_seconds
    );
    let stopped = outcome.evaluations.iter().filter(|e| e.stopped_early).count();
    if stopped > 0 {
        println!("early-stopped evaluations: {stopped}");
    }
    if let Some((config, value)) = &outcome.best {
        println!("best {} = {:.6}", if obj.minimize() { "min" } else { "max" }, value);
        for (k, v) in config {
            println!("  {k} = {v:?}");
        }
    }
    Ok(())
}

fn cmd_objectives() {
    println!("built-in objectives (workloads):");
    for name in amt::objectives::all_names() {
        let obj = amt::objectives::by_name(name).unwrap();
        println!(
            "  {name:<22} dims={:<2} epochs={:<3} {}",
            obj.space().encoded_dim(),
            obj.max_epochs(),
            if obj.minimize() { "minimize" } else { "maximize" }
        );
    }
}

fn cmd_artifacts_check(dir: &str) -> anyhow::Result<()> {
    let rt = HloRuntime::open(dir)?;
    println!(
        "manifest: buckets {:?}, D = {}, M = {}, mlp widths {:?}",
        rt.manifest.buckets,
        rt.manifest.encoded_dim,
        rt.manifest.cand_batch,
        rt.manifest.mlp_widths
    );
    let backend = HloBackend::new(Arc::clone(&rt));
    let mut rng = Rng::new(0);
    for &b in &rt.manifest.buckets.clone() {
        let n = (b * 3 / 4).max(1); // a live size inside this bucket
        let d = rt.manifest.encoded_dim;
        let x = amt::gp::Dataset::from_fn(n, d, |_, _| rng.uniform());
        let theta = Theta::default_for_dim(d);
        let k = amt::gp::SurrogateBackend::gram(&backend, &x, &theta);
        anyhow::ensure!(k.rows == n, "bad gram shape for bucket {b}");
        println!("kernel_matrix_n{b}: OK ({n} live rows)");
    }
    for &h in &rt.manifest.mlp_widths.clone() {
        let mut trainer = amt::runtime::mlp::MlpTrainer::new(Arc::clone(&rt), h, 0)?;
        let data = amt::runtime::mlp::MlpDataset::generate(&rt, 0);
        let loss = trainer.train_epoch(&data, 0.05, 1e-4)?;
        anyhow::ensure!(loss.is_finite());
        println!("mlp_train_h{h}/mlp_eval_h{h}: OK (train loss {loss:.4})");
    }
    println!(
        "all artifacts healthy ({} executions)",
        rt.executions.load(std::sync::atomic::Ordering::Relaxed)
    );
    Ok(())
}

/// `amt worker`: host tuning jobs for remote leaders. Serves one leader
/// connection at a time (the runtime is single-threaded by design — see
/// `distributed::worker`) and goes back to accepting when a session
/// drains or its leader disappears.
fn cmd_worker(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use amt::distributed::transport::{SocketListener, Transport};
    use amt::distributed::worker::WorkerRuntime;
    if let Some(addr) = flags.get("connect") {
        return cmd_worker_connect(addr);
    }
    let addr = flag(flags, "listen", "127.0.0.1:7070");
    let listener = SocketListener::bind(addr)?;
    eprintln!("amt worker listening on {}", listener.local_addr());
    loop {
        let transport = listener.accept()?;
        eprintln!("leader connected: {}", transport.peer());
        let mut runtime = WorkerRuntime::new(Box::new(transport))?;
        match runtime.run() {
            Ok(()) => eprintln!(
                "session drained cleanly ({} poll slices served)",
                runtime.polls_served
            ),
            Err(e) => eprintln!(
                "leader link lost after {} poll slices: {e}",
                runtime.polls_served
            ),
        }
    }
}

/// `amt worker --connect`: dial the leader instead of listening for it
/// (the symmetric membership direction, DESIGN.md §13). Reconnects with
/// capped exponential backoff + jitter while the leader is not up yet
/// (`ConnectionRefused`) and after a dead link; exits cleanly on a
/// graceful drain, and hard-exits on a leader `Deny` (surfaced as
/// `PermissionDenied`, e.g. a duplicate worker name) — retrying a hard
/// verdict would loop forever.
fn cmd_worker_connect(addr: &str) -> anyhow::Result<()> {
    use amt::distributed::transport::{is_not_listening, SocketTransport, Transport};
    use amt::distributed::worker::WorkerRuntime;
    const BASE: std::time::Duration = std::time::Duration::from_millis(200);
    const CAP: std::time::Duration = std::time::Duration::from_secs(10);
    // jitter keeps a restarted fleet from hammering the leader in lockstep
    let seed = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64)
        .unwrap_or(0)
        ^ std::process::id() as u64;
    let mut rng = Rng::new(seed);
    let mut delay = BASE;
    loop {
        let transport = match SocketTransport::connect(addr) {
            Ok(t) => t,
            Err(e) if is_not_listening(&e) => {
                let jittered = delay.mul_f64(1.0 + 0.25 * rng.uniform());
                eprintln!("leader at {addr} not up yet, retrying in {jittered:?}");
                std::thread::sleep(jittered);
                delay = (delay * 2).min(CAP);
                continue;
            }
            Err(e) => return Err(e.into()),
        };
        eprintln!("connected to leader {}", transport.peer());
        delay = BASE; // a live leader resets the backoff clock
        let mut runtime = WorkerRuntime::new(Box::new(transport))?;
        match runtime.run() {
            Ok(()) => {
                eprintln!(
                    "session drained cleanly ({} poll slices served)",
                    runtime.polls_served
                );
                return Ok(());
            }
            Err(e) if e.kind() == std::io::ErrorKind::PermissionDenied => {
                anyhow::bail!("{e}");
            }
            Err(e) => {
                eprintln!(
                    "leader link lost after {} poll slices: {e} — reconnecting",
                    runtime.polls_served
                );
            }
        }
    }
}

/// `amt serve`: the leader half of the multi-process demo — connect to
/// running `amt worker`s, spike a batch of tuning jobs across them and
/// report the results. With `--listen`, also accepts workers that dial
/// in (`amt worker --connect`) before and during the run.
fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use amt::distributed::transport::{SocketListener, SocketTransport, Transport};
    let workers = flag(flags, "workers", "");
    let listen = flag(flags, "listen", "");
    if workers.is_empty() && listen.is_empty() {
        anyhow::bail!(
            "--workers <addr,addr,...> or --listen <addr> is required \
             (start `amt worker` first, or have workers dial in with \
             `amt worker --connect <addr>`)"
        );
    }
    let mut transports: Vec<Box<dyn Transport>> = Vec::new();
    for addr in workers.split(',').filter(|a| !a.is_empty()) {
        transports.push(Box::new(SocketTransport::connect(addr)?));
        eprintln!("connected to worker {addr}");
    }
    let jobs: usize = flag(flags, "jobs", "16").parse()?;
    let objective = flag(flags, "objective", "branin").to_string();
    let strategy = flag(flags, "strategy", "random").to_string();
    let max_jobs: u32 = flag(flags, "max-jobs", "5").parse()?;
    let parallel: u32 = flag(flags, "parallel", "2").parse()?;
    let seed: u64 = flag(flags, "seed", "0").parse()?;

    let service = AmtService::with_remote_workers(PlatformConfig::default(), transports);
    let pool = service.remote_pool().expect("remote plane attached");
    if !listen.is_empty() {
        let listener = SocketListener::bind(listen)?;
        eprintln!("accepting workers on {}", listener.local_addr());
        pool.accept_workers(listener);
        if workers.is_empty() {
            // no pre-connected workers: wait for the first dial-in so the
            // spike has somewhere to run
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
            while pool.live_workers() == 0 {
                if std::time::Instant::now() >= deadline {
                    anyhow::bail!("no worker connected to {listen} within 60s");
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
    let started = std::time::Instant::now();
    for i in 0..jobs {
        let request = TuningJobRequest {
            name: format!("served-{i:04}"),
            objective: objective.clone(),
            strategy: strategy.clone(),
            max_training_jobs: max_jobs,
            max_parallel_jobs: parallel,
            seed: seed ^ i as u64,
            ..Default::default()
        };
        service
            .create_tuning_job(request)
            .map_err(|e| anyhow::anyhow!("create served-{i:04}: {e}"))?;
    }
    let mut evaluations = 0usize;
    let mut failed = 0usize;
    for i in 0..jobs {
        let outcome = service
            .wait(&format!("served-{i:04}"))
            .map_err(|e| anyhow::anyhow!("wait served-{i:04}: {e}"))?;
        evaluations += outcome.evaluations.len();
        if outcome.status != amt::workflow::ExecutionStatus::Succeeded {
            failed += 1;
        }
    }
    let wall = started.elapsed().as_secs_f64();
    let worker_count = pool.worker_count();
    println!(
        "{jobs} tuning jobs ({evaluations} evaluations) over {worker_count} remote workers \
         in {wall:.1}s — {:.1} jobs/s, {failed} failed ({} joined mid-run)",
        jobs as f64 / wall,
        pool.joins()
    );
    print_serve_telemetry(&service);
    Ok(())
}

/// One telemetry line per subsystem at `amt serve` shutdown: the fleet
/// counters, repair/recovery work, WAL commit stats and store traffic
/// that previously only surfaced in tests.
fn print_serve_telemetry(service: &AmtService) {
    let snap = service.telemetry_snapshot();
    let c = |name: &str| snap.counter(name).unwrap_or(0);
    eprintln!(
        "leader: polls_dispatched={} slice_messages={} joins={} drains={} steals={}",
        c("leader.polls_dispatched"),
        c("leader.slice_messages"),
        c("leader.joins"),
        c("leader.drains"),
        c("leader.steals"),
    );
    eprintln!(
        "repair: snapshot_requeues={} scratch_requeues={} replayed_proposals={}",
        c("leader.snapshot_requeues"),
        c("leader.scratch_requeues"),
        c("leader.replayed_proposals"),
    );
    eprintln!(
        "recovery: fast_resumed={} scratch_resumed={} replayed_proposals={}",
        c("recovery.fast_resumed"),
        c("recovery.scratch_resumed"),
        c("recovery.replayed_proposals"),
    );
    eprintln!(
        "wal: commits={} coalesced={} commit_errors={}",
        c("wal.commits"),
        c("wal.coalesced"),
        c("leader.wal_commit_errors") + c("scheduler.wal_commit_errors"),
    );
    eprintln!(
        "store: writes={} shard_lock_acquisitions={}",
        c("store.writes"),
        c("store.shard_lock_acquisitions"),
    );
    if let Some(rtt) = snap.histogram("leader.rtt_us") {
        eprintln!(
            "rtt: n={} p50={}µs p99={}µs max={}µs",
            rtt.count, rtt.p50, rtt.p99, rtt.max
        );
    }
}

/// `amt stats`: run a short tuning spike — purely in-process by default,
/// or over a `--distributed N` loopback worker fleet — then print the
/// service's full telemetry snapshot (DESIGN.md §15). `--json` emits the
/// same snapshot as one JSON object for scripting.
fn cmd_stats(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use amt::distributed::worker::spawn_loopback_worker;
    let jobs: usize = flag(flags, "jobs", "4").parse()?;
    let distributed: usize = flag(flags, "distributed", "0").parse()?;
    let json = flags.contains_key("json");
    let service = if distributed > 0 {
        let mut transports = Vec::new();
        for i in 0..distributed {
            let (transport, _fault, _handle) = spawn_loopback_worker(&format!("stats-w{i}"));
            transports.push(transport);
        }
        AmtService::with_remote_workers(PlatformConfig::default(), transports)
    } else {
        AmtService::new(PlatformConfig::default())
    };
    for i in 0..jobs {
        let request = TuningJobRequest {
            name: format!("stats-{i:03}"),
            objective: flag(flags, "objective", "branin").to_string(),
            strategy: "random".into(),
            max_training_jobs: flag(flags, "max-jobs", "4").parse()?,
            max_parallel_jobs: 2,
            seed: i as u64,
            ..Default::default()
        };
        service
            .create_tuning_job(request)
            .map_err(|e| anyhow::anyhow!("create stats-{i:03}: {e}"))?;
    }
    for i in 0..jobs {
        service
            .wait(&format!("stats-{i:03}"))
            .map_err(|e| anyhow::anyhow!("wait stats-{i:03}: {e}"))?;
    }
    let snap = service.telemetry_snapshot();
    if json {
        println!("{}", snap.to_json().to_string());
    } else {
        print!("{}", snap.render_table());
    }
    Ok(())
}

/// `amt trace [job]`: run one tuning job over an in-process loopback
/// worker fleet and print its reconstructed slice lifecycle from the
/// trace ring — each phase with absolute time since the first event and
/// the delta from the previous phase.
fn cmd_trace(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use amt::distributed::worker::spawn_loopback_worker;
    let job = pos.get(1).map(String::as_str).unwrap_or("trace-demo").to_string();
    let workers: usize = flag(flags, "workers", "2").parse()?;
    let mut transports = Vec::new();
    for i in 0..workers {
        let (transport, _fault, _handle) = spawn_loopback_worker(&format!("trace-w{i}"));
        transports.push(transport);
    }
    let service = AmtService::with_remote_workers(PlatformConfig::default(), transports);
    let request = TuningJobRequest {
        name: job.clone(),
        objective: flag(flags, "objective", "branin").to_string(),
        strategy: "random".into(),
        max_training_jobs: flag(flags, "max-jobs", "4").parse()?,
        max_parallel_jobs: 2,
        seed: flag(flags, "seed", "0").parse()?,
        ..Default::default()
    };
    service
        .create_tuning_job(request)
        .map_err(|e| anyhow::anyhow!("create {job}: {e}"))?;
    service.wait(&job).map_err(|e| anyhow::anyhow!("wait {job}: {e}"))?;
    let events = service.traces_for(&job);
    anyhow::ensure!(
        !events.is_empty(),
        "no trace events recorded for '{job}' (telemetry disabled or sampled out?)"
    );
    println!(
        "trace {:#018x} — job '{job}' ({} events)",
        events[0].trace_id,
        events.len()
    );
    let t0 = events[0].t_us;
    let mut prev = t0;
    for ev in &events {
        println!(
            "  +{:>9}µs  (Δ{:>8}µs)  {}",
            ev.t_us - t0,
            ev.t_us - prev,
            ev.phase
        );
        prev = ev.t_us;
    }
    Ok(())
}

fn cmd_load(pos: &[String], flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use amt::load::{Runner, Workload};
    let seed: u64 = flag(flags, "seed", "42").parse()?;
    let scale: u32 = flag(flags, "scale", "1").parse()?;
    let workload = if flags.contains_key("canned") || flags.contains_key("print-canned") {
        Workload::canned_mixed("cli-load", seed, scale)
    } else if let Some(path) = pos.get(1) {
        Workload::from_json_str(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?
    } else {
        anyhow::bail!("usage: amt load <workload.json> | amt load --canned");
    };
    if flags.contains_key("print-canned") {
        println!("{}", workload.to_json().to_pretty());
        return Ok(());
    }
    let mut runner = Runner::new(workload).map_err(|e| anyhow::anyhow!("workload: {e}"))?;
    let every: u64 = flag(flags, "report-every", "5").parse()?;
    runner.set_report_every(
        (every > 0).then(|| std::time::Duration::from_secs(every)),
    );
    let report = runner.run().map_err(|e| anyhow::anyhow!("load run: {e}"))?;
    if flags.contains_key("json") {
        println!("{}", report.to_json().to_pretty());
    } else {
        print!("{}", report.render());
    }
    anyhow::ensure!(
        report.all_passed(),
        "invariant observers FAILED:\n{}",
        report
            .observers
            .failed()
            .iter()
            .map(|c| format!("  {}: {}", c.name, c.detail))
            .collect::<Vec<_>>()
            .join("\n")
    );
    Ok(())
}

fn cmd_snapshot(path: &str) -> anyhow::Result<()> {
    let service = AmtService::new(PlatformConfig::default());
    let request = TuningJobRequest {
        name: "snapshot-demo".into(),
        objective: "branin".into(),
        strategy: "random".into(),
        max_training_jobs: 5,
        ..Default::default()
    };
    let name = service.create_tuning_job(request).map_err(|e| anyhow::anyhow!("{e}"))?;
    service.wait(&name).map_err(|e| anyhow::anyhow!("{e}"))?;
    std::fs::write(path, service.store().snapshot())?;
    println!("metadata-store snapshot written to {path}");
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "tune" => cmd_tune(&flags),
        "objectives" => {
            cmd_objectives();
            Ok(())
        }
        "artifacts-check" => {
            cmd_artifacts_check(pos.get(1).map(String::as_str).unwrap_or("artifacts"))
        }
        "snapshot" => cmd_snapshot(pos.get(1).map(String::as_str).unwrap_or("store.json")),
        "worker" => cmd_worker(&flags),
        "serve" => cmd_serve(&flags),
        "stats" => cmd_stats(&flags),
        "trace" => cmd_trace(&pos, &flags),
        "load" => cmd_load(&pos, &flags),
        _ => {
            println!(
                "usage: amt <tune|objectives|artifacts-check|snapshot|worker|serve|stats|trace|load> \
                 [--flags]\n\
                 see module docs in rust/src/main.rs"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
