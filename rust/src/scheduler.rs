//! Multi-tenant tuning scheduler (§3.2, §6.5): N tuning jobs multiplexed
//! over a bounded worker pool.
//!
//! The paper's AMT is a fully managed multi-tenant service that absorbs
//! "spikes of many hundreds of tuning jobs" while keeping the synchronous
//! APIs ≥ 99.99% available. This module is the execution substrate that
//! makes the reproduction behave the same way: instead of one dedicated OS
//! thread per tuning job busy-spinning its own workflow, a fixed
//! [`WorkerPool`] of M ≈ num_cpus threads drains a **virtual-time event
//! heap** of runnable [`JobActor`]s.
//!
//! Mechanics:
//!
//! * every submitted job owns one heap entry at a time, keyed by
//!   `(virtual due time ÷ tenant weight, sequence)` — parked executions
//!   (retry backoffs, `Wait` transitions) re-enter ordered behind
//!   less-advanced jobs, which keeps a spike of late arrivals from
//!   starving early ones. The tenant weight (from
//!   `CreateHyperParameterTuningJob`'s `tenant_weight`, default 1) is a
//!   fair-share multiplier: a weight-w job's virtual time is discounted
//!   w×, so under contention it drains ~w× the poll slices of a weight-1
//!   peer (Autotune-style weighted fair queueing); weight 1 divides by
//!   1.0 exactly, so single-weight workloads order identically to the
//!   unweighted scheduler;
//! * a worker pops the earliest entry, polls the actor for a bounded batch
//!   of state-machine steps ([`SchedulerConfig::batch_steps`]), then either
//!   re-queues it (still pending) or publishes its outcome and wakes
//!   waiters on the job's **own** condvar — `wait()` never holds a global
//!   lock while blocking, so one caller waiting on a slow job cannot stall
//!   Create/Describe/Stop traffic for other tenants;
//! * `stop()` only flips the job's shared stop flag (the workflow observes
//!   it at its next scheduling point), and `Describe` never touches the
//!   scheduler at all — it reads the metadata store.
//!
//! Virtual due times never require real sleeping: each tuning job runs on
//! its own discrete-event platform timeline, so the heap is purely an
//! ordering structure (fairness across tenants), not a timer wheel.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::coordinator::{ActorPoll, JobActor, TuningJobOutcome};
use crate::durability::wal::Wal;
use crate::parallel::{self, WorkerPool};

/// Scheduler tuning knobs.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Worker threads in the pool (default: the machine's parallelism,
    /// i.e. `parallel::max_threads()`, capped at 16).
    pub workers: usize,
    /// Max state-machine steps (≈ platform events) per poll slice before a
    /// job is re-queued so its peers get a turn.
    pub batch_steps: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { workers: parallel::max_threads().min(16), batch_steps: 256 }
    }
}

/// One entry of the virtual-time event heap. Min-ordered by
/// `(due ÷ tenant weight, seq)` via `Reverse` in the heap — `due` here is
/// already weight-discounted by [`push_entry`]. Shared with the
/// distributed plane's per-worker heaps ([`crate::distributed::leader`]),
/// which order jobs by exactly the same key.
pub(crate) struct QueueEntry {
    pub(crate) due: f64,
    pub(crate) seq: u64,
    pub(crate) name: String,
}

impl PartialEq for QueueEntry {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for QueueEntry {}
impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.due.total_cmp(&other.due).then(self.seq.cmp(&other.seq))
    }
}

#[derive(Default)]
struct TenantState {
    /// Concurrent-slice cap (0 = unlimited, accounting only).
    limit: usize,
    /// Poll slices currently running for this tenant.
    in_flight: usize,
    /// Max of `in_flight` ever observed (the contention test's probe).
    high_water: usize,
    /// Entries parked at quota, released in `(due, seq)` order.
    deferred: Vec<QueueEntry>,
}

/// Per-tenant in-flight quota accounting, shared by the in-process
/// scheduler and the distributed leader. A tenant with `max_in_flight`
/// q on its requests never has more than q poll slices running at once
/// across the whole pool: an entry popped while the tenant is at quota
/// is parked here and handed back when a running slice finishes. All
/// operations are atomic under one internal mutex (always a leaf lock).
pub(crate) struct TenantQuotas {
    map: Mutex<HashMap<String, TenantState>>,
}

impl TenantQuotas {
    pub(crate) fn new() -> TenantQuotas {
        TenantQuotas { map: Mutex::new(HashMap::new()) }
    }

    /// Try to start a slice for `tenant` (cap `limit`; 0 = unlimited).
    /// Returns the entry back on success; parks it and returns `None`
    /// when the tenant is at quota. The decision and the parking are one
    /// atomic step, so a concurrent release cannot strand the entry.
    pub(crate) fn acquire(
        &self,
        tenant: &str,
        limit: usize,
        entry: QueueEntry,
    ) -> Option<QueueEntry> {
        let mut map = self.map.lock().unwrap();
        let state = map.entry(tenant.to_string()).or_default();
        if limit > 0 {
            state.limit = limit;
        }
        if state.limit > 0 && state.in_flight >= state.limit {
            state.deferred.push(entry);
            return None;
        }
        state.in_flight += 1;
        state.high_water = state.high_water.max(state.in_flight);
        Some(entry)
    }

    /// Finish a slice for `tenant`; returns the earliest-due parked
    /// entry (now admissible) for the caller to requeue, if any.
    pub(crate) fn release(&self, tenant: &str) -> Option<QueueEntry> {
        let mut map = self.map.lock().unwrap();
        let state = map.get_mut(tenant)?;
        state.in_flight = state.in_flight.saturating_sub(1);
        if state.deferred.is_empty() {
            return None;
        }
        let mut best = 0;
        for i in 1..state.deferred.len() {
            let (a, b) = (&state.deferred[i], &state.deferred[best]);
            if a.due.total_cmp(&b.due).then(a.seq.cmp(&b.seq)).is_lt() {
                best = i;
            }
        }
        Some(state.deferred.swap_remove(best))
    }

    /// Highest concurrent slice count this tenant ever reached.
    pub(crate) fn high_water(&self, tenant: &str) -> usize {
        self.map.lock().unwrap().get(tenant).map(|s| s.high_water).unwrap_or(0)
    }
}

/// Terminal state published by a worker.
#[derive(Default)]
struct SlotState {
    outcome: Option<TuningJobOutcome>,
    panicked: bool,
}

/// Per-job slot: the actor (while running) and its published outcome.
/// Lock order is always `actor` before `state`; the registry lock is never
/// held while either is taken for a blocking wait.
struct JobSlot {
    actor: Mutex<Option<JobActor>>,
    state: Mutex<SlotState>,
    done_cv: Condvar,
    stop_flag: Arc<AtomicBool>,
    /// Fair-share weight (≥ 1): heap entries are keyed by `due / weight`.
    weight: f64,
    /// `(tenant, max_in_flight)` when the request named a tenant — the
    /// in-flight quota key. `None` jobs skip quota accounting entirely
    /// (the legacy path, bit-identical ordering).
    quota: Option<(String, usize)>,
    /// Poll slices this job has received (fair-share observability).
    polls: AtomicU64,
}

struct Inner {
    /// Virtual-time event heap of runnable jobs (one entry per live job).
    heap: Mutex<BinaryHeap<Reverse<QueueEntry>>>,
    heap_cv: Condvar,
    /// Registry of all submitted jobs (kept after completion for wait()).
    jobs: Mutex<HashMap<String, Arc<JobSlot>>>,
    shutdown: AtomicBool,
    seq: AtomicU64,
    batch_steps: usize,
    running: AtomicUsize,
    /// Durability log: workers group-commit it at every heap-drain
    /// boundary (one fsync per poll slice, covering every record the
    /// slice appended), and commit *before* publishing an outcome so a
    /// waiter normally never observes a completion the WAL hasn't made
    /// durable. A failed commit keeps its records buffered in the WAL
    /// (retried at the next tick), is retried once immediately, and is
    /// counted in `wal_commit_errors` — the outcome is still published,
    /// so the invariant is best-effort under disk errors; monitor the
    /// counter.
    wal: OnceLock<Arc<Wal>>,
    /// This scheduler's metric registry (per-instance — tests assert
    /// exact counts on isolated schedulers). Counter/histogram fields
    /// below are cached handles into it, under `scheduler.*` names.
    telemetry: crate::telemetry::Registry,
    /// Registry name: `scheduler.wal_commit_errors`.
    wal_commit_errors: Arc<crate::telemetry::Counter>,
    /// Poll slices dispatched across all jobs — the pool-wide
    /// aggregate of every slot's `polls` (previously unnamed; the
    /// remote plane's counterpart is `leader.polls_dispatched`).
    /// Registry name: `scheduler.polls_dispatched`.
    polls_dispatched: Arc<crate::telemetry::Counter>,
    /// Wall-clock latency of one `JobActor::poll` slice (µs).
    /// Registry name: `scheduler.poll_slice_us`.
    poll_slice_us: Arc<crate::telemetry::Histogram>,
    /// Per-tenant in-flight quota accounting (`max_in_flight`).
    quotas: TenantQuotas,
    /// Invoked after every *successful* WAL group commit — the durable
    /// service installs its auto-checkpoint trigger here
    /// (`DurabilityOptions::auto_checkpoint_bytes`). Runs on the
    /// committing worker thread with no scheduler locks held.
    post_commit: OnceLock<Arc<dyn Fn() + Send + Sync>>,
}

/// The multi-tenant tuning scheduler.
pub struct Scheduler {
    inner: Arc<Inner>,
    pool: Option<WorkerPool>,
    workers: usize,
}

impl Scheduler {
    /// Start the worker pool.
    pub fn new(config: SchedulerConfig) -> Scheduler {
        let workers = config.workers.max(1);
        let reg = crate::telemetry::Registry::new();
        let inner = Arc::new(Inner {
            heap: Mutex::new(BinaryHeap::new()),
            heap_cv: Condvar::new(),
            jobs: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            batch_steps: config.batch_steps.max(1),
            running: AtomicUsize::new(0),
            wal: OnceLock::new(),
            wal_commit_errors: reg.counter("scheduler.wal_commit_errors"),
            polls_dispatched: reg.counter("scheduler.polls_dispatched"),
            poll_slice_us: reg.histogram("scheduler.poll_slice_us"),
            telemetry: reg,
            quotas: TenantQuotas::new(),
            post_commit: OnceLock::new(),
        });
        let worker_inner = Arc::clone(&inner);
        let pool = WorkerPool::spawn("amt-sched", workers, move |_worker| {
            worker_loop(&worker_inner);
        });
        Scheduler { inner, pool: Some(pool), workers }
    }

    /// Number of pool workers (fixed for the scheduler's lifetime).
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    /// Attach the durability WAL: workers group-commit it at heap-drain
    /// boundaries, and every actor registered from now on checkpoints to
    /// it. At most one WAL can ever be attached (later calls no-op).
    pub fn set_wal(&self, wal: Arc<Wal>) {
        let _ = self.inner.wal.set(wal);
    }

    /// WAL group commits that failed even after a retry (records stay
    /// buffered and retry at later ticks; a crash before a successful
    /// commit loses them — alert on this counter). Shim over registry
    /// metric `scheduler.wal_commit_errors`; prefer
    /// [`Scheduler::telemetry_metrics`].
    pub fn wal_commit_errors(&self) -> u64 {
        self.inner.wal_commit_errors.get()
    }

    /// Poll slices dispatched across all jobs since construction — the
    /// pool-wide denominator matching
    /// `RemoteWorkerPool::polls_dispatched` on the remote plane. Shim
    /// over registry metric `scheduler.polls_dispatched`.
    pub fn polls_dispatched(&self) -> u64 {
        self.inner.polls_dispatched.get()
    }

    /// Point-in-time snapshot of this scheduler's metric registry
    /// (names under `scheduler.*`, including the
    /// `scheduler.poll_slice_us` latency histogram) — one part of
    /// [`crate::api::AmtService::telemetry_snapshot`].
    pub fn telemetry_metrics(&self) -> Vec<crate::telemetry::MetricSnapshot> {
        self.inner.telemetry.snapshot()
    }

    /// Install a hook invoked after every successful WAL group commit
    /// (no scheduler locks held). At most one hook can ever be installed
    /// (later calls no-op). The durable API layer uses this for
    /// size-triggered automatic checkpoints.
    pub fn set_post_commit(&self, hook: Arc<dyn Fn() + Send + Sync>) {
        let _ = self.inner.post_commit.set(hook);
    }

    /// Highest number of poll slices the named tenant ever held
    /// concurrently — the observable the `max_in_flight` quota bounds
    /// (always ≤ the quota for tenants that set one).
    pub fn tenant_high_water(&self, tenant: &str) -> usize {
        self.inner.quotas.high_water(tenant)
    }

    /// Poll slices the named job has received so far (`None` for unknown
    /// names) — the fair-share accounting the weighted heap key acts on.
    pub fn poll_count(&self, name: &str) -> Option<u64> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        Some(slot.polls.load(Ordering::Relaxed))
    }

    /// Jobs submitted and not yet finished.
    pub fn running_jobs(&self) -> usize {
        self.inner.running.load(Ordering::Relaxed)
    }

    /// True if a job with this name was ever submitted.
    pub fn contains(&self, name: &str) -> bool {
        self.inner.jobs.lock().unwrap().contains_key(name)
    }

    /// Atomically reserve a job name and park its actor, *without*
    /// queueing it for execution yet. Returns false (and drops the actor)
    /// if the name is already taken. The API layer reserves first, then
    /// persists the accepted request to the store, then [`Scheduler::activate`]s —
    /// so a losing concurrent create never touches the store, and no
    /// worker can run (and finish) the job before its record is persisted.
    pub fn register(&self, mut actor: JobActor, stop_flag: Arc<AtomicBool>) -> bool {
        if let Some(wal) = self.inner.wal.get() {
            actor.set_wal(Arc::clone(wal));
        }
        let name = actor.name().to_string();
        let weight = actor.tenant_weight().max(1) as f64;
        let quota = if actor.tenant().is_empty() {
            None
        } else {
            Some((actor.tenant().to_string(), actor.max_in_flight() as usize))
        };
        {
            let mut jobs = self.inner.jobs.lock().unwrap();
            if jobs.contains_key(&name) {
                return false;
            }
            jobs.insert(
                name,
                Arc::new(JobSlot {
                    actor: Mutex::new(Some(actor)),
                    state: Mutex::new(SlotState::default()),
                    done_cv: Condvar::new(),
                    stop_flag,
                    weight,
                    quota,
                    polls: AtomicU64::new(0),
                }),
            );
        }
        self.inner.running.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Queue a previously [`Scheduler::register`]ed job onto the event
    /// heap. Must be called exactly once per registered job.
    pub fn activate(&self, name: &str) {
        self.activate_at(name, 0.0);
    }

    /// Queue a registered job at an explicit virtual due time. Jobs
    /// resumed from a [`crate::coordinator::ResumeSnapshot`] re-enter
    /// here at their checkpoint's clock ([`JobActor::due`]) instead of
    /// `begin()`-style time zero, so a half-finished recovered job does
    /// not jump the fair-share queue ahead of less-advanced peers.
    pub fn activate_at(&self, name: &str, due: f64) {
        let weight = {
            self.inner.jobs.lock().unwrap().get(name).map(|s| s.weight).unwrap_or(1.0)
        };
        push_entry(&self.inner, due.max(0.0), weight, name.to_string());
    }

    /// Reserve and immediately queue a job actor (`register` + `activate`).
    /// Returns false (and drops the actor) if the name is already taken.
    pub fn submit(&self, actor: JobActor, stop_flag: Arc<AtomicBool>) -> bool {
        let name = actor.name().to_string();
        if !self.register(actor, stop_flag) {
            return false;
        }
        self.activate(&name);
        true
    }

    /// Signal a job to stop at its next scheduling point. Returns false
    /// for unknown names; true for known jobs, running or finished.
    pub fn stop(&self, name: &str) -> bool {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() };
        match slot {
            Some(slot) => {
                slot.stop_flag.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Block until the named job finishes; `None` for unknown names.
    ///
    /// The registry lock is released before blocking (each job has its own
    /// condvar), so concurrent Create/Stop/wait calls on other jobs are
    /// never serialized behind this one.
    pub fn wait(&self, name: &str) -> Option<TuningJobOutcome> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        let mut state = slot.state.lock().unwrap();
        while state.outcome.is_none() && !state.panicked {
            state = slot.done_cv.wait(state).unwrap();
        }
        if state.panicked {
            // surface worker panics like the old thread-join path did
            panic!("tuning workflow panicked");
        }
        state.outcome.clone()
    }

    /// Non-blocking probe for a finished outcome.
    pub fn try_outcome(&self, name: &str) -> Option<TuningJobOutcome> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        let state = slot.state.lock().unwrap();
        state.outcome.clone()
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        // set the predicate under the heap mutex: a worker between its
        // shutdown check and cv.wait holds that mutex, so this store
        // cannot interleave there (no lost wakeup)
        {
            let _guard = self.inner.heap.lock().unwrap();
            self.inner.shutdown.store(true, Ordering::SeqCst);
        }
        self.inner.heap_cv.notify_all();
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
    }
}

/// Allocate a sequence number and queue `(due / weight, seq, name)` on
/// the event heap — the single queueing path shared by submit/activate
/// and the worker re-queue, so ordering rules live in one place. The
/// weight discount implements fair-share scheduling: a weight-w tenant's
/// virtual time counts 1/w, so it is popped ~w× as often under
/// contention (weight 1.0 divides exactly ⇒ unweighted ordering).
fn push_entry(inner: &Inner, due: f64, weight: f64, name: String) {
    let seq = inner.seq.fetch_add(1, Ordering::Relaxed);
    let due = due / weight.max(1.0);
    inner.heap.lock().unwrap().push(Reverse(QueueEntry { due, seq, name }));
    inner.heap_cv.notify_one();
}

/// Group-commit the WAL (if attached) through the shared
/// retry-once-and-count helper ([`crate::durability::commit_with_retry`]
/// — one discipline for both execution planes). Concurrent workers
/// committing at the same heap-drain boundary coalesce into one
/// `write`+`fsync` inside the WAL itself.
fn commit_wal(inner: &Inner) {
    if let Some(wal) = inner.wal.get() {
        crate::durability::commit_with_retry(
            wal,
            inner.wal_commit_errors.as_atomic(),
            inner.post_commit.get(),
        );
    }
}

/// Finish a quota-accounted slice: release the tenant slot and requeue
/// the earliest parked entry of that tenant, if one was waiting. The
/// entry keeps its original (already weight-discounted) due and seq, so
/// it re-enters exactly where the quota paused it.
fn release_quota(inner: &Inner, slot: &JobSlot) {
    if let Some((tenant, _)) = &slot.quota {
        if let Some(entry) = inner.quotas.release(tenant) {
            inner.heap.lock().unwrap().push(Reverse(entry));
            inner.heap_cv.notify_one();
        }
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        // pop the earliest-due entry, or sleep until one arrives
        let entry = {
            let mut heap = inner.heap.lock().unwrap();
            loop {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(Reverse(e)) = heap.pop() {
                    break e;
                }
                heap = inner.heap_cv.wait(heap).unwrap();
            }
        };
        let slot = { inner.jobs.lock().unwrap().get(&entry.name).cloned() };
        let Some(slot) = slot else { continue };

        // tenant in-flight quota gate: a tenant at its `max_in_flight`
        // parks the entry; a finishing slice of that tenant requeues it
        if let Some((tenant, limit)) = &slot.quota {
            let admitted = inner.quotas.acquire(
                tenant,
                *limit,
                QueueEntry { due: entry.due, seq: entry.seq, name: entry.name.clone() },
            );
            if admitted.is_none() {
                continue;
            }
        }

        // poll a bounded slice; the actor mutex is per-job, so workers on
        // other jobs are untouched. catch_unwind keeps one poisonous job
        // from taking the whole pool down (§3.3 robustness).
        let mut actor_guard = slot.actor.lock().unwrap();
        let Some(actor) = actor_guard.as_mut() else {
            release_quota(inner, &slot);
            continue;
        };
        slot.polls.fetch_add(1, Ordering::Relaxed);
        inner.polls_dispatched.inc();
        let slice_t0 = crate::telemetry::enabled().then(std::time::Instant::now);
        let polled = std::panic::catch_unwind(AssertUnwindSafe(|| {
            actor.poll(inner.batch_steps)
        }));
        if let Some(t0) = slice_t0 {
            inner.poll_slice_us.record_duration(t0.elapsed());
        }
        match polled {
            Ok(ActorPoll::Pending { due }) => {
                // idle tail (DESIGN.md §17): pipelined jobs pre-compute the
                // next proposal here — after the slice's timing window
                // closed, so speculation never inflates
                // `scheduler.poll_slice_us` — and before the requeue, so the
                // strategy state it advances lands in the next slice's
                // checkpoint.
                let _ = std::panic::catch_unwind(AssertUnwindSafe(|| {
                    actor.speculate_step()
                }));
                drop(actor_guard);
                push_entry(inner, due, slot.weight, entry.name);
                release_quota(inner, &slot);
                // group commit: one fsync covers every record this poll
                // slice appended (store puts, metric emits, checkpoint)
                commit_wal(inner);
            }
            Ok(ActorPoll::Complete(outcome)) => {
                *actor_guard = None; // release strategy/platform resources
                drop(actor_guard);
                release_quota(inner, &slot);
                // durability before acknowledgment: the terminal store
                // records must be on disk before any waiter can observe
                // the outcome (best-effort under disk errors — see
                // `Inner::wal`)
                commit_wal(inner);
                let mut state = slot.state.lock().unwrap();
                // decrement before publishing: a waiter that observes the
                // outcome must never still see this job in running_jobs()
                inner.running.fetch_sub(1, Ordering::Relaxed);
                state.outcome = Some(*outcome);
                drop(state);
                slot.done_cv.notify_all();
            }
            Err(_) => {
                *actor_guard = None;
                drop(actor_guard);
                release_quota(inner, &slot);
                commit_wal(inner);
                let mut state = slot.state.lock().unwrap();
                inner.running.fetch_sub(1, Ordering::Relaxed);
                state.panicked = true;
                drop(state);
                slot.done_cv.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TuningJobRequest;
    use crate::coordinator::stopping_by_name;
    use crate::gp::NativeBackend;
    use crate::metrics::MetricsService;
    use crate::objectives::Objective;
    use crate::platform::{PlatformConfig, TrainingPlatform};
    use crate::store::MetadataStore;

    fn actor(name: &str, evals: u32, seed: u64, stop_flag: Arc<AtomicBool>) -> JobActor {
        actor_with_weight(name, evals, seed, 1, stop_flag)
    }

    fn actor_with_weight(
        name: &str,
        evals: u32,
        seed: u64,
        weight: u32,
        stop_flag: Arc<AtomicBool>,
    ) -> JobActor {
        actor_from_request(
            TuningJobRequest {
                name: name.into(),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: evals,
                max_parallel_jobs: 2,
                seed,
                tenant_weight: weight,
                ..Default::default()
            },
            stop_flag,
        )
    }

    fn actor_from_request(request: TuningJobRequest, stop_flag: Arc<AtomicBool>) -> JobActor {
        let seed = request.seed;
        let objective: Arc<dyn Objective> =
            crate::objectives::by_name("branin").unwrap().into();
        let strategy = crate::strategies::by_name(
            "random",
            &objective.space(),
            Arc::new(NativeBackend),
            seed,
        )
        .unwrap();
        JobActor::new(
            request,
            objective,
            strategy,
            stopping_by_name("off").unwrap(),
            TrainingPlatform::new(PlatformConfig::noiseless(), seed),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            stop_flag,
        )
    }

    #[test]
    fn jobs_complete_through_the_pool() {
        let sched = Scheduler::new(SchedulerConfig { workers: 2, batch_steps: 16 });
        for i in 0..8u64 {
            let flag = Arc::new(AtomicBool::new(false));
            assert!(sched.submit(actor(&format!("s-{i}"), 3, i, Arc::clone(&flag)), flag));
        }
        for i in 0..8u64 {
            let out = sched.wait(&format!("s-{i}")).unwrap();
            assert_eq!(out.evaluations.len(), 3);
        }
        assert_eq!(sched.running_jobs(), 0);
        assert_eq!(sched.worker_count(), 2);
    }

    #[test]
    fn duplicate_submissions_rejected() {
        let sched = Scheduler::new(SchedulerConfig { workers: 1, batch_steps: 64 });
        let f1 = Arc::new(AtomicBool::new(false));
        let f2 = Arc::new(AtomicBool::new(false));
        assert!(sched.submit(actor("dup", 2, 1, Arc::clone(&f1)), f1));
        assert!(!sched.submit(actor("dup", 2, 2, Arc::clone(&f2)), f2));
        assert!(sched.wait("dup").is_some());
    }

    #[test]
    fn wait_on_unknown_job_is_none() {
        let sched = Scheduler::new(SchedulerConfig::default());
        assert!(sched.wait("ghost").is_none());
        assert!(sched.try_outcome("ghost").is_none());
        assert!(!sched.stop("ghost"));
    }

    #[test]
    fn stop_flag_reaches_the_actor() {
        let sched = Scheduler::new(SchedulerConfig { workers: 1, batch_steps: 8 });
        let flag = Arc::new(AtomicBool::new(false));
        assert!(sched.submit(actor("stoppable", 10_000, 3, Arc::clone(&flag)), flag));
        assert!(sched.stop("stoppable"));
        let out = sched.wait("stoppable").unwrap();
        assert!(out.evaluations.len() < 10_000);
    }

    /// Fair-share: with one worker under contention, a weight-2 tenant
    /// should drain ~2× the poll slices of a weight-1 tenant running the
    /// same workload (the heap discounts its virtual time 2×).
    #[test]
    fn weighted_tenant_drains_proportionally_more_polls() {
        let sched = Scheduler::new(SchedulerConfig { workers: 1, batch_steps: 4 });
        let fh = Arc::new(AtomicBool::new(false));
        let fl = Arc::new(AtomicBool::new(false));
        assert!(sched.submit(
            actor_with_weight("heavy", 5000, 9, 2, Arc::clone(&fh)),
            Arc::clone(&fh)
        ));
        assert!(sched.submit(
            actor_with_weight("light", 5000, 9, 1, Arc::clone(&fl)),
            Arc::clone(&fl)
        ));
        // sample both counters once enough slices accumulated
        let (h, l) = loop {
            let h = sched.poll_count("heavy").unwrap();
            let l = sched.poll_count("light").unwrap();
            if h + l >= 600 {
                break (h, l);
            }
            std::thread::yield_now();
        };
        sched.stop("heavy");
        sched.stop("light");
        sched.wait("heavy").unwrap();
        sched.wait("light").unwrap();
        let ratio = h as f64 / l.max(1) as f64;
        assert!(
            ratio > 1.4 && ratio < 3.0,
            "heavy/light poll ratio {ratio:.2} outside ~2x band (h={h}, l={l})"
        );
        assert!(sched.poll_count("ghost").is_none());
    }

    /// Per-tenant in-flight quota (`max_in_flight`): a quota-1 tenant
    /// never holds two pool workers at once, even with two runnable jobs
    /// and a spare worker — its second job parks until the first's slice
    /// finishes. A quota-less tenant on the same pool does overlap,
    /// proving the high-water probe would catch a breach.
    #[test]
    fn quota_one_tenant_never_holds_two_workers() {
        let sched = Scheduler::new(SchedulerConfig { workers: 3, batch_steps: 4 });
        let names = ["capped-a", "capped-b", "free-a", "free-b"];
        for (name, tenant, quota) in [
            ("capped-a", "capped", 1u32),
            ("capped-b", "capped", 1),
            ("free-a", "free", 0),
            ("free-b", "free", 0),
        ] {
            let flag = Arc::new(AtomicBool::new(false));
            let request = TuningJobRequest {
                name: name.into(),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 10_000,
                max_parallel_jobs: 2,
                seed: 5,
                tenant: tenant.into(),
                max_in_flight: quota,
                ..Default::default()
            };
            assert!(sched.submit(actor_from_request(request, Arc::clone(&flag)), flag));
        }
        // with at most one capped slice running, two of the three workers
        // are left for the two "free" jobs — wait for their overlap and
        // for both capped jobs to make progress under the quota
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        while sched.tenant_high_water("free") < 2
            || sched.poll_count("capped-a").unwrap() == 0
            || sched.poll_count("capped-b").unwrap() == 0
        {
            assert!(
                std::time::Instant::now() < deadline,
                "no overlap/progress: free hw {}, capped polls {}/{}",
                sched.tenant_high_water("free"),
                sched.poll_count("capped-a").unwrap(),
                sched.poll_count("capped-b").unwrap()
            );
            std::thread::yield_now();
        }
        for name in names {
            sched.stop(name);
        }
        for name in names {
            sched.wait(name).unwrap();
        }
        assert_eq!(
            sched.tenant_high_water("capped"),
            1,
            "quota-1 tenant held two workers"
        );
        assert!(sched.tenant_high_water("free") >= 2);
        assert_eq!(sched.tenant_high_water("ghost"), 0);
    }

    #[test]
    fn outcomes_identical_to_direct_runner() {
        // the same seeded job through the pool and run-to-completion
        let direct = crate::coordinator::TuningJobRunner::new(
            TuningJobRequest {
                name: "ref".into(),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: 5,
                max_parallel_jobs: 2,
                seed: 17,
                ..Default::default()
            },
            crate::objectives::by_name("branin").unwrap().into(),
            crate::strategies::by_name(
                "random",
                &crate::objectives::by_name("branin").unwrap().space(),
                Arc::new(NativeBackend),
                17,
            )
            .unwrap(),
            stopping_by_name("off").unwrap(),
            TrainingPlatform::new(PlatformConfig::noiseless(), 17),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        )
        .run();

        let sched = Scheduler::new(SchedulerConfig { workers: 3, batch_steps: 7 });
        let flag = Arc::new(AtomicBool::new(false));
        assert!(sched.submit(actor("ref", 5, 17, Arc::clone(&flag)), flag));
        let pooled = sched.wait("ref").unwrap();

        assert_eq!(direct.evaluations.len(), pooled.evaluations.len());
        for (a, b) in direct.evaluations.iter().zip(&pooled.evaluations) {
            assert_eq!(a.training_job_name, b.training_job_name);
            assert_eq!(a.config, b.config);
            assert_eq!(a.final_value, b.final_value);
            assert_eq!(a.ended_at.to_bits(), b.ended_at.to_bits());
        }
        assert_eq!(direct.total_seconds.to_bits(), pooled.total_seconds.to_bits());
    }
}
