//! Dense linear algebra for the native GP surrogate.
//!
//! The O(N³) part of GP inference (Cholesky factorization, triangular
//! solves) runs here in Rust: jax ≥ 0.5 lowers `linalg.cholesky` on CPU to a
//! LAPACK FFI custom-call that the pinned xla_extension 0.5.1 cannot
//! execute, so the coordinator factorizes natively and ships `K⁻¹` / `α` to
//! the AOT posterior/EI graphs (see DESIGN.md §1 "hot-path split").
//!
//! Matrices are row-major `f64`; sizes here are ≤ 512, so simple cache-aware
//! loops beat the overhead of pulling in a BLAS.

use std::fmt;

/// Row-major dense matrix.
#[derive(Clone, Default, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// Immutable row view.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    /// Matrix-matrix product (ikj loop order for cache friendliness).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Max |a - b| over entries (for tests / cross-checks).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
///
/// Returns `Err` with the failing pivot index if the matrix is not PD (the
/// BO engine treats that as a rejected GPHP sample).
pub fn cholesky(a: &Matrix) -> Result<Matrix, usize> {
    let mut l = a.clone();
    cholesky_in_place(&mut l)?;
    // zero the upper triangle (the in-place factorization leaves A there)
    let n = l.rows;
    for i in 0..n {
        for j in i + 1..n {
            l[(i, j)] = 0.0;
        }
    }
    Ok(l)
}

/// In-place Cholesky: overwrite the lower triangle of `a` with L.
///
/// The upper triangle is left untouched (it still holds A's entries), so
/// callers that only read the lower triangle — all triangular solves and
/// [`cho_logdet`] in this module — can use the result directly. This is
/// the zero-allocation factorization the slice-sampler NLL loop runs on a
/// [`crate::gp::GramScratch`]-owned buffer (~600 times per BO proposal).
pub fn cholesky_in_place(a: &mut Matrix) -> Result<(), usize> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    for i in 0..n {
        for j in 0..=i {
            // split borrows: the already-factorized prefixes of rows i and j
            let (s, ljj) = {
                let ri = &a.data[i * n..i * n + j];
                let rj = &a.data[j * n..j * n + j];
                (dot(ri, rj), a.data[j * n + j])
            };
            if i == j {
                let d = a.data[i * n + i] - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(i);
                }
                a.data[i * n + i] = d.sqrt();
            } else {
                a.data[i * n + j] = (a.data[i * n + j] - s) / ljj;
            }
        }
    }
    Ok(())
}

/// Extend a Cholesky factor by one row/column in O(N²).
///
/// Given L with L Lᵀ = K (n × n), the kernel column `k_new = k(x_new, X)`
/// and the diagonal value `k_diag = k(x_new, x_new) + noise + jitter`,
/// returns the (n+1) × (n+1) factor of the bordered matrix
/// `[[K, k_new], [k_newᵀ, k_diag]]` without refactorizing: the new row is
/// `w = L⁻¹ k_new` and the new pivot is `sqrt(k_diag − ‖w‖²)`. This is
/// what makes empirical-Bayes refits after each fresh observation O(N²)
/// instead of O(N³) (DESIGN.md §4).
pub fn chol_append_row(l: &Matrix, k_new: &[f64], k_diag: f64) -> Result<Matrix, usize> {
    let n = l.rows;
    assert_eq!(k_new.len(), n);
    let w = solve_lower(l, k_new);
    let d = k_diag - w.iter().map(|v| v * v).sum::<f64>();
    if d <= 0.0 || !d.is_finite() {
        return Err(n);
    }
    let m = n + 1;
    let mut out = Matrix::zeros(m, m);
    for i in 0..n {
        out.data[i * m..i * m + i + 1].copy_from_slice(&l.data[i * n..i * n + i + 1]);
    }
    out.data[n * m..n * m + n].copy_from_slice(&w);
    out[(n, n)] = d.sqrt();
    Ok(out)
}

/// Solve L x = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut x = b.to_vec();
    solve_lower_in_place(l, &mut x);
    x
}

/// Forward substitution into a caller-owned buffer (zero-allocation path).
/// `x` holds b on entry and the solution on exit.
pub fn solve_lower_in_place(l: &Matrix, x: &mut [f64]) {
    let n = l.rows;
    debug_assert_eq!(x.len(), n);
    for i in 0..n {
        let s = dot(&l.data[i * n..i * n + i], &x[..i]);
        x[i] = (x[i] - s) / l.data[i * n + i];
    }
}

/// Solve Lᵀ x = b for lower-triangular L (backward substitution).
///
/// Column-oriented (saxpy) form: once x[i] is final, its contribution is
/// subtracted from all earlier entries by streaming *row i* of L, which is
/// contiguous in the row-major layout — instead of gathering the strided
/// column L[k][i] per unknown. Same arithmetic, sequential memory access;
/// this is the backward-substitution half of every K⁻¹-column solve in
/// [`cho_inverse`].
pub fn solve_lower_transpose(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = b.to_vec();
    for i in (0..n).rev() {
        x[i] /= l.data[i * n + i];
        let xi = x[i];
        let row = &l.data[i * n..i * n + i];
        for (xk, &lik) in x[..i].iter_mut().zip(row) {
            *xk -= lik * xi;
        }
    }
    x
}

/// Solve K x = b given the Cholesky factor L of K.
pub fn cho_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    solve_lower_transpose(l, &solve_lower(l, b))
}

/// K⁻¹ from the Cholesky factor of K (column-by-column cho_solve of I).
pub fn cho_inverse(l: &Matrix) -> Matrix {
    let n = l.rows;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = cho_solve(l, &e);
        e[j] = 0.0;
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    // symmetrize against round-off
    for i in 0..n {
        for j in 0..i {
            let m = 0.5 * (inv[(i, j)] + inv[(j, i)]);
            inv[(i, j)] = m;
            inv[(j, i)] = m;
        }
    }
    inv
}

/// log det K = 2 Σ log L_ii, from the Cholesky factor.
pub fn cho_logdet(l: &Matrix) -> f64 {
    (0..l.rows).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut a = Matrix::zeros(n, n);
        for v in a.data.iter_mut() {
            *v = rng.normal();
        }
        // A Aᵀ + n I is SPD
        let mut s = a.matmul(&a.transpose());
        for i in 0..n {
            s[(i, i)] += n as f64;
        }
        s
    }

    #[test]
    fn cholesky_reconstructs() {
        for n in [1, 2, 5, 16, 64] {
            let a = random_spd(n, n as u64);
            let l = cholesky(&a).unwrap();
            let rec = l.matmul(&l.transpose());
            assert!(a.max_abs_diff(&rec) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        let mut a = Matrix::eye(3);
        a[(2, 2)] = -1.0;
        assert_eq!(cholesky(&a), Err(2));
    }

    #[test]
    fn cho_solve_solves() {
        let a = random_spd(20, 3);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..20).map(|i| (i as f64).sin()).collect();
        let x = cho_solve(&l, &b);
        let ax = a.matvec(&x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn cho_inverse_is_inverse() {
        let a = random_spd(12, 5);
        let l = cholesky(&a).unwrap();
        let inv = cho_inverse(&l);
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Matrix::eye(12)) < 1e-8);
    }

    #[test]
    fn logdet_matches_direct_for_diagonal() {
        let mut a = Matrix::eye(4);
        for i in 0..4 {
            a[(i, i)] = (i + 1) as f64;
        }
        let l = cholesky(&a).unwrap();
        let expect = (1.0f64 * 2.0 * 3.0 * 4.0).ln();
        assert!((cho_logdet(&l) - expect).abs() < 1e-12);
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let a = random_spd(8, 9);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..8).map(|i| i as f64 - 3.0).collect();
        let y = solve_lower(&l, &b);
        let ly = l.matvec(&y);
        for (u, v) in ly.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
        let z = solve_lower_transpose(&l, &b);
        let ltz = l.transpose().matvec(&z);
        for (u, v) in ltz.iter().zip(&b) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn cholesky_in_place_matches_out_of_place() {
        for n in [1usize, 3, 8, 33] {
            let a = random_spd(n, 100 + n as u64);
            let l = cholesky(&a).unwrap();
            let mut b = a.clone();
            cholesky_in_place(&mut b).unwrap();
            for i in 0..n {
                for j in 0..=i {
                    assert_eq!(l[(i, j)].to_bits(), b[(i, j)].to_bits(), "n={n} ({i},{j})");
                }
            }
            // upper triangle still holds A (documented contract)
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(b[(i, j)], a[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn chol_append_row_matches_full_factorization() {
        for n in [1usize, 4, 12, 40] {
            let big = random_spd(n + 1, 7 + n as u64);
            // principal n×n block, its factor, and the border column
            let mut small = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    small[(i, j)] = big[(i, j)];
                }
            }
            let l_small = cholesky(&small).unwrap();
            let col: Vec<f64> = (0..n).map(|i| big[(i, n)]).collect();
            let l_app = chol_append_row(&l_small, &col, big[(n, n)]).unwrap();
            let l_full = cholesky(&big).unwrap();
            assert!(l_full.max_abs_diff(&l_app) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn chol_append_row_rejects_non_pd_border() {
        let l = cholesky(&random_spd(5, 2)).unwrap();
        // a huge border column makes the Schur complement negative
        let col = vec![1e6; 5];
        assert!(chol_append_row(&l, &col, 1.0).is_err());
    }

    #[test]
    fn solve_lower_in_place_matches_allocating() {
        let a = random_spd(17, 21);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..17).map(|i| (i as f64 * 0.7).cos()).collect();
        let y = solve_lower(&l, &b);
        let mut z = b.clone();
        solve_lower_in_place(&l, &mut z);
        assert_eq!(y, z);
    }

    #[test]
    fn matmul_matches_manual() {
        let a = Matrix::from_rows(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_rows(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }
}
