//! Worker runtime: hosts [`JobActor`]s in a (potentially remote)
//! process and answers a leader's poll protocol.
//!
//! A worker owns no authoritative state. Each assigned job gets a
//! **fresh local store and metrics service** whose only purpose is to
//! absorb the actor's writes; both are wired to one shared *capture
//! WAL* whose group-commit buffer is never committed to disk — after
//! every poll slice the buffer is drained ([`Wal::take_buffer`]),
//! decoded, and shipped to the leader as ONE coalesced
//! [`Message::SliceResult`] carrying the slice's mutation records and
//! its verdict (pre-coalescing workers sent the same content as a
//! `StoreDelta` + `PollResult` pair, which leaders still accept).
//! Because the
//! store/metrics/actor append through exactly the code paths an
//! in-process job uses, the delta is the slice's mutation history in
//! faithful application order, and the leader re-applying it through
//! *its* store reproduces an in-process run bit-for-bit (values and
//! versions; property-tested in `rust/tests/distributed_integration.rs`).
//!
//! The runtime is single-threaded per leader connection — one poll at a
//! time — which is what makes a single shared capture WAL sufficient:
//! every drained buffer belongs entirely to the slice just polled.
//! Parallelism comes from running many workers, not threads per worker.
//!
//! Workers advertise their surrogate backend in the `Hello` and reject
//! assignments pinned to a different one; the leader routes each job
//! only to compatible lanes and the API layer falls back to local
//! execution when no compatible worker is live, so a mixed-backend
//! fleet stays bit-consistent.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use crate::config::TuningJobRequest;
use crate::coordinator::{stopping_by_name, ActorPoll, JobActor};
use crate::durability::wal::Wal;
use crate::gp::NativeBackend;
use crate::metrics::MetricsService;
use crate::objectives::by_name as objective_by_name;
use crate::platform::{PlatformConfig, TrainingPlatform};
use crate::store::MetadataStore;
use crate::strategies::{Observation, Strategy};

use super::proto::{Message, PollReply};
use super::transport::Transport;

/// Default heartbeat period for idle workers — a small fraction of the
/// leader's default 5s lease, so many beats must go missing before a
/// worker is declared dead.
pub const DEFAULT_HEARTBEAT: Duration = Duration::from_millis(500);

struct HostedJob {
    actor: JobActor,
    stop_flag: Arc<AtomicBool>,
    /// Telemetry trace id from the `Assign`, echoed on every
    /// `SliceResult` for this job (DESIGN.md §15).
    trace: Option<u64>,
    /// Keep the local sinks alive for the actor's lifetime.
    _store: Arc<MetadataStore>,
    _metrics: Arc<MetricsService>,
}

/// Build the actor for an assignment — the worker-side mirror of the
/// API layer's job construction (`AmtService::create_prepared`): same
/// strategy wiring, same seeds, same platform timeline, so the actor's
/// trajectory is bit-identical to the one the leader would have run.
fn build_actor(
    request: TuningJobRequest,
    platform: PlatformConfig,
    transfer: Vec<Observation>,
    store: Arc<MetadataStore>,
    metrics: Arc<MetricsService>,
    stop_flag: Arc<AtomicBool>,
) -> Result<JobActor, String> {
    if let Err(e) = request.validate_with_custom_objective() {
        return Err(format!("invalid request: {e}"));
    }
    let Some(objective) = objective_by_name(&request.objective) else {
        return Err(format!("unknown objective '{}'", request.objective));
    };
    let objective: Arc<dyn crate::objectives::Objective> = objective.into();
    // the same construction path the API layer uses (bit-identity
    // across planes depends on it)
    let strategy: Box<dyn Strategy> = crate::strategies::for_request(
        &request.strategy,
        &objective.space(),
        Arc::new(NativeBackend),
        request.seed,
        transfer,
    )
    .ok_or_else(|| format!("unknown strategy '{}'", request.strategy))?;
    let Some(stopping) = stopping_by_name(&request.early_stopping) else {
        return Err(format!("unknown early stopping '{}'", request.early_stopping));
    };
    let seed = request.seed;
    Ok(JobActor::new(
        request,
        objective,
        strategy,
        stopping,
        TrainingPlatform::new(platform, seed),
        store,
        metrics,
        stop_flag,
    ))
}

/// One worker session: hosts jobs for a single leader connection until
/// the leader drains it or the link dies.
pub struct WorkerRuntime {
    transport: Box<dyn Transport>,
    heartbeat: Duration,
    /// Capture WAL (never committed): drained into `StoreDelta`s.
    capture: Arc<Wal>,
    scratch: PathBuf,
    jobs: HashMap<String, HostedJob>,
    label: String,
    /// Surrogate backend this worker evaluates with, advertised in the
    /// `Hello` so the leader pins compatible jobs to this lane.
    backend: String,
    /// Poll slices served (diagnostics).
    pub polls_served: u64,
}

impl WorkerRuntime {
    /// New runtime over a connected transport, with the default
    /// heartbeat period and the native surrogate backend.
    pub fn new(transport: Box<dyn Transport>) -> std::io::Result<WorkerRuntime> {
        Self::with_heartbeat(transport, DEFAULT_HEARTBEAT)
    }

    /// New runtime with an explicit heartbeat period (tests shrink it).
    pub fn with_heartbeat(
        transport: Box<dyn Transport>,
        heartbeat: Duration,
    ) -> std::io::Result<WorkerRuntime> {
        Self::with_options(transport, heartbeat, "native")
    }

    /// New runtime with an explicit heartbeat and backend name. The
    /// compute itself always runs the native backend in this process;
    /// the name is the *compatibility contract* the worker advertises
    /// and enforces: assignments pinned to a different backend are
    /// rejected rather than silently evaluated on the wrong one.
    pub fn with_options(
        transport: Box<dyn Transport>,
        heartbeat: Duration,
        backend: &str,
    ) -> std::io::Result<WorkerRuntime> {
        static SESSION: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let session = SESSION.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let scratch = std::env::temp_dir()
            .join(format!("amt-worker-{}-{session}", std::process::id()));
        std::fs::create_dir_all(&scratch)?;
        let capture = Arc::new(Wal::create(&scratch)?);
        Ok(WorkerRuntime {
            label: format!("worker-{}-{session}", std::process::id()),
            transport,
            heartbeat,
            capture,
            scratch,
            jobs: HashMap::new(),
            backend: backend.to_string(),
            polls_served: 0,
        })
    }

    /// Worker label (sent in the `Hello`).
    pub fn label(&self) -> &str {
        &self.label
    }

    #[allow(clippy::too_many_arguments)]
    fn assign(
        &mut self,
        request: TuningJobRequest,
        platform: PlatformConfig,
        transfer: Vec<Observation>,
        backend: String,
        resume: Option<crate::json::Json>,
        trace: Option<u64>,
        cache_seeds: Vec<(String, crate::json::Json)>,
    ) {
        let name = request.name.clone();
        if backend != self.backend {
            // defense in depth: the leader routes by backend, but a
            // mis-routed job must fail loudly, never evaluate wrong
            self.jobs.remove(&name);
            let _ = self.transport.send(&Message::PollResult {
                job: name,
                reply: PollReply::Rejected {
                    reason: format!(
                        "backend mismatch: job requires '{backend}', worker runs '{}'",
                        self.backend
                    ),
                },
            });
            return;
        }
        let store = Arc::new(MetadataStore::new());
        let metrics = Arc::new(MetricsService::new());
        // leader-provided evaluation-cache seeds (DESIGN.md §17) install
        // unlogged: `insert_raw` bypasses the capture WAL, so seeds the
        // leader already holds are never echoed back as deltas — only
        // entries this job *records* flow leaderward
        for (key, entry) in &cache_seeds {
            store.insert_raw(crate::store::EVAL_CACHE_TABLE, key, 1, entry.clone());
        }
        store.attach_wal(Arc::clone(&self.capture));
        metrics.attach_wal(Arc::clone(&self.capture));
        let stop_flag = Arc::new(AtomicBool::new(false));
        // a requeued job arrives with its last delta-acked resume
        // snapshot: rebuild the actor mid-flight through the same
        // shared path durable recovery uses — O(remaining work), no
        // re-proposed evaluations. A fresh job builds from the request.
        let built = match &resume {
            Some(snap) => crate::coordinator::actor_from_snapshot(
                request,
                snap,
                Arc::new(NativeBackend),
                Arc::clone(&store),
                Arc::clone(&metrics),
                Arc::clone(&stop_flag),
            ),
            None => build_actor(
                request,
                platform,
                transfer,
                Arc::clone(&store),
                Arc::clone(&metrics),
                Arc::clone(&stop_flag),
            ),
        };
        match built {
            Ok(mut actor) => {
                actor.set_wal(Arc::clone(&self.capture));
                // a re-assignment replaces any previous incarnation
                self.jobs.insert(
                    name,
                    HostedJob { actor, stop_flag, trace, _store: store, _metrics: metrics },
                );
            }
            Err(reason) => {
                // tell the leader right away; the job is terminal there
                self.jobs.remove(&name);
                let _ = self.transport.send(&Message::PollResult {
                    job: name,
                    reply: PollReply::Rejected { reason },
                });
            }
        }
    }

    fn poll(&mut self, job: &str, max_steps: usize) -> std::io::Result<()> {
        let Some(hosted) = self.jobs.get_mut(job) else {
            return self.transport.send(&Message::SliceResult {
                job: job.to_string(),
                records: Vec::new(),
                reply: PollReply::Rejected { reason: "job not assigned here".into() },
                trace: None,
            });
        };
        self.polls_served += 1;
        let trace = hosted.trace;
        let poll = hosted.actor.poll(max_steps.max(1));
        // idle tail (DESIGN.md §17): pipelined jobs pre-compute the next
        // proposal after the slice finished — the already-appended
        // checkpoint excludes it, so a worker death here just
        // re-speculates deterministically on the replacement worker
        if matches!(poll, ActorPoll::Pending { .. }) {
            hosted.actor.speculate_step();
        }
        // the slice's mutations, in application order, straight out of
        // the capture WAL's buffer, coalesced with the verdict into one
        // frame (records precede the reply within the message, so the
        // delta-before-verdict invariant holds structurally)
        let records = Wal::decode_frames(&self.capture.take_buffer()).records;
        let reply = match poll {
            ActorPoll::Pending { due } => PollReply::Pending { due },
            ActorPoll::Complete(outcome) => {
                self.jobs.remove(job);
                PollReply::Complete(outcome)
            }
        };
        self.transport.send(&Message::SliceResult {
            job: job.to_string(),
            records,
            reply,
            trace,
        })
    }

    /// Dispatch one leader message; `Flow::Drained` ends the session.
    fn handle(&mut self, msg: Message) -> std::io::Result<Flow> {
        match msg {
            Message::Assign {
                request,
                platform,
                transfer,
                backend,
                resume,
                trace,
                cache_seeds,
            } => {
                self.assign(request, platform, transfer, backend, resume, trace, cache_seeds);
            }
            Message::PollRequest { job, max_steps } => {
                self.poll(&job, max_steps)?;
            }
            Message::Stop { job } => {
                if let Some(h) = self.jobs.get(&job) {
                    h.stop_flag.store(true, std::sync::atomic::Ordering::Relaxed);
                }
            }
            Message::Batch { messages } => {
                // a leader control burst: dispatch in order, exactly as
                // if the elements had arrived as separate frames
                for m in messages {
                    match self.handle(m)? {
                        Flow::Continue => {}
                        Flow::Drained => return Ok(Flow::Drained),
                    }
                }
            }
            Message::Drain => {
                let _ = self.transport.send(&Message::DrainAck);
                return Ok(Flow::Drained);
            }
            Message::Deny { reason } => {
                // a hard admission verdict (e.g. duplicate worker
                // name), not a link failure: reconnect loops must
                // exit on it instead of retrying
                return Err(std::io::Error::new(
                    std::io::ErrorKind::PermissionDenied,
                    format!("leader denied worker: {reason}"),
                ));
            }
            // leader-bound messages can't arrive here; ignore
            _ => {}
        }
        Ok(Flow::Continue)
    }

    /// Serve the leader until it drains the session (`Ok`) or the link
    /// dies (`Err`). Either way the runtime is finished afterwards.
    pub fn run(&mut self) -> std::io::Result<()> {
        self.transport.send(&Message::Hello {
            worker: self.label.clone(),
            backend: self.backend.clone(),
            proto: super::proto::PROTO_VERSION,
        })?;
        loop {
            match self.transport.recv(self.heartbeat)? {
                None => {
                    // idle: renew the lease
                    self.transport.send(&Message::Heartbeat)?;
                }
                Some(msg) => {
                    if let Flow::Drained = self.handle(msg)? {
                        return Ok(());
                    }
                }
            }
        }
    }
}

/// Control-flow verdict of [`WorkerRuntime::handle`].
enum Flow {
    /// Keep serving the session.
    Continue,
    /// The leader drained the session: exit cleanly.
    Drained,
}

impl Drop for WorkerRuntime {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.scratch);
    }
}

/// Spawn an in-process loopback worker thread (the `--distributed` mode
/// of the soak example, benches and tests): returns the leader-side
/// transport, the fault handle, and the join handle of the worker
/// thread, which runs until drained or killed.
pub fn spawn_loopback_worker(
    label: &str,
) -> (
    Box<dyn Transport>,
    Arc<super::transport::LoopbackFault>,
    std::thread::JoinHandle<()>,
) {
    spawn_loopback_worker_with_backend(label, "native")
}

/// [`spawn_loopback_worker`] with an explicit advertised backend name —
/// the mixed-backend-fleet test double: routing and rejection behave
/// exactly as they would for a worker on a genuinely different backend.
pub fn spawn_loopback_worker_with_backend(
    label: &str,
    backend: &str,
) -> (
    Box<dyn Transport>,
    Arc<super::transport::LoopbackFault>,
    std::thread::JoinHandle<()>,
) {
    let (leader_end, worker_end, fault) = super::transport::loopback_pair(label);
    let backend = backend.to_string();
    let handle = std::thread::Builder::new()
        .name(format!("amt-remote-{label}"))
        .spawn(move || {
            if let Ok(mut runtime) =
                WorkerRuntime::with_options(Box::new(worker_end), DEFAULT_HEARTBEAT, &backend)
            {
                let _ = runtime.run();
            }
        })
        .expect("failed to spawn loopback worker");
    (Box::new(leader_end), fault, handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::durability::wal::WalRecord;

    fn drive(
        transport: &mut dyn Transport,
        job: &str,
        max_steps: usize,
    ) -> (Vec<(u64, WalRecord)>, PollReply) {
        transport
            .send(&Message::PollRequest { job: job.into(), max_steps })
            .unwrap();
        let mut delta = Vec::new();
        loop {
            match transport.recv(Duration::from_secs(10)).unwrap() {
                // legacy two-message form, still legal on the wire
                Some(Message::StoreDelta { records, .. }) => delta.extend(records),
                Some(Message::PollResult { reply, .. }) => return (delta, reply),
                Some(Message::SliceResult { records, reply, .. }) => {
                    delta.extend(records);
                    return (delta, reply);
                }
                Some(_) => {}
                None => panic!("worker went quiet"),
            }
        }
    }

    #[test]
    fn hosted_job_streams_deltas_and_completes() {
        let (mut leader, _fault, handle) = spawn_loopback_worker("unit");
        // swallow the Hello
        loop {
            match leader.recv(Duration::from_secs(10)).unwrap() {
                Some(Message::Hello { .. }) => break,
                Some(_) | None => {}
            }
        }
        let request = TuningJobRequest {
            name: "w-unit".into(),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 3,
            max_parallel_jobs: 2,
            seed: 9,
            ..Default::default()
        };
        leader
            .send(&Message::Assign {
                request,
                platform: PlatformConfig::noiseless(),
                transfer: Vec::new(),
                backend: "native".into(),
                resume: None,
                trace: None,
                cache_seeds: Vec::new(),
            })
            .unwrap();
        let mut all_records = Vec::new();
        let outcome = loop {
            let (delta, reply) = drive(leader.as_mut(), "w-unit", 64);
            all_records.extend(delta);
            match reply {
                PollReply::Pending { .. } => {}
                PollReply::Complete(outcome) => break outcome,
                PollReply::Rejected { reason } => panic!("rejected: {reason}"),
            }
        };
        assert_eq!(outcome.evaluations.len(), 3);
        // the delta stream contains the job's store puts and metric emits
        assert!(all_records.iter().any(|(_, r)| matches!(
            r,
            WalRecord::Put { table, .. } if table == "training_jobs"
        )));
        assert!(all_records.iter().any(|(_, r)| matches!(r, WalRecord::Emit { .. })));
        // polling an unknown job is rejected, not fatal
        let (_, reply) = drive(leader.as_mut(), "ghost", 8);
        assert!(matches!(reply, PollReply::Rejected { .. }));
        leader.send(&Message::Drain).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn unknown_objective_assignment_is_rejected() {
        let (mut leader, _fault, handle) = spawn_loopback_worker("reject");
        let request = TuningJobRequest {
            name: "bad".into(),
            objective: "not-a-workload".into(),
            strategy: "random".into(),
            ..Default::default()
        };
        leader
            .send(&Message::Assign {
                request,
                platform: PlatformConfig::noiseless(),
                transfer: Vec::new(),
                backend: "native".into(),
                resume: None,
                trace: None,
                cache_seeds: Vec::new(),
            })
            .unwrap();
        let reply = loop {
            match leader.recv(Duration::from_secs(10)).unwrap() {
                Some(Message::PollResult { reply, .. }) => break reply,
                Some(_) | None => {}
            }
        };
        assert!(matches!(reply, PollReply::Rejected { .. }));
        leader.send(&Message::Drain).unwrap();
        handle.join().unwrap();
    }
}
