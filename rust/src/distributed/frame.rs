//! Message framing for the distributed execution plane — the WAL's
//! on-wire frame discipline ([`crate::durability::wal`]) applied to a
//! byte stream between processes:
//!
//! ```text
//! [len: u32 LE] [crc32(payload): u32 LE] [payload: len bytes]
//! ```
//!
//! where the payload is the compact JSON of one protocol message
//! ([`crate::distributed::proto::Message`]). Sharing the framing (and
//! the JSON layer's bit-exact f64 encoding) means a `StoreDelta`'s
//! records arrive at the leader byte-for-byte equivalent to what a local
//! WAL append would have produced.
//!
//! Unlike WAL replay — where a torn tail is silently dropped — a corrupt
//! frame on a live connection is an **error**: there is no valid way to
//! resynchronize a byte stream after garbage, so transports surface
//! `InvalidData` and the peer is treated as dead (its jobs requeue).

use crate::durability::wal::crc32;

/// Frame header size: length + checksum.
pub const HEADER_BYTES: usize = 8;

/// Upper bound on one message payload (matches the WAL's corruption
/// guard: a garbage length prefix must not trigger a giant allocation).
pub const MAX_FRAME_BYTES: u32 = 1 << 26;

/// Frame a payload for the wire.
pub fn encode(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Try to decode one frame from the front of `buf`, **borrowing** the
/// payload from the input — the zero-copy primitive both transports
/// parse from (a received message is parsed and dropped immediately, so
/// an owned copy of the payload would be pure overhead).
///
/// * `Ok(Some((payload, consumed)))` — a complete, checksum-valid frame;
///   the payload borrows `buf[HEADER_BYTES..consumed]` and the caller
///   drains `consumed` bytes once done with it.
/// * `Ok(None)` — `buf` holds only a partial frame; read more bytes.
/// * `Err` — oversized length prefix or checksum mismatch: the stream is
///   unrecoverable.
pub fn decode_borrowed(buf: &[u8]) -> std::io::Result<Option<(&[u8], usize)>> {
    if buf.len() < HEADER_BYTES {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let crc = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if len > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds maximum"),
        ));
    }
    let end = HEADER_BYTES + len as usize;
    if buf.len() < end {
        return Ok(None);
    }
    let payload = &buf[HEADER_BYTES..end];
    if crc32(payload) != crc {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "frame checksum mismatch",
        ));
    }
    Ok(Some((payload, end)))
}

/// [`decode_borrowed`] with an owned payload, for callers that must hold
/// the bytes past the life of `buf`.
pub fn decode(buf: &[u8]) -> std::io::Result<Option<(Vec<u8>, usize)>> {
    Ok(decode_borrowed(buf)?.map(|(payload, end)| (payload.to_vec(), end)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let payload = br#"{"op":"heartbeat","t":0.1}"#;
        let framed = encode(payload);
        assert_eq!(framed.len(), HEADER_BYTES + payload.len());
        let (back, consumed) = decode(&framed).unwrap().unwrap();
        assert_eq!(back, payload);
        assert_eq!(consumed, framed.len());
        // empty payload frames are legal
        let (empty, n) = decode(&encode(b"")).unwrap().unwrap();
        assert!(empty.is_empty());
        assert_eq!(n, HEADER_BYTES);
    }

    #[test]
    fn partial_frames_ask_for_more() {
        let framed = encode(b"hello world");
        for cut in 0..framed.len() {
            assert!(decode(&framed[..cut]).unwrap().is_none(), "cut at {cut}");
        }
    }

    #[test]
    fn back_to_back_frames_decode_in_sequence() {
        let mut stream = encode(b"first");
        stream.extend_from_slice(&encode(b"second"));
        let (a, n) = decode(&stream).unwrap().unwrap();
        assert_eq!(a, b"first");
        let (b, m) = decode(&stream[n..]).unwrap().unwrap();
        assert_eq!(b, b"second");
        assert_eq!(n + m, stream.len());
    }

    #[test]
    fn corruption_is_an_error_not_a_drop() {
        let mut framed = encode(b"payload-bytes");
        framed[HEADER_BYTES + 3] ^= 0xFF;
        assert!(decode(&framed).is_err());
        let mut oversized = encode(b"x");
        oversized[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&oversized).is_err());
    }
}
