//! Wire protocol of the distributed execution plane (DESIGN.md §11).
//!
//! Every message is one [`crate::distributed::frame`] frame whose payload
//! is the compact JSON of a [`Message`]. The vocabulary is deliberately
//! small — the leader drives, the worker answers:
//!
//! * leader → worker: [`Message::Assign`] (host this job),
//!   [`Message::PollRequest`] (run one bounded slice),
//!   [`Message::Stop`] (flip the job's stop flag), [`Message::Drain`]
//!   (finish up and end the session), [`Message::Deny`] (admission
//!   rejected — e.g. a duplicate worker name; the worker must exit,
//!   not retry);
//! * worker → leader: [`Message::Hello`] (identify on connect),
//!   [`Message::SliceResult`] (the slice's store/metrics mutations plus
//!   its verdict as ONE message), [`Message::Heartbeat`] (lease renewal
//!   while idle), [`Message::DrainAck`].
//!
//! A slice's records are literal [`WalRecord`]s — the durability
//! engine's record format *is* the wire format, so every f64 crosses the
//! process boundary bit-exactly and the leader can apply the delta
//! through the same store/metrics paths an in-process job would have
//! used. Ordering guarantee: the leader applies a slice's records before
//! acting on its reply, and applies slices in receipt order, so per-key
//! mutation order on the leader equals the worker's application order.
//!
//! **Wire compatibility.** Pre-coalescing workers reported each slice as
//! two messages — [`Message::StoreDelta`] followed by
//! [`Message::PollResult`] — and both remain fully decodable and
//! handled: a new leader accepts either form, and a new worker's
//! `SliceResult` carries the `records` and `reply` fields with exactly
//! the encodings those two messages used, so nothing about the record or
//! reply format forked. [`Message::Batch`] likewise wraps ordinary
//! messages verbatim: receivers unwrap and dispatch each element in
//! order, which is semantically identical to (and cheaper than) the
//! elements arriving as separate frames.

use crate::config::TuningJobRequest;
use crate::coordinator::{EvaluationRecord, TuningJobOutcome};
use crate::durability::wal::WalRecord;
use crate::json::Json;
use crate::platform::PlatformConfig;
use crate::space::{config_from_json_typed, config_to_json_typed};
use crate::strategies::Observation;
use crate::workflow::ExecutionStatus;

/// Wire protocol generation this build speaks, advertised in the
/// `Hello`. Generation 1 (the field absent on the wire) reports slices
/// as `StoreDelta` + `PollResult` pairs and does not decode
/// [`Message::Batch`]; generation 2 coalesces slices into
/// [`Message::SliceResult`] and accepts batched control bursts; a
/// generation-3 peer additionally carries the optional telemetry
/// `trace` id on `Assign`/`SliceResult` (DESIGN.md §15). The trace
/// field is absent-on-wire compatible in both directions: older
/// decoders ignore the extra key, and newer decoders map an absent or
/// null key to `None` — so generation bumps never gate it; it simply
/// drops off cleanly against a pre-trace peer. Leaders still never send
/// a `Batch` to a generation-1 lane.
pub const PROTO_VERSION: u32 = 3;

/// Verdict of one remote poll slice.
#[derive(Debug)]
pub enum PollReply {
    /// Not terminal; `due` is the actor's virtual re-poll time (the
    /// leader's heap key, exactly as [`crate::coordinator::ActorPoll`]).
    Pending {
        /// Virtual re-poll time.
        due: f64,
    },
    /// Terminal: the finished outcome.
    Complete(Box<TuningJobOutcome>),
    /// The worker cannot run this job (unknown objective, never
    /// assigned, …). Terminal from the leader's perspective.
    Rejected {
        /// Human-readable cause.
        reason: String,
    },
}

/// One protocol message.
#[derive(Debug)]
pub enum Message {
    /// Worker self-identification, sent once on connect.
    Hello {
        /// Worker label (diagnostics only).
        worker: String,
        /// Surrogate backend the worker evaluates with (e.g. "native").
        /// The leader routes each job only to lanes whose backend
        /// matches the job's — mixed-backend fleets stay bit-consistent.
        backend: String,
        /// Wire protocol generation ([`PROTO_VERSION`]); absent on the
        /// wire = 1 (a pre-coalescing worker). The leader only sends
        /// `Batch` frames to lanes advertising ≥ 2.
        proto: u32,
    },
    /// Host a tuning job: everything a worker needs to rebuild the
    /// [`crate::coordinator::JobActor`] — the validated request, the
    /// leader's platform configuration (identical simulated timelines)
    /// and the pre-resolved warm-start observations (workers never read
    /// the leader's store). After a worker death, `resume` carries the
    /// job's last delta-acked v1 [`crate::coordinator::ResumeSnapshot`],
    /// and the new worker rebuilds the actor mid-flight instead of from
    /// scratch.
    Assign {
        /// The accepted tuning-job request.
        request: TuningJobRequest,
        /// Leader's platform configuration.
        platform: PlatformConfig,
        /// Warm-start transfer observations resolved at create time.
        transfer: Vec<Observation>,
        /// Surrogate backend the job must be evaluated with.
        backend: String,
        /// Resume snapshot for a requeued job (`None` = fresh start).
        resume: Option<Json>,
        /// Telemetry trace id minted at submission (DESIGN.md §15);
        /// `None` when tracing is off or the peer predates it. The
        /// worker remembers it and echoes it on every `SliceResult`
        /// for this job.
        trace: Option<u64>,
        /// Evaluation-cache seed entries for the job's objective
        /// (DESIGN.md §17): `(key, entry)` pairs from the leader's
        /// `eval_cache` table, installed unlogged into the worker's
        /// local store so cache-enabled jobs hit across the fleet.
        /// Empty when the job has the cache off (and absent on the wire
        /// — pre-cache peers interoperate unchanged).
        cache_seeds: Vec<(String, Json)>,
    },
    /// Run one bounded poll slice of an assigned job.
    PollRequest {
        /// Tuning-job name.
        job: String,
        /// Max state-machine steps for the slice.
        max_steps: usize,
    },
    /// Flip an assigned job's stop flag (observed at its next
    /// scheduling point, like the Stop API).
    Stop {
        /// Tuning-job name.
        job: String,
    },
    /// The store/metrics mutations of one poll slice, as WAL records in
    /// application order (`(lsn, record)`; LSNs are worker-local and
    /// informational — the leader re-applies through its own store).
    StoreDelta {
        /// Tuning-job name the slice belonged to.
        job: String,
        /// Ordered mutation records.
        records: Vec<(u64, WalRecord)>,
    },
    /// Verdict of a poll slice (sent after its `StoreDelta`).
    ///
    /// Legacy two-message form — current workers send one
    /// [`Message::SliceResult`] instead; kept decodable so old workers
    /// interoperate with new leaders.
    PollResult {
        /// Tuning-job name.
        job: String,
        /// Pending / Complete / Rejected.
        reply: PollReply,
    },
    /// One poll slice, coalesced: the mutations *and* the verdict in a
    /// single frame. Replaces the `StoreDelta` + `PollResult` pair (half
    /// the frames, one syscall per slice on socket transports) with the
    /// identical field encodings, and keeps their invariant structurally:
    /// records precede the reply within one message, so the leader
    /// cannot observe the verdict before the mutations it summarizes.
    SliceResult {
        /// Tuning-job name the slice belonged to.
        job: String,
        /// Ordered mutation records (as [`Message::StoreDelta`]).
        records: Vec<(u64, WalRecord)>,
        /// Slice verdict (as [`Message::PollResult`]).
        reply: PollReply,
        /// Echo of the job's `Assign` trace id — lets the leader pin
        /// the `worker_poll` trace phase to the exact slice that the
        /// remote end ran. `None` from pre-trace workers.
        trace: Option<u64>,
    },
    /// Several messages in one frame, dispatched in order by the
    /// receiver. The leader wraps per-lane control bursts (rebalance
    /// `Assign`/`Stop` floods, multi-job `PollRequest` dispatch) so a
    /// burst costs one frame + one write instead of N. Nesting a `Batch`
    /// inside a `Batch` is not produced and not accepted.
    Batch {
        /// The wrapped messages, in dispatch order.
        messages: Vec<Message>,
    },
    /// Lease renewal (idle worker).
    Heartbeat,
    /// Leader is done with this session: finish and acknowledge.
    Drain,
    /// Worker acknowledges a drain; the session ends.
    DrainAck,
    /// Leader rejects the worker's admission (duplicate worker name,
    /// …). A hard verdict: the worker must exit its session without
    /// retrying, unlike a dead link which the backoff loop may retry.
    Deny {
        /// Human-readable cause.
        reason: String,
    },
}

fn exec_status_to_json(s: &ExecutionStatus) -> Json {
    match s {
        ExecutionStatus::Succeeded => Json::obj(vec![("kind", Json::Str("Succeeded".into()))]),
        ExecutionStatus::Failed(reason) => Json::obj(vec![
            ("kind", Json::Str("Failed".into())),
            ("reason", Json::Str(reason.clone())),
        ]),
    }
}

fn exec_status_from_json(j: &Json) -> Option<ExecutionStatus> {
    match j.get("kind")?.as_str()? {
        "Succeeded" => Some(ExecutionStatus::Succeeded),
        "Failed" => Some(ExecutionStatus::Failed(
            j.get("reason").and_then(Json::as_str).unwrap_or("").to_string(),
        )),
        _ => None,
    }
}

/// Wire JSON of the optional telemetry trace id: `None` encodes as
/// `null` (indistinguishable, by design, from the key being absent on
/// a pre-trace peer's frame).
fn trace_to_json(trace: Option<u64>) -> Json {
    match trace {
        None => Json::Null,
        Some(id) => Json::Num(id as f64),
    }
}

/// Parse the optional trace id off a message object: absent, `null`,
/// or malformed all read as `None` — a pre-trace peer's frames and a
/// tracing peer's frames decode through the same path.
fn trace_from_json(j: &Json) -> Option<u64> {
    match j.get("trace") {
        None | Some(Json::Null) => None,
        Some(t) => t.as_i64().map(|v| v as u64),
    }
}

/// Wire JSON of a slice verdict — one codec shared by the legacy
/// `PollResult` message and the coalesced `SliceResult`, so the two
/// forms cannot drift apart.
fn poll_reply_to_json(reply: &PollReply) -> Json {
    match reply {
        PollReply::Pending { due } => Json::obj(vec![
            ("kind", Json::Str("pending".into())),
            ("due", Json::Num(*due)),
        ]),
        PollReply::Complete(outcome) => Json::obj(vec![
            ("kind", Json::Str("complete".into())),
            ("outcome", outcome_to_json(outcome)),
        ]),
        PollReply::Rejected { reason } => Json::obj(vec![
            ("kind", Json::Str("rejected".into())),
            ("reason", Json::Str(reason.clone())),
        ]),
    }
}

fn poll_reply_from_json(j: &Json) -> Option<PollReply> {
    Some(match j.get("kind")?.as_str()? {
        "pending" => PollReply::Pending { due: j.get("due")?.as_f64()? },
        "complete" => PollReply::Complete(Box::new(outcome_from_json(j.get("outcome")?)?)),
        "rejected" => PollReply::Rejected { reason: j.get("reason")?.as_str()?.to_string() },
        _ => return None,
    })
}

/// Wire JSON of a finished outcome (f64s round-trip bit-exactly; configs
/// use the type-tagged encoding so `Value` variants survive the trip).
/// Evaluation records use [`EvaluationRecord::to_json`] — the same codec
/// resume snapshots carry, so the formats cannot drift apart.
pub fn outcome_to_json(o: &TuningJobOutcome) -> Json {
    Json::obj(vec![
        ("name", Json::Str(o.name.clone())),
        (
            "evaluations",
            Json::Arr(o.evaluations.iter().map(EvaluationRecord::to_json).collect()),
        ),
        (
            "best",
            match &o.best {
                None => Json::Null,
                Some((cfg, v)) => Json::obj(vec![
                    ("config", config_to_json_typed(cfg)),
                    ("value", Json::Num(*v)),
                ]),
            },
        ),
        ("total_seconds", Json::Num(o.total_seconds)),
        ("total_billable_seconds", Json::Num(o.total_billable_seconds)),
        ("status", exec_status_to_json(&o.status)),
        ("retries", Json::Num(o.retries as f64)),
    ])
}

/// Parse the wire JSON of a finished outcome.
pub fn outcome_from_json(j: &Json) -> Option<TuningJobOutcome> {
    let best = match j.get("best")? {
        Json::Null => None,
        b => Some((config_from_json_typed(b.get("config")?)?, b.get("value")?.as_f64()?)),
    };
    Some(TuningJobOutcome {
        name: j.get("name")?.as_str()?.to_string(),
        evaluations: j
            .get("evaluations")?
            .as_arr()?
            .iter()
            .map(EvaluationRecord::from_json)
            .collect::<Option<_>>()?,
        best,
        total_seconds: j.get("total_seconds")?.as_f64()?,
        total_billable_seconds: j.get("total_billable_seconds")?.as_f64()?,
        status: exec_status_from_json(j.get("status")?)?,
        retries: j.get("retries")?.as_i64()? as u32,
    })
}

impl Message {
    /// Wire JSON of the message.
    pub fn to_json(&self) -> Json {
        match self {
            Message::Hello { worker, backend, proto } => Json::obj(vec![
                ("type", Json::Str("hello".into())),
                ("worker", Json::Str(worker.clone())),
                ("backend", Json::Str(backend.clone())),
                ("proto", Json::Num(*proto as f64)),
            ]),
            Message::Assign {
                request,
                platform,
                transfer,
                backend,
                resume,
                trace,
                cache_seeds,
            } => {
                let mut fields = vec![
                    ("type", Json::Str("assign".into())),
                    ("request", request.to_json()),
                    ("platform", platform.to_json()),
                    ("transfer", crate::strategies::observations_to_json(transfer)),
                    ("backend", Json::Str(backend.clone())),
                    ("resume", resume.clone().unwrap_or(Json::Null)),
                    ("trace", trace_to_json(*trace)),
                ];
                // absent-on-wire when empty, like `trace`: pre-cache
                // peers never see the field
                if !cache_seeds.is_empty() {
                    fields.push((
                        "cache_seeds",
                        Json::Arr(
                            cache_seeds
                                .iter()
                                .map(|(k, v)| {
                                    Json::obj(vec![
                                        ("key", Json::Str(k.clone())),
                                        ("entry", v.clone()),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                Json::obj(fields)
            }
            Message::PollRequest { job, max_steps } => Json::obj(vec![
                ("type", Json::Str("poll".into())),
                ("job", Json::Str(job.clone())),
                ("max_steps", Json::Num(*max_steps as f64)),
            ]),
            Message::Stop { job } => Json::obj(vec![
                ("type", Json::Str("stop".into())),
                ("job", Json::Str(job.clone())),
            ]),
            Message::StoreDelta { job, records } => Json::obj(vec![
                ("type", Json::Str("delta".into())),
                ("job", Json::Str(job.clone())),
                (
                    "records",
                    Json::Arr(records.iter().map(|(lsn, r)| r.to_json(*lsn)).collect()),
                ),
            ]),
            Message::PollResult { job, reply } => Json::obj(vec![
                ("type", Json::Str("result".into())),
                ("job", Json::Str(job.clone())),
                ("reply", poll_reply_to_json(reply)),
            ]),
            Message::SliceResult { job, records, reply, trace } => Json::obj(vec![
                ("type", Json::Str("slice".into())),
                ("job", Json::Str(job.clone())),
                (
                    "records",
                    Json::Arr(records.iter().map(|(lsn, r)| r.to_json(*lsn)).collect()),
                ),
                ("reply", poll_reply_to_json(reply)),
                ("trace", trace_to_json(*trace)),
            ]),
            Message::Batch { messages } => Json::obj(vec![
                ("type", Json::Str("batch".into())),
                ("messages", Json::Arr(messages.iter().map(Message::to_json).collect())),
            ]),
            Message::Heartbeat => Json::obj(vec![("type", Json::Str("heartbeat".into()))]),
            Message::Drain => Json::obj(vec![("type", Json::Str("drain".into()))]),
            Message::DrainAck => Json::obj(vec![("type", Json::Str("drain_ack".into()))]),
            Message::Deny { reason } => Json::obj(vec![
                ("type", Json::Str("deny".into())),
                ("reason", Json::Str(reason.clone())),
            ]),
        }
    }

    /// Parse a wire JSON message.
    pub fn from_json(j: &Json) -> Option<Message> {
        Some(match j.get("type")?.as_str()? {
            "hello" => Message::Hello {
                worker: j.get("worker")?.as_str()?.to_string(),
                // pre-pinning workers always evaluated natively
                backend: j
                    .get("backend")
                    .and_then(Json::as_str)
                    .unwrap_or("native")
                    .to_string(),
                // pre-coalescing workers are generation 1
                proto: j.get("proto").and_then(Json::as_i64).unwrap_or(1) as u32,
            },
            "assign" => Message::Assign {
                request: TuningJobRequest::from_json(j.get("request")?)?,
                platform: PlatformConfig::from_json(j.get("platform")?),
                transfer: crate::strategies::observations_from_json(j.get("transfer")?)?,
                backend: j
                    .get("backend")
                    .and_then(Json::as_str)
                    .unwrap_or("native")
                    .to_string(),
                resume: match j.get("resume") {
                    None | Some(Json::Null) => None,
                    Some(s) => Some(s.clone()),
                },
                trace: trace_from_json(j),
                // absent or null ⇒ no seeds (pre-cache leader)
                cache_seeds: match j.get("cache_seeds").and_then(Json::as_arr) {
                    Some(arr) => arr
                        .iter()
                        .map(|e| {
                            Some((
                                e.get("key")?.as_str()?.to_string(),
                                e.get("entry")?.clone(),
                            ))
                        })
                        .collect::<Option<_>>()?,
                    None => Vec::new(),
                },
            },
            "poll" => Message::PollRequest {
                job: j.get("job")?.as_str()?.to_string(),
                max_steps: j.get("max_steps")?.as_i64()? as usize,
            },
            "stop" => Message::Stop { job: j.get("job")?.as_str()?.to_string() },
            "delta" => Message::StoreDelta {
                job: j.get("job")?.as_str()?.to_string(),
                records: j
                    .get("records")?
                    .as_arr()?
                    .iter()
                    .map(WalRecord::from_json)
                    .collect::<Option<_>>()?,
            },
            "result" => Message::PollResult {
                job: j.get("job")?.as_str()?.to_string(),
                reply: poll_reply_from_json(j.get("reply")?)?,
            },
            "slice" => Message::SliceResult {
                job: j.get("job")?.as_str()?.to_string(),
                records: j
                    .get("records")?
                    .as_arr()?
                    .iter()
                    .map(WalRecord::from_json)
                    .collect::<Option<_>>()?,
                reply: poll_reply_from_json(j.get("reply")?)?,
                trace: trace_from_json(j),
            },
            "batch" => {
                let messages = j
                    .get("messages")?
                    .as_arr()?
                    .iter()
                    .map(Message::from_json)
                    .collect::<Option<Vec<_>>>()?;
                // nested batches are not part of the protocol
                if messages.iter().any(|m| matches!(m, Message::Batch { .. })) {
                    return None;
                }
                Message::Batch { messages }
            }
            "heartbeat" => Message::Heartbeat,
            "drain" => Message::Drain,
            "drain_ack" => Message::DrainAck,
            "deny" => Message::Deny {
                reason: j.get("reason").and_then(Json::as_str).unwrap_or("").to_string(),
            },
            _ => return None,
        })
    }

    /// Frame the message for the wire (compact JSON inside one
    /// length+crc frame).
    pub fn encode(&self) -> Vec<u8> {
        super::frame::encode(self.to_json().to_string().as_bytes())
    }

    /// Parse one frame payload back into a message.
    pub fn decode(payload: &[u8]) -> std::io::Result<Message> {
        let text = std::str::from_utf8(payload).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "message not utf-8")
        })?;
        let parsed = crate::json::parse(text).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("message json: {e}"))
        })?;
        Message::from_json(&parsed).ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "unknown message shape")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{Config, Value};

    fn roundtrip(msg: &Message) -> Message {
        let framed = msg.encode();
        let (payload, consumed) = super::super::frame::decode(&framed).unwrap().unwrap();
        assert_eq!(consumed, framed.len());
        Message::decode(&payload).unwrap()
    }

    #[test]
    fn control_messages_roundtrip() {
        assert!(matches!(roundtrip(&Message::Heartbeat), Message::Heartbeat));
        assert!(matches!(roundtrip(&Message::Drain), Message::Drain));
        assert!(matches!(roundtrip(&Message::DrainAck), Message::DrainAck));
        assert!(matches!(
            roundtrip(&Message::Deny { reason: "duplicate worker name".into() }),
            Message::Deny { reason } if reason == "duplicate worker name"
        ));
        assert!(matches!(
            roundtrip(&Message::Hello {
                worker: "w0".into(),
                backend: "native".into(),
                proto: PROTO_VERSION,
            }),
            Message::Hello { worker, backend, proto: PROTO_VERSION }
                if worker == "w0" && backend == "native"
        ));
        // a Hello without backend/proto fields (pre-pinning,
        // pre-coalescing worker) defaults to native, generation 1
        let legacy = crate::json::parse(r#"{"type": "hello", "worker": "old"}"#).unwrap();
        assert!(matches!(
            Message::from_json(&legacy),
            Some(Message::Hello { backend, proto: 1, .. }) if backend == "native"
        ));
        assert!(matches!(
            roundtrip(&Message::Stop { job: "j".into() }),
            Message::Stop { job } if job == "j"
        ));
        let m = roundtrip(&Message::PollRequest { job: "j".into(), max_steps: 256 });
        assert!(matches!(m, Message::PollRequest { job, max_steps: 256 } if job == "j"));
    }

    #[test]
    fn assign_roundtrips_request_platform_and_transfer() {
        let mut config = Config::new();
        config.insert("eta".into(), Value::Float(0.1));
        config.insert("depth".into(), Value::Int(6));
        config.insert("booster".into(), Value::Cat("gbtree".into()));
        let msg = Message::Assign {
            request: TuningJobRequest {
                name: "remote-1".into(),
                seed: 42,
                tenant_weight: 3,
                ..Default::default()
            },
            platform: PlatformConfig { provisioning_mean: 7.5, ..Default::default() },
            transfer: vec![Observation { config, value: -1.0 / 3.0 }],
            backend: "native".into(),
            resume: None,
            trace: None,
            cache_seeds: Vec::new(),
        };
        let Message::Assign {
            request,
            platform,
            transfer,
            backend,
            resume,
            trace,
            cache_seeds,
        } = roundtrip(&msg)
        else {
            panic!("wrong variant");
        };
        assert!(trace.is_none());
        assert!(cache_seeds.is_empty());
        assert_eq!(request.name, "remote-1");
        assert_eq!(request.seed, 42);
        assert_eq!(request.tenant_weight, 3);
        assert_eq!(platform.provisioning_mean.to_bits(), 7.5f64.to_bits());
        assert_eq!(transfer.len(), 1);
        assert_eq!(transfer[0].value.to_bits(), (-1.0f64 / 3.0).to_bits());
        assert_eq!(transfer[0].config.get("depth"), Some(&Value::Int(6)));
        assert_eq!(
            transfer[0].config.get("booster"),
            Some(&Value::Cat("gbtree".into()))
        );
        assert_eq!(backend, "native");
        assert!(resume.is_none());
    }

    #[test]
    fn assign_resume_snapshot_rides_the_wire_verbatim() {
        let snap = crate::json::parse(
            r#"{"v": 1, "cursor": {"clock": 0.125}, "strategy": {"kind": "random"},
                "platform": {}, "coord": {}}"#,
        )
        .unwrap();
        let msg = Message::Assign {
            request: TuningJobRequest { name: "requeued".into(), ..Default::default() },
            platform: PlatformConfig::default(),
            transfer: Vec::new(),
            backend: "hlo".into(),
            resume: Some(snap.clone()),
            trace: None,
            cache_seeds: Vec::new(),
        };
        let Message::Assign { backend, resume, .. } = roundtrip(&msg) else {
            panic!("wrong variant");
        };
        assert_eq!(backend, "hlo");
        assert_eq!(resume, Some(snap), "snapshot payload must survive verbatim");
    }

    #[test]
    fn delta_records_roundtrip_bit_exact() {
        let records = vec![
            (
                3u64,
                WalRecord::Put {
                    table: "training_jobs".into(),
                    key: "j-train-0001".into(),
                    version: 2,
                    value: Json::obj(vec![("final_value", Json::Num(1.0 / 3.0))]),
                },
            ),
            (4u64, WalRecord::Emit { stream: "j/loss".into(), time: 1e-300, value: -0.125 }),
        ];
        let msg = Message::StoreDelta { job: "j".into(), records };
        let Message::StoreDelta { job, records } = roundtrip(&msg) else {
            panic!("wrong variant");
        };
        assert_eq!(job, "j");
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].0, 3);
        let WalRecord::Put { version, value, .. } = &records[0].1 else { panic!() };
        assert_eq!(*version, 2);
        assert_eq!(
            value.get("final_value").unwrap().as_f64().unwrap().to_bits(),
            (1.0f64 / 3.0).to_bits()
        );
        let WalRecord::Emit { time, value, .. } = &records[1].1 else { panic!() };
        assert_eq!(time.to_bits(), 1e-300f64.to_bits());
        assert_eq!(value.to_bits(), (-0.125f64).to_bits());
    }

    #[test]
    fn slice_result_roundtrips_and_matches_two_message_encodings() {
        let records = vec![
            (
                7u64,
                WalRecord::Put {
                    table: "training_jobs".into(),
                    key: "j-train-0002".into(),
                    version: 5,
                    value: Json::obj(vec![("v", Json::Num(-0.5))]),
                },
            ),
            (8u64, WalRecord::Emit { stream: "j/loss".into(), time: 2.5, value: 1.0 / 3.0 }),
        ];
        let msg = Message::SliceResult {
            job: "j".into(),
            records: records.clone(),
            reply: PollReply::Pending { due: 12.25 },
            trace: None,
        };
        let Message::SliceResult { job, records: back, reply, trace: _ } = roundtrip(&msg)
        else {
            panic!("wrong variant");
        };
        assert_eq!(job, "j");
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, 7);
        let WalRecord::Emit { time, value, .. } = &back[1].1 else { panic!() };
        assert_eq!(time.to_bits(), 2.5f64.to_bits());
        assert_eq!(value.to_bits(), (1.0f64 / 3.0).to_bits());
        assert!(matches!(reply, PollReply::Pending { due } if due.to_bits() == 12.25f64.to_bits()));
        // field encodings are literally the legacy messages': the slice's
        // "records" json equals StoreDelta's, its "reply" json equals
        // PollResult's
        let slice = msg.to_json();
        let delta =
            Message::StoreDelta { job: "j".into(), records }.to_json();
        let result = Message::PollResult {
            job: "j".into(),
            reply: PollReply::Pending { due: 12.25 },
        }
        .to_json();
        assert_eq!(
            slice.get("records").unwrap().to_string(),
            delta.get("records").unwrap().to_string()
        );
        assert_eq!(
            slice.get("reply").unwrap().to_string(),
            result.get("reply").unwrap().to_string()
        );
    }

    #[test]
    fn assign_cache_seeds_roundtrip_and_absent_when_empty() {
        let seeds = vec![(
            "branin|{\"x\":{\"float\":0.25}}".to_string(),
            Json::obj(vec![("final_value", Json::Num(1.0 / 3.0))]),
        )];
        let msg = Message::Assign {
            request: TuningJobRequest { name: "c".into(), ..Default::default() },
            platform: PlatformConfig::default(),
            transfer: Vec::new(),
            backend: "native".into(),
            resume: None,
            trace: None,
            cache_seeds: seeds.clone(),
        };
        let Message::Assign { cache_seeds, .. } = roundtrip(&msg) else {
            panic!("wrong variant");
        };
        assert_eq!(cache_seeds.len(), 1);
        assert_eq!(cache_seeds[0].0, seeds[0].0);
        assert_eq!(
            cache_seeds[0].1.get("final_value").unwrap().as_f64().unwrap().to_bits(),
            (1.0f64 / 3.0).to_bits(),
            "entry payload must survive bit-exactly"
        );
        // empty seed lists stay OFF the wire, like an absent trace id —
        // a pre-cache peer's decoder never sees an unknown key
        let empty = Message::Assign {
            request: TuningJobRequest { name: "c".into(), ..Default::default() },
            platform: PlatformConfig::default(),
            transfer: Vec::new(),
            backend: "native".into(),
            resume: None,
            trace: None,
            cache_seeds: Vec::new(),
        };
        assert!(empty.to_json().get("cache_seeds").is_none());
    }

    #[test]
    fn trace_ids_roundtrip_and_absent_on_wire_reads_as_none() {
        // present → survives the frame bit-exactly
        let msg = Message::SliceResult {
            job: "t".into(),
            records: Vec::new(),
            reply: PollReply::Pending { due: 1.0 },
            trace: Some(424_242),
        };
        let Message::SliceResult { trace, .. } = roundtrip(&msg) else { panic!() };
        assert_eq!(trace, Some(424_242));
        let msg = Message::Assign {
            request: TuningJobRequest { name: "t".into(), ..Default::default() },
            platform: PlatformConfig::default(),
            transfer: Vec::new(),
            backend: "native".into(),
            resume: None,
            trace: Some(7),
            cache_seeds: Vec::new(),
        };
        let Message::Assign { trace, .. } = roundtrip(&msg) else { panic!() };
        assert_eq!(trace, Some(7));

        // a generation-2 peer's frame has NO trace key at all — decode
        // hand-built JSON without it, exactly what such a peer emits
        let gen2 = crate::json::parse(
            r#"{"type": "slice", "job": "t", "records": [],
                "reply": {"kind": "pending", "due": 2.0}}"#,
        )
        .unwrap();
        let Some(Message::SliceResult { trace, .. }) = Message::from_json(&gen2) else {
            panic!("gen-2 slice frame must decode");
        };
        assert_eq!(trace, None, "absent trace key must read as None");
        // and a null trace key (this build's None encoding) likewise
        let null = crate::json::parse(
            r#"{"type": "slice", "job": "t", "records": [],
                "reply": {"kind": "pending", "due": 2.0}, "trace": null}"#,
        )
        .unwrap();
        let Some(Message::SliceResult { trace, .. }) = Message::from_json(&null) else {
            panic!("null-trace slice frame must decode");
        };
        assert_eq!(trace, None);
    }

    #[test]
    fn batch_roundtrips_in_order_and_rejects_nesting() {
        let msg = Message::Batch {
            messages: vec![
                Message::Stop { job: "a".into() },
                Message::PollRequest { job: "b".into(), max_steps: 64 },
                Message::PollRequest { job: "c".into(), max_steps: 64 },
            ],
        };
        let Message::Batch { messages } = roundtrip(&msg) else { panic!("wrong variant") };
        assert_eq!(messages.len(), 3);
        assert!(matches!(&messages[0], Message::Stop { job } if job == "a"));
        assert!(matches!(&messages[1], Message::PollRequest { job, .. } if job == "b"));
        assert!(matches!(&messages[2], Message::PollRequest { job, .. } if job == "c"));
        // a batch inside a batch is a protocol violation, not a message
        let nested = Json::obj(vec![
            ("type", Json::Str("batch".into())),
            (
                "messages",
                Json::Arr(vec![Json::obj(vec![
                    ("type", Json::Str("batch".into())),
                    ("messages", Json::Arr(Vec::new())),
                ])]),
            ),
        ]);
        assert!(Message::from_json(&nested).is_none());
    }

    #[test]
    fn outcome_roundtrips_every_field() {
        let mut config = Config::new();
        config.insert("x".into(), Value::Float(0.25));
        let outcome = TuningJobOutcome {
            name: "job".into(),
            evaluations: vec![EvaluationRecord {
                training_job_name: "job-train-0000".into(),
                config: config.clone(),
                curve: vec![0.5, 1.0 / 3.0],
                final_value: Some(1.0 / 3.0),
                status: TrainingJobStatus::Completed,
                stopped_early: false,
                attempts: 2,
                submitted_at: 1.5,
                ended_at: 123.456789,
                cached: false,
            }],
            best: Some((config, 1.0 / 3.0)),
            total_seconds: 123.456789,
            total_billable_seconds: 121.25,
            status: ExecutionStatus::Succeeded,
            retries: 1,
        };
        let back = outcome_from_json(&outcome_to_json(&outcome)).unwrap();
        assert_eq!(back.name, outcome.name);
        assert_eq!(back.retries, 1);
        assert_eq!(back.status, ExecutionStatus::Succeeded);
        assert_eq!(back.total_seconds.to_bits(), outcome.total_seconds.to_bits());
        assert_eq!(back.evaluations.len(), 1);
        let (a, b) = (&back.evaluations[0], &outcome.evaluations[0]);
        assert_eq!(a.training_job_name, b.training_job_name);
        assert_eq!(a.config, b.config);
        assert_eq!(a.curve.len(), 2);
        assert_eq!(a.curve[1].to_bits(), b.curve[1].to_bits());
        assert_eq!(a.final_value.unwrap().to_bits(), b.final_value.unwrap().to_bits());
        assert_eq!(a.status, TrainingJobStatus::Completed);
        assert_eq!(a.attempts, 2);
        assert_eq!(a.ended_at.to_bits(), b.ended_at.to_bits());
        assert_eq!(back.best.unwrap().1.to_bits(), (1.0f64 / 3.0).to_bits());
        // failed executions carry their reason
        let failed = TuningJobOutcome {
            status: ExecutionStatus::Failed("boom".into()),
            best: None,
            evaluations: Vec::new(),
            ..outcome
        };
        let back = outcome_from_json(&outcome_to_json(&failed)).unwrap();
        assert_eq!(back.status, ExecutionStatus::Failed("boom".into()));
        assert!(back.best.is_none());
    }
}
