//! Leader-side remote worker pool: the distributed counterpart of the
//! in-process [`crate::scheduler::Scheduler`].
//!
//! A [`RemoteWorkerPool`] exposes the same dispatch surface the
//! scheduler gives the API layer (`register` / `activate` / `stop` /
//! `wait` / `try_outcome` / `poll_count` / `running_jobs`), but instead
//! of polling actors on pool threads it drives one **driver thread per
//! worker connection**, each draining a per-worker virtual-time event
//! heap keyed exactly like the scheduler's (`(due ÷ tenant_weight,
//! seq)`) and speaking the [`super::proto`] protocol:
//!
//! ```text
//! pop job → [Assign once] → [Stop if requested] → PollRequest
//!        ← StoreDelta (applied to the leader store/metrics in order)
//!        ← PollResult (Pending → requeue · Complete → publish)
//! ```
//!
//! Deltas are applied through the leader's ordinary `store.put` /
//! `metrics.emit` paths — versions are recomputed *at the leader*, so
//! final store contents (values **and** versions) are bit-identical to
//! the same jobs run on the in-process pool, and when a durability WAL
//! is attached every applied record is logged and group-committed per
//! slice just like a local poll slice would be.
//!
//! **Leases.** A worker renews its lease with every message (heartbeats
//! while idle). A worker that stays silent past the lease — or whose
//! link errors — is declared dead and its unfinished jobs move to the
//! least-loaded live compatible worker. The repair is **O(remaining
//! work)** whenever possible: every `Pending` slice's delta carries the
//! job's v1 [`crate::coordinator::ResumeSnapshot`] checkpoint (appended
//! by the actor at the slice boundary, so delta application is atomic
//! per slice — the leader's store state always equals the last acked
//! checkpoint's), and the re-`Assign` ships that snapshot so the new
//! worker rebuilds the actor mid-flight. Jobs with no acked checkpoint
//! yet (or whose terminal slice was in flight) fall back to the PR 3
//! scratch path: partial leader records reset,
//! `warm_start`/`tuning_jobs` seeds re-persisted, deterministic replay
//! from the request seed. Both paths finish with exactly the records of
//! an uninterrupted run. With no live compatible workers left, jobs
//! fail loudly (outcome `Failed`, store record `Failed`) instead of
//! hanging.
//!
//! **Backend pinning.** Each worker's `Hello` advertises its surrogate
//! backend; each job's spec pins the backend it must evaluate on.
//! Routing (activation, death repair) only considers matching lanes, so
//! a mixed-backend fleet stays bit-consistent; the API layer checks
//! [`RemoteWorkerPool::supports_backend`] and keeps jobs local when no
//! compatible worker is live.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::TuningJobRequest;
use crate::coordinator::TuningJobOutcome;
use crate::durability::wal::{Wal, WalRecord};
use crate::metrics::MetricsService;
use crate::platform::PlatformConfig;
use crate::scheduler::{QueueEntry, TenantQuotas};
use crate::store::MetadataStore;
use crate::strategies::Observation;
use crate::workflow::ExecutionStatus;

use super::proto::{Message, PollReply};
use super::transport::Transport;

/// Knobs for the remote pool.
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// Max state-machine steps per remote poll slice (the scheduler's
    /// `batch_steps`, shipped in every `PollRequest`).
    pub batch_steps: usize,
    /// Worker lease: *idle* silence longer than this declares the
    /// worker dead and requeues its jobs. Workers heartbeat at a small
    /// fraction of the leader's lease (`DEFAULT_HEARTBEAT`).
    pub lease: Duration,
    /// Per-slice compute budget: how long a dispatched `PollRequest`
    /// may go unanswered before the worker is declared dead. Workers
    /// are single-threaded and cannot heartbeat mid-poll, so this must
    /// comfortably exceed the slowest slice (a large BO refit can take
    /// seconds) — it is a hang detector, not a latency bound. Link
    /// errors are still detected immediately.
    pub poll_timeout: Duration,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            batch_steps: 256,
            lease: Duration::from_secs(5),
            poll_timeout: Duration::from_secs(120),
        }
    }
}

/// Everything the leader needs to (re)create one remote job: the
/// validated request, the platform configuration and the pre-resolved
/// warm-start observations. Kept for the job's lifetime so a worker
/// death can re-dispatch from scratch.
pub struct RemoteJobSpec {
    /// The accepted tuning-job request.
    pub request: TuningJobRequest,
    /// Leader's platform configuration (shipped to the worker).
    pub platform: PlatformConfig,
    /// Warm-start transfer observations resolved at create time.
    pub transfer: Vec<Observation>,
    /// Surrogate backend the job must evaluate on (lane routing key).
    pub backend: String,
}

#[derive(Default)]
struct SlotState {
    outcome: Option<TuningJobOutcome>,
}

struct RemoteSlot {
    spec: RemoteJobSpec,
    weight: f64,
    quota: Option<(String, usize)>,
    state: Mutex<SlotState>,
    done_cv: Condvar,
    stop: AtomicBool,
    /// Stop forwarded to the current worker incarnation.
    stop_sent: AtomicBool,
    /// Index of the worker lane hosting this job (usize::MAX = none).
    lane: AtomicUsize,
    /// Assign shipped to the current lane incarnation.
    started: AtomicBool,
    polls: AtomicU64,
    /// The job's last delta-acked v1 resume snapshot. Delta application
    /// is atomic per slice and every `Pending` slice ends with its
    /// checkpoint record, so whenever this is `Some`, the leader's
    /// store/metrics state for the job equals exactly this snapshot's —
    /// a worker death requeues from here with O(remaining work).
    last_ckpt: Mutex<Option<crate::json::Json>>,
}

const NO_LANE: usize = usize::MAX;

struct WorkerLane {
    heap: Mutex<BinaryHeap<Reverse<QueueEntry>>>,
    alive: AtomicBool,
    /// Unfinished jobs assigned here (least-loaded placement heuristic).
    load: AtomicUsize,
}

/// Lane backends (from each worker's `Hello`), under one mutex with a
/// condvar so routing can wait for the fleet to identify itself.
struct LaneBackends {
    known: Mutex<Vec<Option<String>>>,
    hello_cv: Condvar,
}

struct LeaderInner {
    store: Arc<MetadataStore>,
    metrics: Arc<MetricsService>,
    wal: Option<Arc<Wal>>,
    batch_steps: usize,
    lease: Duration,
    poll_timeout: Duration,
    jobs: Mutex<HashMap<String, Arc<RemoteSlot>>>,
    lanes: Vec<WorkerLane>,
    backends: LaneBackends,
    live: AtomicUsize,
    running: AtomicUsize,
    shutdown: AtomicBool,
    seq: AtomicU64,
    quotas: TenantQuotas,
    /// Worker-death repairs that requeued from a delta-acked snapshot
    /// (O(remaining)) vs from scratch, and — for the scratch leg — how
    /// many already-proposed evaluations the rerun re-executes.
    snapshot_requeues: AtomicU64,
    scratch_requeues: AtomicU64,
    replayed_proposals: AtomicU64,
    /// Group commits that failed even after a retry (mirrors
    /// `Scheduler::wal_commit_errors` for the remote plane).
    wal_commit_errors: AtomicU64,
    /// Invoked after every successful WAL group commit (the durable
    /// service's auto-checkpoint trigger — same hook as the scheduler's,
    /// so the WAL stays bounded no matter which plane commits).
    post_commit: std::sync::OnceLock<Arc<dyn Fn() + Send + Sync>>,
    /// Serializes placement decisions: activation, death repair and
    /// quota-release routing, so concurrent worker deaths cannot strand
    /// or duplicate a job's single heap entry.
    route: Mutex<()>,
}

/// The leader-side remote execution plane.
pub struct RemoteWorkerPool {
    inner: Arc<LeaderInner>,
    drivers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl RemoteWorkerPool {
    /// Start one driver thread per connected worker transport. Deltas
    /// apply into `store`/`metrics`; when `wal` is given, every applied
    /// record is logged and group-committed per slice.
    pub fn new(
        transports: Vec<Box<dyn Transport>>,
        store: Arc<MetadataStore>,
        metrics: Arc<MetricsService>,
        wal: Option<Arc<Wal>>,
        config: RemoteConfig,
    ) -> RemoteWorkerPool {
        let lanes = (0..transports.len())
            .map(|_| WorkerLane {
                heap: Mutex::new(BinaryHeap::new()),
                alive: AtomicBool::new(true),
                load: AtomicUsize::new(0),
            })
            .collect();
        let inner = Arc::new(LeaderInner {
            store,
            metrics,
            wal,
            batch_steps: config.batch_steps.max(1),
            lease: config.lease,
            poll_timeout: config.poll_timeout.max(config.lease),
            jobs: Mutex::new(HashMap::new()),
            backends: LaneBackends {
                known: Mutex::new(vec![None; transports.len()]),
                hello_cv: Condvar::new(),
            },
            lanes,
            live: AtomicUsize::new(transports.len()),
            running: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            quotas: TenantQuotas::new(),
            snapshot_requeues: AtomicU64::new(0),
            scratch_requeues: AtomicU64::new(0),
            replayed_proposals: AtomicU64::new(0),
            wal_commit_errors: AtomicU64::new(0),
            post_commit: std::sync::OnceLock::new(),
            route: Mutex::new(()),
        });
        let drivers = transports
            .into_iter()
            .enumerate()
            .map(|(idx, transport)| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("amt-lead-{idx}"))
                    .spawn(move || driver_loop(&inner, idx, transport))
                    .expect("failed to spawn leader driver")
            })
            .collect();
        RemoteWorkerPool { inner, drivers: Mutex::new(drivers) }
    }

    /// Connected worker transports this pool was built over.
    pub fn worker_count(&self) -> usize {
        self.inner.lanes.len()
    }

    /// Workers whose lease is still good.
    pub fn live_workers(&self) -> usize {
        self.inner.live.load(Ordering::Relaxed)
    }

    /// Jobs registered and not yet finished.
    pub fn running_jobs(&self) -> usize {
        self.inner.running.load(Ordering::Relaxed)
    }

    /// True if a job with this name was ever registered.
    pub fn contains(&self, name: &str) -> bool {
        self.inner.jobs.lock().unwrap().contains_key(name)
    }

    /// Poll slices dispatched for the named job (`None` for unknown).
    pub fn poll_count(&self, name: &str) -> Option<u64> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        Some(slot.polls.load(Ordering::Relaxed))
    }

    /// Highest concurrent slice count the named tenant ever reached.
    pub fn tenant_high_water(&self, tenant: &str) -> usize {
        self.inner.quotas.high_water(tenant)
    }

    /// WAL group commits that failed even after a retry (records stay
    /// buffered in the WAL and retry at later slices — alert on this,
    /// exactly like `Scheduler::wal_commit_errors`).
    pub fn wal_commit_errors(&self) -> u64 {
        self.inner.wal_commit_errors.load(Ordering::Relaxed)
    }

    /// Worker-death repairs that requeued a job from its last
    /// delta-acked resume snapshot (the O(remaining-work) path).
    pub fn snapshot_requeues(&self) -> u64 {
        self.inner.snapshot_requeues.load(Ordering::Relaxed)
    }

    /// Worker-death repairs that fell back to reset + replay-from-seed.
    pub fn scratch_requeues(&self) -> u64 {
        self.inner.scratch_requeues.load(Ordering::Relaxed)
    }

    /// Strategy proposals re-executed across all scratch requeues (the
    /// evaluations that already existed when the worker died; snapshot
    /// requeues contribute 0 by construction).
    pub fn replayed_proposals(&self) -> u64 {
        self.inner.replayed_proposals.load(Ordering::Relaxed)
    }

    /// True when at least one live worker advertises `backend` — the
    /// API layer's routing gate (jobs stay on the local plane
    /// otherwise). Waits briefly (up to the lease) for lanes that have
    /// not sent their `Hello` yet, so a just-constructed pool answers
    /// correctly.
    pub fn supports_backend(&self, backend: &str) -> bool {
        await_hellos(&self.inner);
        let known = self.inner.backends.known.lock().unwrap();
        known.iter().enumerate().any(|(i, b)| {
            self.inner.lanes[i].alive.load(Ordering::SeqCst)
                && b.as_deref() == Some(backend)
        })
    }

    /// Advertised backend of each lane (`None` = no `Hello` yet).
    pub fn lane_backends(&self) -> Vec<Option<String>> {
        self.inner.backends.known.lock().unwrap().clone()
    }

    /// Install a hook invoked after every successful WAL group commit
    /// on this plane (at most once; later calls no-op). The durable API
    /// layer installs the same auto-checkpoint trigger it gives the
    /// scheduler, so `DurabilityOptions::auto_checkpoint_bytes` bounds
    /// the log regardless of which plane does the committing.
    pub fn set_post_commit(&self, hook: Arc<dyn Fn() + Send + Sync>) {
        let _ = self.inner.post_commit.set(hook);
    }

    /// Reserve a job name without queueing it (the API layer persists
    /// the accepted request in between, exactly like the in-process
    /// scheduler's register/activate split). False if taken.
    pub fn register(&self, spec: RemoteJobSpec) -> bool {
        let name = spec.request.name.clone();
        let weight = spec.request.tenant_weight.max(1) as f64;
        let quota = if spec.request.tenant.is_empty() {
            None
        } else {
            Some((spec.request.tenant.clone(), spec.request.max_in_flight as usize))
        };
        let mut jobs = self.inner.jobs.lock().unwrap();
        if jobs.contains_key(&name) {
            return false;
        }
        jobs.insert(
            name,
            Arc::new(RemoteSlot {
                spec,
                weight,
                quota,
                state: Mutex::new(SlotState::default()),
                done_cv: Condvar::new(),
                stop: AtomicBool::new(false),
                stop_sent: AtomicBool::new(false),
                lane: AtomicUsize::new(NO_LANE),
                started: AtomicBool::new(false),
                polls: AtomicU64::new(0),
                last_ckpt: Mutex::new(None),
            }),
        );
        drop(jobs);
        self.inner.running.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Place a registered job on the least-loaded live worker running a
    /// compatible backend and queue it. Must be called exactly once per
    /// registered job.
    pub fn activate(&self, name: &str) {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() };
        let Some(slot) = slot else { return };
        await_hellos(&self.inner);
        let _route = self.inner.route.lock().unwrap();
        match pick_lane(&self.inner, &slot.spec.backend) {
            Some(idx) => {
                slot.lane.store(idx, Ordering::SeqCst);
                self.inner.lanes[idx].load.fetch_add(1, Ordering::Relaxed);
                push_lane_entry(&self.inner, idx, 0.0, slot.weight, name.to_string());
            }
            None => mark_failed(
                &self.inner,
                &slot,
                name,
                &format!("no live remote workers for backend '{}'", slot.spec.backend),
            ),
        }
    }

    /// Signal a job to stop at its next scheduling point.
    pub fn stop(&self, name: &str) -> bool {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() };
        match slot {
            Some(slot) => {
                slot.stop.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Block until the named job finishes; `None` for unknown names.
    pub fn wait(&self, name: &str) -> Option<TuningJobOutcome> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        let mut state = slot.state.lock().unwrap();
        while state.outcome.is_none() {
            state = slot.done_cv.wait(state).unwrap();
        }
        state.outcome.clone()
    }

    /// Non-blocking probe for a finished outcome.
    pub fn try_outcome(&self, name: &str) -> Option<TuningJobOutcome> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        let state = slot.state.lock().unwrap();
        state.outcome.clone()
    }
}

impl Drop for RemoteWorkerPool {
    fn drop(&mut self) {
        // drivers poll the shutdown flag between receive slices
        self.inner.shutdown.store(true, Ordering::SeqCst);
        let drivers = std::mem::take(&mut *self.drivers.lock().unwrap());
        for d in drivers {
            let _ = d.join();
        }
    }
}

/// Block (bounded by the lease) until every live lane has identified
/// its backend via `Hello` — one-time at fleet startup; a no-op after.
fn await_hellos(inner: &LeaderInner) {
    let deadline = Instant::now() + inner.lease;
    let mut known = inner.backends.known.lock().unwrap();
    loop {
        let pending = known.iter().enumerate().any(|(i, b)| {
            b.is_none() && inner.lanes[i].alive.load(Ordering::SeqCst)
        });
        if !pending || Instant::now() >= deadline {
            return;
        }
        known = inner
            .backends
            .hello_cv
            .wait_timeout(known, Duration::from_millis(20))
            .unwrap()
            .0;
    }
}

/// Record a worker's advertised backend and wake routing waiters.
fn note_hello(inner: &LeaderInner, idx: usize, backend: &str) {
    let mut known = inner.backends.known.lock().unwrap();
    if known[idx].as_deref() != Some(backend) {
        known[idx] = Some(backend.to_string());
    }
    drop(known);
    inner.backends.hello_cv.notify_all();
}

/// Least-loaded live lane whose worker runs `backend`, if any.
fn pick_lane(inner: &LeaderInner, backend: &str) -> Option<usize> {
    let known = inner.backends.known.lock().unwrap();
    inner
        .lanes
        .iter()
        .enumerate()
        .filter(|(i, l)| {
            l.alive.load(Ordering::SeqCst) && known[*i].as_deref() == Some(backend)
        })
        .min_by_key(|(_, l)| l.load.load(Ordering::Relaxed))
        .map(|(i, _)| i)
}

/// Queue `(due / weight, seq, name)` on a lane's heap (same key as the
/// in-process scheduler's `push_entry`).
fn push_lane_entry(inner: &LeaderInner, idx: usize, due: f64, weight: f64, name: String) {
    let seq = inner.seq.fetch_add(1, Ordering::Relaxed);
    let due = due / weight.max(1.0);
    inner.lanes[idx].heap.lock().unwrap().push(Reverse(QueueEntry { due, seq, name }));
}

/// Re-push an already-discounted entry (quota release, death repair).
fn repush_entry(inner: &LeaderInner, idx: usize, entry: QueueEntry) {
    inner.lanes[idx].heap.lock().unwrap().push(Reverse(entry));
}

/// Apply one delta through the leader's ordinary mutation paths:
/// versions are recomputed here, WAL records (when attached) are
/// appended inside the store/metrics critical sections, and worker
/// checkpoints are re-logged verbatim — the "existing durability commit
/// path" of DESIGN.md §11. v1 resume-snapshot checkpoints are also
/// retained per job: they are what a worker-death repair requeues from.
fn apply_delta(inner: &LeaderInner, records: &[(u64, WalRecord)]) {
    for (_, rec) in records {
        match rec {
            WalRecord::Put { table, key, value, .. } => {
                inner.store.put(table, key, value.clone());
            }
            WalRecord::Delete { table, key } => {
                inner.store.delete(table, key);
            }
            WalRecord::Emit { stream, time, value } => {
                inner.metrics.emit(stream, *time, *value);
            }
            WalRecord::RemoveStreams { prefix } => {
                inner.metrics.remove_streams(prefix);
            }
            WalRecord::Checkpoint { job, exec } => {
                if let Some(w) = &inner.wal {
                    w.append(rec);
                }
                if crate::coordinator::is_resume_snapshot(exec) {
                    let slot = { inner.jobs.lock().unwrap().get(job).cloned() };
                    if let Some(slot) = slot {
                        *slot.last_ckpt.lock().unwrap() = Some(exec.clone());
                    }
                }
            }
        }
    }
}

/// Group-commit the attached WAL, mirroring the in-process scheduler's
/// semantics exactly: retry a failed commit once, count persistent
/// failures (records stay buffered and retry at later slices), and run
/// the post-commit hook (auto-checkpoint) after success.
fn commit_wal(inner: &LeaderInner) {
    if let Some(w) = &inner.wal {
        if w.commit().is_err() && w.commit().is_err() {
            inner.wal_commit_errors.fetch_add(1, Ordering::Relaxed);
        } else if let Some(hook) = inner.post_commit.get() {
            (**hook)();
        }
    }
}

/// Publish a terminal outcome and wake waiters (idempotent: a second
/// terminal verdict for the same job changes nothing).
fn publish(inner: &LeaderInner, slot: &RemoteSlot, outcome: TuningJobOutcome) {
    let mut state = slot.state.lock().unwrap();
    if state.outcome.is_some() {
        return;
    }
    let lane = slot.lane.swap(NO_LANE, Ordering::SeqCst);
    if lane != NO_LANE {
        inner.lanes[lane].load.fetch_sub(1, Ordering::Relaxed);
    }
    inner.running.fetch_sub(1, Ordering::Relaxed);
    state.outcome = Some(outcome);
    drop(state);
    slot.done_cv.notify_all();
}

/// Fail a job loudly: `Failed` store record (commit included) plus a
/// `Failed` outcome for waiters.
fn mark_failed(inner: &LeaderInner, slot: &RemoteSlot, name: &str, reason: &str) {
    crate::api::persist_job_failed(&inner.store, name, slot.spec.request.to_json(), reason);
    commit_wal(inner);
    publish(
        inner,
        slot,
        TuningJobOutcome {
            name: name.to_string(),
            evaluations: Vec::new(),
            best: None,
            total_seconds: 0.0,
            total_billable_seconds: 0.0,
            status: ExecutionStatus::Failed(reason.to_string()),
            retries: 0,
        },
    );
}

/// Reset a job's partial leader-side records and re-persist its seeds,
/// so its deterministic rerun on a new worker starts from exactly the
/// state the original create left — the same shared helpers the API
/// layer's recovery and `create_prepared` use, so the record shapes
/// cannot drift apart.
fn reset_and_reseed(inner: &LeaderInner, slot: &RemoteSlot, name: &str) {
    crate::api::reset_job_records(&inner.store, &inner.metrics, name);
    let transfer_json = if slot.spec.transfer.is_empty() {
        None
    } else {
        Some(crate::strategies::observations_to_json(&slot.spec.transfer))
    };
    crate::api::persist_job_seeds(&inner.store, &slot.spec.request, transfer_json);
    commit_wal(inner);
}

/// Declare worker `idx` dead and requeue its unfinished jobs.
///
/// Each job requeues from its last delta-acked v1 resume snapshot when
/// it has one and its leader-side record is still `InProgress` — the
/// snapshot is exactly the leader's applied state, so no records are
/// reset and the new worker resumes mid-flight with zero re-executed
/// proposals. Jobs with no acked checkpoint, or whose terminal slice's
/// delta landed but whose `PollResult` was lost (record already
/// terminal — resuming would double-apply the final slice), take the
/// scratch path: reset + reseed + deterministic replay from the seed.
///
/// `held` is the entry the dying driver had in flight (if any); jobs
/// parked in tenant quota queues are detected by elimination (assigned
/// to this lane, unfinished, no entry in the drained heap or in hand)
/// and only repaired in place — their parked entry re-routes to the new
/// lane at release time. The whole repair runs under the route lock, so
/// a concurrent death of another worker sees a consistent picture.
fn on_worker_death(inner: &LeaderInner, idx: usize, held: Option<QueueEntry>) {
    let _route = inner.route.lock().unwrap();
    let lane = &inner.lanes[idx];
    if !lane.alive.swap(false, Ordering::SeqCst) {
        return;
    }
    inner.live.fetch_sub(1, Ordering::SeqCst);
    let mut entries: Vec<QueueEntry> = {
        let mut heap = lane.heap.lock().unwrap();
        std::mem::take(&mut *heap).into_iter().map(|Reverse(e)| e).collect()
    };
    entries.extend(held);
    let entry_names: HashSet<String> = entries.iter().map(|e| e.name.clone()).collect();

    let slots: Vec<(String, Arc<RemoteSlot>)> = {
        let jobs = inner.jobs.lock().unwrap();
        jobs.iter().map(|(n, s)| (n.clone(), Arc::clone(s))).collect()
    };
    for (name, slot) in slots {
        if slot.lane.load(Ordering::SeqCst) != idx {
            continue;
        }
        if slot.state.lock().unwrap().outcome.is_some() {
            continue;
        }
        let record_in_progress = inner
            .store
            .get("tuning_jobs", &name)
            .and_then(|(_, j)| j.get("status").and_then(crate::json::Json::as_str).map(String::from))
            .is_some_and(|s| s == "InProgress");
        let has_snapshot = slot.last_ckpt.lock().unwrap().is_some();
        if has_snapshot && record_in_progress {
            // O(remaining) leg: leader state == snapshot state; the
            // re-Assign on the new lane ships the snapshot
            inner.snapshot_requeues.fetch_add(1, Ordering::Relaxed);
        } else {
            // scratch leg: reset partial records, reseed, replay
            *slot.last_ckpt.lock().unwrap() = None;
            inner.scratch_requeues.fetch_add(1, Ordering::Relaxed);
            inner.replayed_proposals.fetch_add(
                inner
                    .store
                    .list_keys("training_jobs", &format!("{name}-train-"))
                    .len() as u64,
                Ordering::Relaxed,
            );
            reset_and_reseed(inner, &slot, &name);
        }
        slot.started.store(false, Ordering::SeqCst);
        slot.stop_sent.store(false, Ordering::SeqCst);
        match pick_lane(inner, &slot.spec.backend) {
            Some(new_idx) => {
                lane.load.fetch_sub(1, Ordering::Relaxed);
                inner.lanes[new_idx].load.fetch_add(1, Ordering::Relaxed);
                slot.lane.store(new_idx, Ordering::SeqCst);
                if !entry_names.contains(&name) {
                    // parked in a quota queue: the release path will
                    // route its entry to the new lane
                    continue;
                }
                let entry = entries
                    .iter()
                    .position(|e| e.name == name)
                    .map(|i| entries.swap_remove(i))
                    .expect("entry present");
                repush_entry(inner, new_idx, entry);
            }
            None => mark_failed(inner, &slot, &name, "remote worker died with no replacement"),
        }
    }
}

/// Finish a quota-accounted slice and route any released parked entry
/// to its job's *current* lane (which may have changed under a death
/// repair since it was parked).
fn release_quota(inner: &LeaderInner, slot: &RemoteSlot) {
    let Some((tenant, _)) = &slot.quota else { return };
    let Some(d) = inner.quotas.release(tenant) else { return };
    let _route = inner.route.lock().unwrap();
    let target = {
        let jobs = inner.jobs.lock().unwrap();
        jobs.get(&d.name).map(|s| s.lane.load(Ordering::SeqCst))
    };
    match target {
        Some(idx) if idx != NO_LANE && inner.lanes[idx].alive.load(Ordering::SeqCst) => {
            repush_entry(inner, idx, QueueEntry { due: d.due, seq: d.seq, name: d.name });
        }
        _ => {} // job finished or failed meanwhile: entry is obsolete
    }
}

/// One driver: owns the transport to worker `idx` and drains that
/// worker's heap.
fn driver_loop(inner: &Arc<LeaderInner>, idx: usize, mut transport: Box<dyn Transport>) {
    // short receive slices keep shutdown and death detection responsive
    let slice = Duration::from_millis(20).min(inner.lease);
    let mut last_seen = Instant::now();
    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            let _ = transport.send(&Message::Drain);
            let _ = transport.recv(Duration::from_millis(200));
            return;
        }
        let popped = { inner.lanes[idx].heap.lock().unwrap().pop() };
        let Some(Reverse(entry)) = popped else {
            // idle: pump the link (heartbeats renew the lease)
            match transport.recv(slice) {
                Ok(Some(msg)) => {
                    last_seen = Instant::now();
                    if let Message::Hello { backend, .. } = &msg {
                        note_hello(inner, idx, backend);
                    }
                }
                Ok(None) => {
                    if last_seen.elapsed() > inner.lease {
                        on_worker_death(inner, idx, None);
                        return;
                    }
                }
                Err(_) => {
                    on_worker_death(inner, idx, None);
                    return;
                }
            }
            continue;
        };

        let slot = { inner.jobs.lock().unwrap().get(&entry.name).cloned() };
        let Some(slot) = slot else { continue };
        if slot.state.lock().unwrap().outcome.is_some() {
            continue; // already terminal: the entry is obsolete
        }
        let current_lane = slot.lane.load(Ordering::SeqCst);
        if current_lane != idx {
            // the job moved under a repair while this entry was in
            // flight between heaps: hand it to the owning lane
            if current_lane != NO_LANE {
                repush_entry(inner, current_lane, entry);
            }
            continue;
        }

        // tenant in-flight quota gate (shared semantics with the
        // in-process scheduler)
        let mut quota_held = false;
        if let Some((tenant, limit)) = &slot.quota {
            let admitted = inner.quotas.acquire(
                tenant,
                *limit,
                QueueEntry { due: entry.due, seq: entry.seq, name: entry.name.clone() },
            );
            if admitted.is_none() {
                continue;
            }
            quota_held = true;
        }

        // drive one slice: Assign (first time on this lane) → Stop (if
        // requested) → PollRequest → read delta(s) → PollResult
        let name = entry.name.clone();
        let result: std::io::Result<()> = (|| {
            if !slot.started.swap(true, Ordering::SeqCst) {
                // a repaired job carries its last delta-acked snapshot:
                // the new worker rebuilds the actor mid-flight instead
                // of replaying from the seed
                let resume = slot.last_ckpt.lock().unwrap().clone();
                transport.send(&Message::Assign {
                    request: slot.spec.request.clone(),
                    platform: slot.spec.platform.clone(),
                    transfer: slot.spec.transfer.clone(),
                    backend: slot.spec.backend.clone(),
                    resume,
                })?;
            }
            if slot.stop.load(Ordering::Relaxed)
                && !slot.stop_sent.swap(true, Ordering::SeqCst)
            {
                transport.send(&Message::Stop { job: name.clone() })?;
            }
            slot.polls.fetch_add(1, Ordering::Relaxed);
            transport.send(&Message::PollRequest {
                job: name.clone(),
                max_steps: inner.batch_steps,
            })
        })();
        if result.is_err() {
            if quota_held {
                release_quota(inner, &slot);
            }
            on_worker_death(inner, idx, Some(entry));
            return;
        }

        // await the slice's verdict, applying deltas as they arrive
        let mut sent_at = Instant::now();
        let reply = loop {
            if inner.shutdown.load(Ordering::SeqCst) {
                if quota_held {
                    release_quota(inner, &slot);
                }
                let _ = transport.send(&Message::Drain);
                return;
            }
            match transport.recv(slice) {
                Ok(Some(Message::StoreDelta { records, .. })) => {
                    last_seen = Instant::now();
                    sent_at = last_seen;
                    apply_delta(inner, &records);
                }
                Ok(Some(Message::PollResult { job, reply })) => {
                    last_seen = Instant::now();
                    if job == name {
                        break Ok(reply);
                    }
                    // out-of-band result (duplicate rejection): ignore
                }
                Ok(Some(msg)) => {
                    last_seen = Instant::now();
                    if let Message::Hello { backend, .. } = &msg {
                        note_hello(inner, idx, backend);
                    }
                }
                Ok(None) => {
                    // a worker mid-poll cannot heartbeat (single
                    // threaded), so the in-flight bound is the compute
                    // budget, not the idle lease
                    if sent_at.elapsed() > inner.poll_timeout {
                        break Err(());
                    }
                }
                Err(_) => break Err(()),
            }
        };
        match reply {
            Ok(PollReply::Pending { due }) => {
                push_lane_entry(inner, idx, due, slot.weight, name);
                if quota_held {
                    release_quota(inner, &slot);
                }
                commit_wal(inner);
            }
            Ok(PollReply::Complete(outcome)) => {
                if quota_held {
                    release_quota(inner, &slot);
                }
                // durability before acknowledgment, like the scheduler
                commit_wal(inner);
                publish(inner, &slot, *outcome);
            }
            Ok(PollReply::Rejected { reason }) => {
                if quota_held {
                    release_quota(inner, &slot);
                }
                mark_failed(inner, &slot, &name, &format!("worker rejected job: {reason}"));
            }
            Err(()) => {
                if quota_held {
                    release_quota(inner, &slot);
                }
                on_worker_death(inner, idx, Some(entry));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::worker::spawn_loopback_worker;

    fn spec(name: &str, evals: u32, seed: u64) -> RemoteJobSpec {
        RemoteJobSpec {
            request: TuningJobRequest {
                name: name.into(),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: evals,
                max_parallel_jobs: 2,
                seed,
                ..Default::default()
            },
            platform: PlatformConfig::noiseless(),
            transfer: Vec::new(),
            backend: "native".into(),
        }
    }

    fn pool(workers: usize) -> (RemoteWorkerPool, Vec<std::thread::JoinHandle<()>>) {
        let mut transports = Vec::new();
        let mut handles = Vec::new();
        for i in 0..workers {
            let (t, _fault, h) = spawn_loopback_worker(&format!("lead-{i}"));
            transports.push(t);
            handles.push(h);
        }
        let p = RemoteWorkerPool::new(
            transports,
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            None,
            RemoteConfig::default(),
        );
        (p, handles)
    }

    #[test]
    fn jobs_complete_through_remote_workers() {
        let (pool, handles) = pool(2);
        for i in 0..6u64 {
            assert!(pool.register(spec(&format!("r-{i}"), 3, i)));
            pool.activate(&format!("r-{i}"));
        }
        assert!(!pool.register(spec("r-0", 3, 0)), "duplicate names rejected");
        for i in 0..6u64 {
            let out = pool.wait(&format!("r-{i}")).unwrap();
            assert_eq!(out.evaluations.len(), 3);
            assert_eq!(out.status, ExecutionStatus::Succeeded);
        }
        assert_eq!(pool.running_jobs(), 0);
        assert_eq!(pool.worker_count(), 2);
        assert_eq!(pool.live_workers(), 2);
        assert!(pool.poll_count("r-0").unwrap() > 0);
        assert!(pool.poll_count("ghost").is_none());
        assert!(pool.wait("ghost").is_none());
        drop(pool);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn stop_reaches_remote_job() {
        let (pool, handles) = pool(1);
        assert!(pool.register(spec("stoppable", 10_000, 3)));
        pool.activate("stoppable");
        assert!(pool.stop("stoppable"));
        assert!(!pool.stop("ghost"));
        let out = pool.wait("stoppable").unwrap();
        assert!(out.evaluations.len() < 10_000);
        drop(pool);
        for h in handles {
            h.join().unwrap();
        }
    }

    /// Backend pinning: jobs route only to lanes advertising their
    /// backend; a job with no compatible worker fails loudly.
    #[test]
    fn backend_pinning_routes_and_fails_loudly() {
        use crate::distributed::worker::spawn_loopback_worker_with_backend;
        let (t_native, _f1, h1) = spawn_loopback_worker("bk-native");
        let (t_hlo, _f2, h2) = spawn_loopback_worker_with_backend("bk-hlo", "hlo");
        let pool = RemoteWorkerPool::new(
            vec![t_native, t_hlo],
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            None,
            RemoteConfig::default(),
        );
        assert!(pool.supports_backend("native"));
        assert!(pool.supports_backend("hlo"));
        assert!(!pool.supports_backend("tpu"));
        assert_eq!(
            pool.lane_backends(),
            vec![Some("native".to_string()), Some("hlo".to_string())]
        );

        let mut s = spec("pin-hlo", 3, 1);
        s.backend = "hlo".into();
        assert!(pool.register(s));
        pool.activate("pin-hlo");
        let out = pool.wait("pin-hlo").unwrap();
        assert_eq!(out.status, ExecutionStatus::Succeeded, "hlo lane must host the job");

        let mut s = spec("pin-nowhere", 2, 2);
        s.backend = "tpu".into();
        assert!(pool.register(s));
        pool.activate("pin-nowhere");
        let out = pool.wait("pin-nowhere").unwrap();
        assert!(
            matches!(out.status, ExecutionStatus::Failed(ref e) if e.contains("tpu")),
            "incompatible job must fail loudly, got {:?}",
            out.status
        );
        drop(pool);
        h1.join().unwrap();
        h2.join().unwrap();
    }

    #[test]
    fn unknown_objective_job_fails_loudly() {
        let (pool, handles) = pool(1);
        let mut s = spec("bad-objective", 3, 1);
        s.request.objective = "no-such-workload".into();
        assert!(pool.register(s));
        pool.activate("bad-objective");
        let out = pool.wait("bad-objective").unwrap();
        assert!(matches!(out.status, ExecutionStatus::Failed(_)));
        drop(pool);
        for h in handles {
            h.join().unwrap();
        }
    }
}
