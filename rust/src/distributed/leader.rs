//! Leader-side remote worker pool: the distributed counterpart of the
//! in-process [`crate::scheduler::Scheduler`].
//!
//! A [`RemoteWorkerPool`] exposes the same dispatch surface the
//! scheduler gives the API layer (`register` / `activate` / `stop` /
//! `wait` / `try_outcome` / `poll_count` / `running_jobs`), but instead
//! of polling actors on pool threads it drives one **driver thread per
//! worker connection**, each draining a per-worker virtual-time event
//! heap keyed exactly like the scheduler's (`(due ÷ tenant_weight,
//! seq)`) and speaking the [`super::proto`] protocol:
//!
//! ```text
//! pop job → [Assign once · Stop if requested · PollRequest] (one Batch)
//!        ← SliceResult (records applied, then the verdict:
//!                       Pending → requeue · Complete → publish)
//! ```
//!
//! Deltas are applied through the leader's ordinary batched mutation
//! paths (`store.put_batch` / `metrics.emit_batch`) — versions are
//! recomputed *at the leader*, so final store contents (values **and**
//! versions) are bit-identical to the same jobs run on the in-process
//! pool, and when a durability WAL is attached every applied record is
//! logged and group-committed per slice just like a local poll slice
//! would be (concurrent lane drivers share one write+fsync via the
//! WAL's group-commit ticket). Legacy workers reporting a slice as
//! `StoreDelta` + `PollResult` interoperate unchanged.
//!
//! **Leases.** A worker renews its lease with every message (heartbeats
//! while idle). A worker that stays silent past the lease — or whose
//! link errors — is declared dead and its unfinished jobs move to the
//! least-loaded live compatible worker. The repair is **O(remaining
//! work)** whenever possible: every `Pending` slice's delta carries the
//! job's v1 [`crate::coordinator::ResumeSnapshot`] checkpoint (appended
//! by the actor at the slice boundary, so delta application is atomic
//! per slice — the leader's store state always equals the last acked
//! checkpoint's), and the re-`Assign` ships that snapshot so the new
//! worker rebuilds the actor mid-flight. Jobs with no acked checkpoint
//! yet (or whose terminal slice was in flight) fall back to the PR 3
//! scratch path: partial leader records reset,
//! `warm_start`/`tuning_jobs` seeds re-persisted, deterministic replay
//! from the request seed. Both paths finish with exactly the records of
//! an uninterrupted run. With no live compatible workers left, jobs
//! fail loudly (outcome `Failed`, store record `Failed`) instead of
//! hanging.
//!
//! **Backend pinning.** Each worker's `Hello` advertises its surrogate
//! backend; each job's spec pins the backend it must evaluate on.
//! Routing (activation, death repair) only considers matching lanes, so
//! a mixed-backend fleet stays bit-consistent; the API layer checks
//! [`RemoteWorkerPool::supports_backend`] and keeps jobs local when no
//! compatible worker is live.
//!
//! **Elastic membership (DESIGN.md §13).** The lane table is dynamic:
//! workers are admitted mid-run ([`RemoteWorkerPool::add_worker`], or a
//! leader-side accept loop over a [`SocketListener`] via
//! [`RemoteWorkerPool::accept_workers`]), drained gracefully
//! ([`RemoteWorkerPool::drain_worker`] — every assigned job migrates at
//! the next slice boundary riding its retained resume snapshot, zero
//! re-executed proposals; with no surviving compatible lane jobs are
//! *parked*, snapshot kept, and resume at the next join), and
//! load-balanced by **work stealing**: when lane depths skew past a
//! threshold (and whenever a new worker's first `Hello` lands during an
//! ongoing run), queued jobs move from the deepest to the shallowest
//! compatible lane — the same snapshot-migration machinery as death
//! repair, minus the death. A `Hello` whose worker name is already
//! registered on a live lane is answered with [`Message::Deny`] and the
//! lane is retired. Liveness counters [`RemoteWorkerPool::joins`] /
//! [`RemoteWorkerPool::drains`] / [`RemoteWorkerPool::steals`] sit
//! alongside the repair counters.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::config::TuningJobRequest;
use crate::coordinator::TuningJobOutcome;
use crate::durability::wal::{Wal, WalRecord};
use crate::metrics::MetricsService;
use crate::platform::PlatformConfig;
use crate::scheduler::{QueueEntry, TenantQuotas};
use crate::store::{MetadataStore, StoreBatchOp};
use crate::strategies::Observation;
use crate::telemetry::{self, Counter, Gauge, Histogram, MetricSnapshot};
use crate::workflow::ExecutionStatus;

use super::proto::{Message, PollReply};
use super::transport::{SocketListener, Transport};

/// Knobs for the remote pool.
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// Max state-machine steps per remote poll slice (the scheduler's
    /// `batch_steps`, shipped in every `PollRequest`).
    pub batch_steps: usize,
    /// Worker lease: *idle* silence longer than this declares the
    /// worker dead and requeues its jobs. Workers heartbeat at a small
    /// fraction of the leader's lease (`DEFAULT_HEARTBEAT`).
    pub lease: Duration,
    /// Per-slice compute budget: how long a dispatched `PollRequest`
    /// may go unanswered before the worker is declared dead. Workers
    /// are single-threaded and cannot heartbeat mid-poll, so this must
    /// comfortably exceed the slowest slice (a large BO refit can take
    /// seconds) — it is a hang detector, not a latency bound. Link
    /// errors are still detected immediately.
    pub poll_timeout: Duration,
}

impl Default for RemoteConfig {
    fn default() -> Self {
        RemoteConfig {
            batch_steps: 256,
            lease: Duration::from_secs(5),
            poll_timeout: Duration::from_secs(120),
        }
    }
}

/// Everything the leader needs to (re)create one remote job: the
/// validated request, the platform configuration and the pre-resolved
/// warm-start observations. Kept for the job's lifetime so a worker
/// death can re-dispatch from scratch.
pub struct RemoteJobSpec {
    /// The accepted tuning-job request.
    pub request: TuningJobRequest,
    /// Leader's platform configuration (shipped to the worker).
    pub platform: PlatformConfig,
    /// Warm-start transfer observations resolved at create time.
    pub transfer: Vec<Observation>,
    /// Surrogate backend the job must evaluate on (lane routing key).
    pub backend: String,
}

#[derive(Default)]
struct SlotState {
    outcome: Option<TuningJobOutcome>,
}

struct RemoteSlot {
    spec: RemoteJobSpec,
    weight: f64,
    quota: Option<(String, usize)>,
    state: Mutex<SlotState>,
    done_cv: Condvar,
    stop: AtomicBool,
    /// Stop forwarded to the current worker incarnation.
    stop_sent: AtomicBool,
    /// Index of the worker lane hosting this job (usize::MAX = none).
    lane: AtomicUsize,
    /// Assign shipped to the current lane incarnation.
    started: AtomicBool,
    polls: AtomicU64,
    /// The job's last delta-acked v1 resume snapshot. Delta application
    /// is atomic per slice and every `Pending` slice ends with its
    /// checkpoint record, so whenever this is `Some`, the leader's
    /// store/metrics state for the job equals exactly this snapshot's —
    /// a worker death requeues from here with O(remaining work).
    last_ckpt: Mutex<Option<crate::json::Json>>,
    /// Queue entry of a job parked by a last-lane drain (no compatible
    /// lane left). The snapshot above is retained with it, so the next
    /// join resumes the job mid-flight instead of failing it.
    parked_entry: Mutex<Option<QueueEntry>>,
}

const NO_LANE: usize = usize::MAX;

struct WorkerLane {
    heap: Mutex<BinaryHeap<Reverse<QueueEntry>>>,
    alive: AtomicBool,
    /// Graceful-drain requested: routing skips this lane, and its own
    /// driver migrates every assigned job at the next slice boundary.
    draining: AtomicBool,
    /// Unfinished jobs assigned here (least-loaded placement heuristic).
    load: AtomicUsize,
    /// Wire protocol generation from the worker's `Hello` (1 until one
    /// arrives — the legacy two-message dialect, which cannot decode
    /// `Batch`). The driver only coalesces control bursts for ≥ 2.
    proto: AtomicU32,
}

/// Lane backends (from each worker's `Hello`), under one mutex with a
/// condvar so routing can wait for the fleet to identify itself.
struct LaneBackends {
    known: Mutex<Vec<Option<String>>>,
    hello_cv: Condvar,
}

struct LeaderInner {
    store: Arc<MetadataStore>,
    metrics: Arc<MetricsService>,
    wal: Option<Arc<Wal>>,
    batch_steps: usize,
    lease: Duration,
    poll_timeout: Duration,
    jobs: Mutex<HashMap<String, Arc<RemoteSlot>>>,
    /// Dynamic lane table: append-only (indices are stable for the
    /// pool's lifetime; dead/drained lanes stay as tombstones). Always
    /// the *first* lock acquired when combined with `backends.known` or
    /// `names` — snapshot and release before touching either.
    lanes: RwLock<Vec<Arc<WorkerLane>>>,
    backends: LaneBackends,
    /// Worker label per lane (from `Hello`): duplicate-name admission
    /// control for reconnecting workers.
    names: Mutex<Vec<Option<String>>>,
    live: AtomicUsize,
    running: AtomicUsize,
    shutdown: AtomicBool,
    seq: AtomicU64,
    quotas: TenantQuotas,
    /// This pool's metric registry (every counter/gauge/histogram below
    /// is a handle into it, under `leader.*` names). Per-instance, never
    /// global: tests assert exact counts on isolated pools.
    telemetry: telemetry::Registry,
    /// Worker-death repairs that requeued from a delta-acked snapshot
    /// (O(remaining)) vs from scratch, and — for the scratch leg — how
    /// many already-proposed evaluations the rerun re-executes.
    /// Registry names: `leader.snapshot_requeues` /
    /// `leader.scratch_requeues` / `leader.replayed_proposals`.
    snapshot_requeues: Arc<Counter>,
    scratch_requeues: Arc<Counter>,
    replayed_proposals: Arc<Counter>,
    /// Group commits that failed even after a retry (mirrors
    /// `Scheduler::wal_commit_errors` for the remote plane).
    /// Registry name: `leader.wal_commit_errors`.
    wal_commit_errors: Arc<Counter>,
    /// Worker→leader slice-carrying messages received (`SliceResult`,
    /// plus legacy `StoreDelta` / `PollResult`). Against `polls_sent`
    /// this is the throughput plane's frames-per-slice observable:
    /// coalesced workers hold it at ~1 per slice, two-message workers
    /// at ~2. Registry name: `leader.slice_messages`.
    slice_messages: Arc<Counter>,
    /// Poll slices dispatched across all jobs (pool-wide denominator
    /// for `slice_messages`). Registry name: `leader.polls_dispatched`.
    polls_sent: Arc<Counter>,
    /// Dispatch→verdict round-trip latency per slice (µs), recorded on
    /// every slice that returns a verdict. Registry name:
    /// `leader.rtt_us`.
    rtt_us: Arc<Histogram>,
    /// Invoked after every successful WAL group commit (the durable
    /// service's auto-checkpoint trigger — same hook as the scheduler's,
    /// so the WAL stays bounded no matter which plane commits).
    post_commit: std::sync::OnceLock<Arc<dyn Fn() + Send + Sync>>,
    /// Elastic-fleet liveness counters: workers admitted after
    /// construction, lanes drained gracefully to completion, and queued
    /// jobs migrated by the work-stealing rebalancer. Registry names:
    /// `leader.joins` / `leader.drains` / `leader.steals`.
    joins: Arc<Counter>,
    drains: Arc<Counter>,
    steals: Arc<Counter>,
    /// Jobs parked with no compatible lane (drain-of-last-lane): the
    /// rebalancer's cheap "is there orphaned work" signal. All
    /// mutations happen under the `route` lock (whose release fences
    /// them); the lock-free read in `needs_rebalance` is a tolerant
    /// pre-check. Registry name: `leader.parked_jobs`.
    parked_jobs: Arc<Gauge>,
    /// Serializes placement decisions: activation, death repair,
    /// drain migration, work stealing and quota-release routing, so
    /// concurrent worker deaths cannot strand or duplicate a job's
    /// single heap entry.
    route: Mutex<()>,
    /// Driver + accept-loop join handles (here rather than on the pool
    /// so the accept loop and `add_worker` can register new drivers).
    drivers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// The leader-side remote execution plane.
pub struct RemoteWorkerPool {
    inner: Arc<LeaderInner>,
}

impl RemoteWorkerPool {
    /// Start one driver thread per connected worker transport. Deltas
    /// apply into `store`/`metrics`; when `wal` is given, every applied
    /// record is logged and group-committed per slice.
    pub fn new(
        transports: Vec<Box<dyn Transport>>,
        store: Arc<MetadataStore>,
        metrics: Arc<MetricsService>,
        wal: Option<Arc<Wal>>,
        config: RemoteConfig,
    ) -> RemoteWorkerPool {
        let reg = telemetry::Registry::new();
        let inner = Arc::new(LeaderInner {
            store,
            metrics,
            wal,
            batch_steps: config.batch_steps.max(1),
            lease: config.lease,
            poll_timeout: config.poll_timeout.max(config.lease),
            jobs: Mutex::new(HashMap::new()),
            backends: LaneBackends {
                known: Mutex::new(Vec::new()),
                hello_cv: Condvar::new(),
            },
            lanes: RwLock::new(Vec::new()),
            names: Mutex::new(Vec::new()),
            live: AtomicUsize::new(0),
            running: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            quotas: TenantQuotas::new(),
            snapshot_requeues: reg.counter("leader.snapshot_requeues"),
            scratch_requeues: reg.counter("leader.scratch_requeues"),
            replayed_proposals: reg.counter("leader.replayed_proposals"),
            wal_commit_errors: reg.counter("leader.wal_commit_errors"),
            slice_messages: reg.counter("leader.slice_messages"),
            polls_sent: reg.counter("leader.polls_dispatched"),
            rtt_us: reg.histogram("leader.rtt_us"),
            joins: reg.counter("leader.joins"),
            drains: reg.counter("leader.drains"),
            steals: reg.counter("leader.steals"),
            parked_jobs: reg.gauge("leader.parked_jobs"),
            telemetry: reg,
            post_commit: std::sync::OnceLock::new(),
            route: Mutex::new(()),
            drivers: Mutex::new(Vec::new()),
        });
        for transport in transports {
            admit_worker(&inner, transport, false);
        }
        RemoteWorkerPool { inner }
    }

    /// Lanes ever part of this pool (including dead/drained tombstones).
    pub fn worker_count(&self) -> usize {
        self.inner.lanes.read().unwrap().len()
    }

    /// Workers whose lease is still good.
    pub fn live_workers(&self) -> usize {
        self.inner.live.load(Ordering::Relaxed)
    }

    /// Jobs registered and not yet finished.
    pub fn running_jobs(&self) -> usize {
        self.inner.running.load(Ordering::Relaxed)
    }

    /// True if a job with this name was ever registered.
    pub fn contains(&self, name: &str) -> bool {
        self.inner.jobs.lock().unwrap().contains_key(name)
    }

    /// Poll slices dispatched for the named job (`None` for unknown).
    pub fn poll_count(&self, name: &str) -> Option<u64> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        Some(slot.polls.load(Ordering::Relaxed))
    }

    /// Highest concurrent slice count the named tenant ever reached.
    pub fn tenant_high_water(&self, tenant: &str) -> usize {
        self.inner.quotas.high_water(tenant)
    }

    /// WAL group commits that failed even after a retry (records stay
    /// buffered in the WAL and retry at later slices — alert on this,
    /// exactly like `Scheduler::wal_commit_errors`). Shim over registry
    /// metric `leader.wal_commit_errors`; prefer
    /// [`RemoteWorkerPool::telemetry_metrics`].
    pub fn wal_commit_errors(&self) -> u64 {
        self.inner.wal_commit_errors.get()
    }

    /// Worker→leader slice-carrying messages received across the pool's
    /// lifetime (one per `SliceResult`; legacy workers contribute one
    /// per `StoreDelta` *and* one per `PollResult`). Shim over registry
    /// metric `leader.slice_messages`.
    pub fn slice_messages(&self) -> u64 {
        self.inner.slice_messages.get()
    }

    /// Poll slices dispatched across all jobs — divide
    /// [`RemoteWorkerPool::slice_messages`] by this for the pool's
    /// frames-per-slice ratio (~1 coalesced, ~2 legacy). Shim over
    /// registry metric `leader.polls_dispatched`.
    pub fn polls_dispatched(&self) -> u64 {
        self.inner.polls_sent.get()
    }

    /// Worker-death repairs that requeued a job from its last
    /// delta-acked resume snapshot (the O(remaining-work) path). Shim
    /// over registry metric `leader.snapshot_requeues`.
    pub fn snapshot_requeues(&self) -> u64 {
        self.inner.snapshot_requeues.get()
    }

    /// Worker-death repairs that fell back to reset + replay-from-seed.
    /// Shim over registry metric `leader.scratch_requeues`.
    pub fn scratch_requeues(&self) -> u64 {
        self.inner.scratch_requeues.get()
    }

    /// Strategy proposals re-executed across all scratch requeues (the
    /// evaluations that already existed when the worker died; snapshot
    /// requeues contribute 0 by construction). Shim over registry
    /// metric `leader.replayed_proposals`.
    pub fn replayed_proposals(&self) -> u64 {
        self.inner.replayed_proposals.get()
    }

    /// Point-in-time snapshot of this pool's metric registry (names
    /// under `leader.*`, including the `leader.rtt_us` dispatch→verdict
    /// latency histogram) — one part of
    /// [`crate::api::AmtService::telemetry_snapshot`].
    pub fn telemetry_metrics(&self) -> Vec<MetricSnapshot> {
        self.inner.telemetry.snapshot()
    }

    /// True when at least one live worker advertises `backend` — the
    /// API layer's routing gate (jobs stay on the local plane
    /// otherwise). Waits briefly (up to the lease) for lanes that have
    /// not sent their `Hello` yet, so a just-constructed pool answers
    /// correctly.
    pub fn supports_backend(&self, backend: &str) -> bool {
        await_hellos(&self.inner);
        let lanes = lanes_snapshot(&self.inner);
        let known = self.inner.backends.known.lock().unwrap();
        lanes.iter().enumerate().any(|(i, l)| {
            l.alive.load(Ordering::SeqCst)
                && !l.draining.load(Ordering::SeqCst)
                && known.get(i).and_then(|b| b.as_deref()) == Some(backend)
        })
    }

    /// Advertised backend of each lane (`None` = no `Hello` yet).
    pub fn lane_backends(&self) -> Vec<Option<String>> {
        self.inner.backends.known.lock().unwrap().clone()
    }

    /// Admit a new worker transport into the fleet mid-run: a fresh
    /// lane with its own heap and driver thread. Routing considers the
    /// lane as soon as its `Hello` lands, and that first `Hello` also
    /// triggers a rebalance so an ongoing run's queued and parked jobs
    /// move onto the new capacity immediately. Returns the lane index.
    pub fn add_worker(&self, transport: Box<dyn Transport>) -> usize {
        admit_worker(&self.inner, transport, true)
    }

    /// Gracefully drain worker `idx`: its driver migrates every
    /// assigned job to surviving compatible lanes at the next slice
    /// boundary (each rides its retained resume snapshot — zero
    /// re-executed proposals), sends `Drain` so the worker session ends
    /// cleanly, and retires the lane. With no surviving compatible lane
    /// the jobs are *parked* (snapshot kept) and resume at the next
    /// join — never failed. Returns false for an unknown or already
    /// dead lane.
    pub fn drain_worker(&self, idx: usize) -> bool {
        let lanes = self.inner.lanes.read().unwrap();
        let Some(lane) = lanes.get(idx) else { return false };
        if !lane.alive.load(Ordering::SeqCst) {
            return false;
        }
        lane.draining.store(true, Ordering::SeqCst);
        true
    }

    /// Dynamic-membership accept loop: admit every connection arriving
    /// on `listener` as a new worker lane until the pool shuts down.
    pub fn accept_workers(&self, listener: SocketListener) {
        let inner = Arc::clone(&self.inner);
        let handle = std::thread::Builder::new()
            .name("amt-lead-accept".into())
            .spawn(move || loop {
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept_timeout(Duration::from_millis(200)) {
                    Ok(Some(t)) => {
                        admit_worker(&inner, Box::new(t), true);
                    }
                    Ok(None) => {}
                    Err(_) => return,
                }
            })
            .expect("failed to spawn leader accept loop");
        self.inner.drivers.lock().unwrap().push(handle);
    }

    /// Workers admitted after construction (late joins). Shim over
    /// registry metric `leader.joins`.
    pub fn joins(&self) -> u64 {
        self.inner.joins.get()
    }

    /// Lanes drained gracefully to completion. Shim over registry
    /// metric `leader.drains`.
    pub fn drains(&self) -> u64 {
        self.inner.drains.get()
    }

    /// Queued jobs migrated between lanes by the work-stealing
    /// rebalancer (each rides its snapshot: zero re-executed
    /// proposals). Shim over registry metric `leader.steals`.
    pub fn steals(&self) -> u64 {
        self.inner.steals.get()
    }

    /// Install a hook invoked after every successful WAL group commit
    /// on this plane (at most once; later calls no-op). The durable API
    /// layer installs the same auto-checkpoint trigger it gives the
    /// scheduler, so `DurabilityOptions::auto_checkpoint_bytes` bounds
    /// the log regardless of which plane does the committing.
    pub fn set_post_commit(&self, hook: Arc<dyn Fn() + Send + Sync>) {
        let _ = self.inner.post_commit.set(hook);
    }

    /// Reserve a job name without queueing it (the API layer persists
    /// the accepted request in between, exactly like the in-process
    /// scheduler's register/activate split). False if taken.
    pub fn register(&self, spec: RemoteJobSpec) -> bool {
        let name = spec.request.name.clone();
        let weight = spec.request.tenant_weight.max(1) as f64;
        let quota = if spec.request.tenant.is_empty() {
            None
        } else {
            Some((spec.request.tenant.clone(), spec.request.max_in_flight as usize))
        };
        let mut jobs = self.inner.jobs.lock().unwrap();
        if jobs.contains_key(&name) {
            return false;
        }
        jobs.insert(
            name.clone(),
            Arc::new(RemoteSlot {
                spec,
                weight,
                quota,
                state: Mutex::new(SlotState::default()),
                done_cv: Condvar::new(),
                stop: AtomicBool::new(false),
                stop_sent: AtomicBool::new(false),
                lane: AtomicUsize::new(NO_LANE),
                started: AtomicBool::new(false),
                polls: AtomicU64::new(0),
                last_ckpt: Mutex::new(None),
                parked_entry: Mutex::new(None),
            }),
        );
        drop(jobs);
        self.inner.running.fetch_add(1, Ordering::Relaxed);
        // mint the job's trace id at submission: the `propose` phase is
        // the lifecycle anchor every later wire-carried phase hangs off
        telemetry::trace::ensure_trace(&name);
        true
    }

    /// Place a registered job on the least-loaded live worker running a
    /// compatible backend and queue it. Must be called exactly once per
    /// registered job.
    pub fn activate(&self, name: &str) {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() };
        let Some(slot) = slot else { return };
        await_hellos(&self.inner);
        let _route = self.inner.route.lock().unwrap();
        match pick_lane(&self.inner, &slot.spec.backend) {
            Some(idx) => {
                slot.lane.store(idx, Ordering::SeqCst);
                lane(&self.inner, idx).load.fetch_add(1, Ordering::Relaxed);
                push_lane_entry(&self.inner, idx, 0.0, slot.weight, name.to_string());
            }
            None => mark_failed(
                &self.inner,
                &slot,
                name,
                &format!("no live remote workers for backend '{}'", slot.spec.backend),
            ),
        }
    }

    /// Signal a job to stop at its next scheduling point.
    pub fn stop(&self, name: &str) -> bool {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() };
        match slot {
            Some(slot) => {
                slot.stop.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Block until the named job finishes; `None` for unknown names.
    pub fn wait(&self, name: &str) -> Option<TuningJobOutcome> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        let mut state = slot.state.lock().unwrap();
        while state.outcome.is_none() {
            state = slot.done_cv.wait(state).unwrap();
        }
        state.outcome.clone()
    }

    /// Non-blocking probe for a finished outcome.
    pub fn try_outcome(&self, name: &str) -> Option<TuningJobOutcome> {
        let slot = { self.inner.jobs.lock().unwrap().get(name).cloned() }?;
        let state = slot.state.lock().unwrap();
        state.outcome.clone()
    }
}

impl Drop for RemoteWorkerPool {
    fn drop(&mut self) {
        // drivers poll the shutdown flag between receive slices
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // the accept loop may still be admitting (and registering new
        // driver handles): keep draining until the vec stays empty
        loop {
            let drivers = std::mem::take(&mut *self.inner.drivers.lock().unwrap());
            if drivers.is_empty() {
                return;
            }
            for d in drivers {
                let _ = d.join();
            }
        }
    }
}

/// Clone the lane handle at `idx`. Lanes are append-only, so indices
/// handed to drivers stay valid for the pool's lifetime.
fn lane(inner: &LeaderInner, idx: usize) -> Arc<WorkerLane> {
    Arc::clone(&inner.lanes.read().unwrap()[idx])
}

/// Snapshot the lane table, dropping the lanes lock before the caller
/// acquires any other (lanes is always the outermost of the routing
/// locks — see the field docs).
fn lanes_snapshot(inner: &LeaderInner) -> Vec<Arc<WorkerLane>> {
    inner.lanes.read().unwrap().clone()
}

/// Append a new lane + driver thread for `transport`. `late` admissions
/// (post-construction joins) count in the `joins` liveness counter.
fn admit_worker(inner: &Arc<LeaderInner>, transport: Box<dyn Transport>, late: bool) -> usize {
    let idx = {
        let mut lanes = inner.lanes.write().unwrap();
        lanes.push(Arc::new(WorkerLane {
            heap: Mutex::new(BinaryHeap::new()),
            alive: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            load: AtomicUsize::new(0),
            proto: AtomicU32::new(1),
        }));
        lanes.len() - 1
    };
    {
        let mut known = inner.backends.known.lock().unwrap();
        if known.len() <= idx {
            known.resize(idx + 1, None);
        }
    }
    {
        let mut names = inner.names.lock().unwrap();
        if names.len() <= idx {
            names.resize(idx + 1, None);
        }
    }
    inner.live.fetch_add(1, Ordering::SeqCst);
    if late {
        inner.joins.inc();
    }
    let handle = {
        let inner = Arc::clone(inner);
        std::thread::Builder::new()
            .name(format!("amt-lead-{idx}"))
            .spawn(move || driver_loop(&inner, idx, transport))
            .expect("failed to spawn leader driver")
    };
    inner.drivers.lock().unwrap().push(handle);
    idx
}

/// Take lane `idx` out of the fleet (idempotent).
fn retire_lane(inner: &LeaderInner, idx: usize) {
    if lane(inner, idx).alive.swap(false, Ordering::SeqCst) {
        inner.live.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Block (bounded by the lease) until every live lane has identified
/// its backend via `Hello` — one-time per admission; a no-op after.
fn await_hellos(inner: &LeaderInner) {
    let deadline = Instant::now() + inner.lease;
    loop {
        let lanes = lanes_snapshot(inner);
        let known = inner.backends.known.lock().unwrap();
        let pending = lanes.iter().enumerate().any(|(i, l)| {
            l.alive.load(Ordering::SeqCst)
                && known.get(i).map_or(true, Option::is_none)
        });
        if !pending || Instant::now() >= deadline {
            return;
        }
        let _unused = inner
            .backends
            .hello_cv
            .wait_timeout(known, Duration::from_millis(20))
            .unwrap();
    }
}

/// Verdict of a worker's `Hello` under dynamic membership.
enum HelloVerdict {
    /// Recorded; `first` marks the lane's first hello (join complete).
    Accepted { first: bool },
    /// Another live lane already registered this worker name.
    Duplicate,
}

/// Record a worker's label, advertised backend and wire protocol
/// generation, and wake routing waiters; rejects a name already held by
/// a different live lane.
fn note_hello(
    inner: &LeaderInner,
    idx: usize,
    worker: &str,
    backend: &str,
    proto: u32,
) -> HelloVerdict {
    let lanes = lanes_snapshot(inner);
    if let Some(l) = lanes.get(idx) {
        l.proto.store(proto.max(1), Ordering::SeqCst);
    }
    {
        let mut names = inner.names.lock().unwrap();
        let duplicate = names.iter().enumerate().any(|(i, n)| {
            i != idx
                && n.as_deref() == Some(worker)
                && lanes.get(i).is_some_and(|l| l.alive.load(Ordering::SeqCst))
        });
        if duplicate {
            return HelloVerdict::Duplicate;
        }
        if names.len() <= idx {
            names.resize(idx + 1, None);
        }
        names[idx] = Some(worker.to_string());
    }
    let first = {
        let mut known = inner.backends.known.lock().unwrap();
        if known.len() <= idx {
            known.resize(idx + 1, None);
        }
        let first = known[idx].is_none();
        if known[idx].as_deref() != Some(backend) {
            known[idx] = Some(backend.to_string());
        }
        first
    };
    inner.backends.hello_cv.notify_all();
    HelloVerdict::Accepted { first }
}

/// Least-loaded live non-draining lane whose worker runs `backend`.
fn pick_lane(inner: &LeaderInner, backend: &str) -> Option<usize> {
    let lanes = lanes_snapshot(inner);
    let known = inner.backends.known.lock().unwrap();
    lanes
        .iter()
        .enumerate()
        .filter(|(i, l)| {
            l.alive.load(Ordering::SeqCst)
                && !l.draining.load(Ordering::SeqCst)
                && known.get(*i).and_then(|b| b.as_deref()) == Some(backend)
        })
        .min_by_key(|(_, l)| l.load.load(Ordering::Relaxed))
        .map(|(i, _)| i)
}

/// Queue `(due / weight, seq, name)` on a lane's heap (same key as the
/// in-process scheduler's `push_entry`).
fn push_lane_entry(inner: &LeaderInner, idx: usize, due: f64, weight: f64, name: String) {
    let seq = inner.seq.fetch_add(1, Ordering::Relaxed);
    let due = due / weight.max(1.0);
    lane(inner, idx).heap.lock().unwrap().push(Reverse(QueueEntry { due, seq, name }));
}

/// Re-push an already-discounted entry (quota release, death repair,
/// drain migration, steal).
fn repush_entry(inner: &LeaderInner, idx: usize, entry: QueueEntry) {
    lane(inner, idx).heap.lock().unwrap().push(Reverse(entry));
}

/// Flush the batched-application runs accumulated by [`apply_delta`]:
/// the pending store ops as one [`MetadataStore::put_batch`], then the
/// pending metric points as one [`MetricsService::emit_batch`]. Store
/// and metrics are disjoint state spaces and each run preserves its own
/// per-key / per-stream input order, so flushing the two runs
/// back-to-back is state-identical to the interleaved per-record
/// application a delta used to get.
fn flush_delta_runs<'a>(
    inner: &LeaderInner,
    store_ops: &mut Vec<StoreBatchOp<'a>>,
    emits: &mut Vec<(&'a str, f64, f64)>,
) {
    if !store_ops.is_empty() {
        inner.store.put_batch(store_ops);
        store_ops.clear();
    }
    if !emits.is_empty() {
        inner.metrics.emit_batch(emits);
        emits.clear();
    }
}

/// Apply one delta through the leader's ordinary mutation paths:
/// versions are recomputed here, WAL records (when attached) are
/// appended inside the store/metrics critical sections, and worker
/// checkpoints are re-logged verbatim — the "existing durability commit
/// path" of DESIGN.md §11. v1 resume-snapshot checkpoints are also
/// retained per job: they are what a worker-death repair requeues from.
///
/// Application is **batched**: consecutive puts/deletes and emits
/// accumulate into runs applied via `put_batch` / `emit_batch` — one
/// shard-lock acquisition per touched shard per run instead of one per
/// record. `RemoveStreams` and `Checkpoint` are barriers (a removal must
/// observe the emits before it; a checkpoint must be logged after the
/// records it covers), so runs flush there and at the end of the delta.
fn apply_delta(inner: &LeaderInner, records: &[(u64, WalRecord)]) {
    let mut store_ops: Vec<StoreBatchOp<'_>> = Vec::new();
    let mut emits: Vec<(&str, f64, f64)> = Vec::new();
    for (_, rec) in records {
        match rec {
            WalRecord::Put { table, key, value, .. } => {
                store_ops.push(StoreBatchOp::Put { table, key, value });
            }
            WalRecord::Delete { table, key } => {
                store_ops.push(StoreBatchOp::Delete { table, key });
            }
            WalRecord::Emit { stream, time, value } => {
                emits.push((stream, *time, *value));
            }
            WalRecord::RemoveStreams { prefix } => {
                flush_delta_runs(inner, &mut store_ops, &mut emits);
                inner.metrics.remove_streams(prefix);
            }
            WalRecord::Checkpoint { job, exec } => {
                flush_delta_runs(inner, &mut store_ops, &mut emits);
                if let Some(w) = &inner.wal {
                    w.append(rec);
                }
                if crate::coordinator::is_resume_snapshot(exec) {
                    let slot = { inner.jobs.lock().unwrap().get(job).cloned() };
                    if let Some(slot) = slot {
                        *slot.last_ckpt.lock().unwrap() = Some(exec.clone());
                    }
                }
            }
        }
    }
    flush_delta_runs(inner, &mut store_ops, &mut emits);
}

/// Group-commit the attached WAL through the shared durability helper —
/// the in-process scheduler's exact semantics (retry a failed commit
/// once, count persistent failures while the records stay buffered and
/// retry at later slices, run the post-commit auto-checkpoint hook after
/// success). Concurrent lane drivers committing here piggyback on one
/// in-flight write+fsync ([`Wal::commit`]'s group-commit ticket).
fn commit_wal(inner: &LeaderInner) {
    if let Some(w) = &inner.wal {
        crate::durability::commit_with_retry(
            w,
            inner.wal_commit_errors.as_atomic(),
            inner.post_commit.get(),
        );
    }
}

/// Publish a terminal outcome and wake waiters (idempotent: a second
/// terminal verdict for the same job changes nothing).
fn publish(inner: &LeaderInner, slot: &RemoteSlot, outcome: TuningJobOutcome) {
    let mut state = slot.state.lock().unwrap();
    if state.outcome.is_some() {
        return;
    }
    let lane_idx = slot.lane.swap(NO_LANE, Ordering::SeqCst);
    if lane_idx != NO_LANE {
        lane(inner, lane_idx).load.fetch_sub(1, Ordering::Relaxed);
    }
    inner.running.fetch_sub(1, Ordering::Relaxed);
    state.outcome = Some(outcome);
    drop(state);
    slot.done_cv.notify_all();
}

/// Fail a job loudly: `Failed` store record (commit included) plus a
/// `Failed` outcome for waiters.
fn mark_failed(inner: &LeaderInner, slot: &RemoteSlot, name: &str, reason: &str) {
    crate::api::persist_job_failed(&inner.store, name, slot.spec.request.to_json(), reason);
    commit_wal(inner);
    publish(
        inner,
        slot,
        TuningJobOutcome {
            name: name.to_string(),
            evaluations: Vec::new(),
            best: None,
            total_seconds: 0.0,
            total_billable_seconds: 0.0,
            status: ExecutionStatus::Failed(reason.to_string()),
            retries: 0,
        },
    );
}

/// Reset a job's partial leader-side records and re-persist its seeds,
/// so its deterministic rerun on a new worker starts from exactly the
/// state the original create left — the same shared helpers the API
/// layer's recovery and `create_prepared` use, so the record shapes
/// cannot drift apart.
fn reset_and_reseed(inner: &LeaderInner, slot: &RemoteSlot, name: &str) {
    {
        // reset deletes + reseed puts land as one atomic WAL unit: a
        // concurrent commit (another lane's slice) cannot persist the
        // deletes without the re-creates (the torn-reset bug)
        let _unit = inner.wal.as_ref().map(|w| w.begin_unit());
        crate::api::reset_job_records(&inner.store, &inner.metrics, name);
        let transfer_json = if slot.spec.transfer.is_empty() {
            None
        } else {
            Some(crate::strategies::observations_to_json(&slot.spec.transfer))
        };
        crate::api::persist_job_seeds(&inner.store, &slot.spec.request, transfer_json);
        // unit guard drops here, before this thread's own commit
    }
    commit_wal(inner);
}

/// Declare worker `idx` dead and requeue its unfinished jobs.
///
/// Each job requeues from its last delta-acked v1 resume snapshot when
/// it has one and its leader-side record is still `InProgress` — the
/// snapshot is exactly the leader's applied state, so no records are
/// reset and the new worker resumes mid-flight with zero re-executed
/// proposals. Jobs with no acked checkpoint, or whose terminal slice's
/// delta landed but whose `PollResult` was lost (record already
/// terminal — resuming would double-apply the final slice), take the
/// scratch path: reset + reseed + deterministic replay from the seed.
///
/// `held` is the entry the dying driver had in flight (if any); jobs
/// parked in tenant quota queues are detected by elimination (assigned
/// to this lane, unfinished, no entry in the drained heap or in hand)
/// and only repaired in place — their parked entry re-routes to the new
/// lane at release time. The whole repair runs under the route lock, so
/// a concurrent death of another worker sees a consistent picture.
fn on_worker_death(inner: &LeaderInner, idx: usize, held: Option<QueueEntry>) {
    let _route = inner.route.lock().unwrap();
    let lane_ref = lane(inner, idx);
    if !lane_ref.alive.swap(false, Ordering::SeqCst) {
        return;
    }
    inner.live.fetch_sub(1, Ordering::SeqCst);
    let mut entries: Vec<QueueEntry> = {
        let mut heap = lane_ref.heap.lock().unwrap();
        std::mem::take(&mut *heap).into_iter().map(|Reverse(e)| e).collect()
    };
    entries.extend(held);
    let entry_names: HashSet<String> = entries.iter().map(|e| e.name.clone()).collect();

    let slots: Vec<(String, Arc<RemoteSlot>)> = {
        let jobs = inner.jobs.lock().unwrap();
        jobs.iter().map(|(n, s)| (n.clone(), Arc::clone(s))).collect()
    };
    for (name, slot) in slots {
        if slot.lane.load(Ordering::SeqCst) != idx {
            continue;
        }
        if slot.state.lock().unwrap().outcome.is_some() {
            continue;
        }
        let record_in_progress = inner
            .store
            .get("tuning_jobs", &name)
            .and_then(|(_, j)| j.get("status").and_then(crate::json::Json::as_str).map(String::from))
            .is_some_and(|s| s == "InProgress");
        let has_snapshot = slot.last_ckpt.lock().unwrap().is_some();
        if has_snapshot && record_in_progress {
            // O(remaining) leg: leader state == snapshot state; the
            // re-Assign on the new lane ships the snapshot
            inner.snapshot_requeues.inc();
        } else {
            // scratch leg: reset partial records, reseed, replay
            *slot.last_ckpt.lock().unwrap() = None;
            inner.scratch_requeues.inc();
            inner.replayed_proposals.add(
                inner
                    .store
                    .list_keys("training_jobs", &format!("{name}-train-"))
                    .len() as u64,
            );
            reset_and_reseed(inner, &slot, &name);
        }
        slot.started.store(false, Ordering::SeqCst);
        slot.stop_sent.store(false, Ordering::SeqCst);
        match pick_lane(inner, &slot.spec.backend) {
            Some(new_idx) => {
                lane_ref.load.fetch_sub(1, Ordering::Relaxed);
                lane(inner, new_idx).load.fetch_add(1, Ordering::Relaxed);
                slot.lane.store(new_idx, Ordering::SeqCst);
                if !entry_names.contains(&name) {
                    // parked in a quota queue: the release path will
                    // route its entry to the new lane
                    continue;
                }
                let entry = entries
                    .iter()
                    .position(|e| e.name == name)
                    .map(|i| entries.swap_remove(i))
                    .expect("entry present");
                repush_entry(inner, new_idx, entry);
            }
            None => mark_failed(inner, &slot, &name, "remote worker died with no replacement"),
        }
    }
}

/// Finish a quota-accounted slice and route any released parked entry
/// to its job's *current* lane (which may have changed under a death
/// repair, drain or steal since it was parked). A released job left
/// laneless by a last-lane drain is parked on its slot instead, so the
/// next join can resume it.
fn release_quota(inner: &LeaderInner, slot: &RemoteSlot) {
    let Some((tenant, _)) = &slot.quota else { return };
    let Some(d) = inner.quotas.release(tenant) else { return };
    let _route = inner.route.lock().unwrap();
    let released = {
        let jobs = inner.jobs.lock().unwrap();
        jobs.get(&d.name).map(Arc::clone)
    };
    let Some(released) = released else { return };
    let idx = released.lane.load(Ordering::SeqCst);
    let entry = QueueEntry { due: d.due, seq: d.seq, name: d.name };
    if idx != NO_LANE && lane(inner, idx).alive.load(Ordering::SeqCst) {
        repush_entry(inner, idx, entry);
    } else if idx == NO_LANE && released.state.lock().unwrap().outcome.is_none() {
        // drained off its lane while quota-parked: keep it parked
        *released.parked_entry.lock().unwrap() = Some(entry);
        inner.parked_jobs.add(1);
    }
    // otherwise the job finished or failed meanwhile: entry is obsolete
}

/// Load skew that triggers a steal: deepest minus shallowest eligible
/// lane must differ by at least a whole job beyond rounding.
const STEAL_THRESHOLD: usize = 2;

/// Cheap pre-check for the idle-driver rebalance trigger: parked work
/// exists, or eligible lane depths skew past [`STEAL_THRESHOLD`].
fn needs_rebalance(inner: &LeaderInner) -> bool {
    if inner.parked_jobs.get() > 0 {
        return true;
    }
    let lanes = lanes_snapshot(inner);
    let known = inner.backends.known.lock().unwrap();
    let mut min = usize::MAX;
    let mut max = 0usize;
    for (i, l) in lanes.iter().enumerate() {
        if !l.alive.load(Ordering::SeqCst)
            || l.draining.load(Ordering::SeqCst)
            || known.get(i).map_or(true, Option::is_none)
        {
            continue;
        }
        let load = l.load.load(Ordering::Relaxed);
        min = min.min(load);
        max = max.max(load);
    }
    min != usize::MAX && max >= min + STEAL_THRESHOLD
}

/// Place parked jobs, then steal queued jobs from the deepest lane to
/// the shallowest until depths are within [`STEAL_THRESHOLD`]. Runs
/// when a new worker's first `Hello` lands and from idle drivers when
/// [`needs_rebalance`] fires.
fn rebalance(inner: &LeaderInner) {
    let _route = inner.route.lock().unwrap();
    place_orphans_locked(inner);
    // bounded: each iteration migrates exactly one job
    for _ in 0..64 {
        if !steal_one_locked(inner) {
            return;
        }
    }
}

/// Re-place jobs parked by a last-lane drain (route lock held).
fn place_orphans_locked(inner: &LeaderInner) {
    if inner.parked_jobs.get() == 0 {
        return;
    }
    let slots: Vec<Arc<RemoteSlot>> = {
        let jobs = inner.jobs.lock().unwrap();
        jobs.values().map(Arc::clone).collect()
    };
    for slot in slots {
        let Some(entry) = slot.parked_entry.lock().unwrap().take() else { continue };
        if slot.state.lock().unwrap().outcome.is_some() {
            inner.parked_jobs.add(-1);
            continue;
        }
        match pick_lane(inner, &slot.spec.backend) {
            Some(idx) => {
                lane(inner, idx).load.fetch_add(1, Ordering::Relaxed);
                slot.lane.store(idx, Ordering::SeqCst);
                repush_entry(inner, idx, entry);
                inner.parked_jobs.add(-1);
            }
            None => {
                // still no compatible lane: stay parked
                *slot.parked_entry.lock().unwrap() = Some(entry);
            }
        }
    }
}

/// Migrate one queued job from the deepest to the shallowest compatible
/// lane (route lock held). Only *queued* entries move — a job whose
/// slice is in flight has no heap entry and is never touched, so the
/// migrated job's next slice re-`Assign`s with its retained snapshot
/// and the steal re-executes nothing. Returns false when no eligible
/// migration exists.
fn steal_one_locked(inner: &LeaderInner) -> bool {
    let lanes = lanes_snapshot(inner);
    let known = inner.backends.known.lock().unwrap().clone();
    let eligible: Vec<usize> = (0..lanes.len())
        .filter(|&i| {
            lanes[i].alive.load(Ordering::SeqCst)
                && !lanes[i].draining.load(Ordering::SeqCst)
                && known.get(i).is_some_and(Option::is_some)
        })
        .collect();
    if eligible.len() < 2 {
        return false;
    }
    let donor = *eligible
        .iter()
        .max_by_key(|&&i| lanes[i].load.load(Ordering::Relaxed))
        .expect("eligible is nonempty");
    let donor_load = lanes[donor].load.load(Ordering::Relaxed);
    let entries: Vec<QueueEntry> = {
        let mut heap = lanes[donor].heap.lock().unwrap();
        std::mem::take(&mut *heap).into_iter().map(|Reverse(e)| e).collect()
    };
    let mut stolen: Option<(QueueEntry, Arc<RemoteSlot>, usize)> = None;
    let mut keep = Vec::new();
    for entry in entries {
        if stolen.is_some() {
            keep.push(entry);
            continue;
        }
        let slot = { inner.jobs.lock().unwrap().get(&entry.name).cloned() };
        let Some(slot) = slot else { continue }; // unknown: obsolete entry
        if slot.state.lock().unwrap().outcome.is_some() {
            continue; // terminal: obsolete entry
        }
        let cur = slot.lane.load(Ordering::SeqCst);
        if cur != donor {
            // moved under a concurrent repair: hand to the owner lane
            if cur != NO_LANE {
                repush_entry(inner, cur, entry);
            }
            continue;
        }
        let target = eligible
            .iter()
            .copied()
            .filter(|&i| {
                i != donor && known[i].as_deref() == Some(slot.spec.backend.as_str())
            })
            .min_by_key(|&i| lanes[i].load.load(Ordering::Relaxed));
        match target {
            Some(t)
                if donor_load
                    >= lanes[t].load.load(Ordering::Relaxed) + STEAL_THRESHOLD =>
            {
                stolen = Some((entry, slot, t));
            }
            _ => keep.push(entry),
        }
    }
    {
        let mut heap = lanes[donor].heap.lock().unwrap();
        for e in keep {
            heap.push(Reverse(e));
        }
    }
    let Some((entry, slot, t)) = stolen else { return false };
    lanes[donor].load.fetch_sub(1, Ordering::Relaxed);
    lanes[t].load.fetch_add(1, Ordering::Relaxed);
    slot.lane.store(t, Ordering::SeqCst);
    slot.started.store(false, Ordering::SeqCst);
    slot.stop_sent.store(false, Ordering::SeqCst);
    repush_entry(inner, t, entry);
    inner.steals.inc();
    true
}

/// Migrate every job off a draining lane. Runs on the lane's own driver
/// *between* slices, so none of this lane's jobs is mid-slice: the
/// leader's store state equals each job's last acked checkpoint, and
/// the re-`Assign` on the target lane ships that snapshot — zero
/// re-executed proposals (a never-polled job resumes fresh from its
/// persisted seeds, also zero). With no surviving compatible lane, the
/// job is parked (snapshot retained) for a future join — not failed.
fn drain_lane(inner: &LeaderInner, idx: usize) {
    let _route = inner.route.lock().unwrap();
    let lane_ref = lane(inner, idx);
    let mut entries: Vec<QueueEntry> = {
        let mut heap = lane_ref.heap.lock().unwrap();
        std::mem::take(&mut *heap).into_iter().map(|Reverse(e)| e).collect()
    };
    let slots: Vec<(String, Arc<RemoteSlot>)> = {
        let jobs = inner.jobs.lock().unwrap();
        jobs.iter().map(|(n, s)| (n.clone(), Arc::clone(s))).collect()
    };
    for (name, slot) in slots {
        if slot.lane.load(Ordering::SeqCst) != idx {
            continue;
        }
        if slot.state.lock().unwrap().outcome.is_some() {
            continue;
        }
        slot.started.store(false, Ordering::SeqCst);
        slot.stop_sent.store(false, Ordering::SeqCst);
        let entry = entries
            .iter()
            .position(|e| e.name == name)
            .map(|i| entries.swap_remove(i));
        lane_ref.load.fetch_sub(1, Ordering::Relaxed);
        match pick_lane(inner, &slot.spec.backend) {
            Some(new_idx) => {
                lane(inner, new_idx).load.fetch_add(1, Ordering::Relaxed);
                slot.lane.store(new_idx, Ordering::SeqCst);
                if let Some(entry) = entry {
                    repush_entry(inner, new_idx, entry);
                }
                // entry None: parked in a tenant quota queue — the
                // release path routes it to the new lane
            }
            None => {
                slot.lane.store(NO_LANE, Ordering::SeqCst);
                if let Some(entry) = entry {
                    *slot.parked_entry.lock().unwrap() = Some(entry);
                    inner.parked_jobs.add(1);
                }
                // entry None: quota-parked — the release path parks it
            }
        }
    }
}

/// One driver: owns the transport to worker `idx` and drains that
/// worker's heap.
fn driver_loop(inner: &Arc<LeaderInner>, idx: usize, mut transport: Box<dyn Transport>) {
    // short receive slices keep shutdown and death detection responsive
    let slice = Duration::from_millis(20).min(inner.lease);
    let mut last_seen = Instant::now();
    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            let _ = transport.send(&Message::Drain);
            let _ = transport.recv(Duration::from_millis(200));
            return;
        }
        let lane_ref = lane(inner, idx);
        if lane_ref.draining.load(Ordering::SeqCst) {
            // graceful drain: this driver is between slices, so every
            // job of this lane sits exactly at its last acked
            // checkpoint — migrate them all, close the session cleanly
            drain_lane(inner, idx);
            let _ = transport.send(&Message::Drain);
            let _ = transport.recv(Duration::from_millis(500));
            retire_lane(inner, idx);
            inner.drains.inc();
            return;
        }
        let popped = { lane_ref.heap.lock().unwrap().pop() };
        let Some(Reverse(entry)) = popped else {
            // idle: pump the link (heartbeats renew the lease)
            match transport.recv(slice) {
                Ok(Some(msg)) => {
                    last_seen = Instant::now();
                    if let Message::Hello { worker, backend, proto } = &msg {
                        match note_hello(inner, idx, worker, backend, *proto) {
                            HelloVerdict::Duplicate => {
                                let _ = transport.send(&Message::Deny {
                                    reason: format!(
                                        "worker name '{worker}' is already \
                                         registered on a live lane"
                                    ),
                                });
                                retire_lane(inner, idx);
                                return;
                            }
                            HelloVerdict::Accepted { first } => {
                                if first {
                                    // a join during an ongoing run:
                                    // steal queued + parked work onto
                                    // the new capacity right away
                                    rebalance(inner);
                                }
                            }
                        }
                    }
                }
                Ok(None) => {
                    if last_seen.elapsed() > inner.lease {
                        on_worker_death(inner, idx, None);
                        return;
                    }
                    if needs_rebalance(inner) {
                        rebalance(inner);
                    }
                }
                Err(_) => {
                    on_worker_death(inner, idx, None);
                    return;
                }
            }
            continue;
        };

        let slot = { inner.jobs.lock().unwrap().get(&entry.name).cloned() };
        let Some(slot) = slot else { continue };
        if slot.state.lock().unwrap().outcome.is_some() {
            continue; // already terminal: the entry is obsolete
        }
        let current_lane = slot.lane.load(Ordering::SeqCst);
        if current_lane != idx {
            // the job moved under a repair while this entry was in
            // flight between heaps: hand it to the owning lane
            if current_lane != NO_LANE {
                repush_entry(inner, current_lane, entry);
            }
            continue;
        }

        // tenant in-flight quota gate (shared semantics with the
        // in-process scheduler)
        let mut quota_held = false;
        if let Some((tenant, limit)) = &slot.quota {
            let admitted = inner.quotas.acquire(
                tenant,
                *limit,
                QueueEntry { due: entry.due, seq: entry.seq, name: entry.name.clone() },
            );
            if admitted.is_none() {
                continue;
            }
            quota_held = true;
        }

        // drive one slice: Assign (first time on this lane) → Stop (if
        // requested) → PollRequest, coalesced into ONE Batch frame when
        // more than the PollRequest is due → read the SliceResult
        let name = entry.name.clone();
        let result: std::io::Result<()> = (|| {
            let mut burst = Vec::new();
            if !slot.started.swap(true, Ordering::SeqCst) {
                // a repaired job carries its last delta-acked snapshot:
                // the new worker rebuilds the actor mid-flight instead
                // of replaying from the seed
                let resume = slot.last_ckpt.lock().unwrap().clone();
                // ship the leader's current cache slice for this
                // objective so the worker can short-circuit configs
                // already evaluated elsewhere; gathered at send time so
                // a re-assign after a repair carries fresher seeds
                let cache_seeds = if slot.spec.request.eval_cache {
                    inner.store.scan(
                        crate::store::EVAL_CACHE_TABLE,
                        &format!("{}|", slot.spec.request.objective),
                    )
                } else {
                    Vec::new()
                };
                burst.push(Message::Assign {
                    request: slot.spec.request.clone(),
                    platform: slot.spec.platform.clone(),
                    transfer: slot.spec.transfer.clone(),
                    backend: slot.spec.backend.clone(),
                    resume,
                    cache_seeds,
                    // a gen-3 worker echoes this id on every
                    // SliceResult; earlier generations never see it
                    trace: telemetry::trace::trace_id(&name),
                });
            }
            if slot.stop.load(Ordering::Relaxed)
                && !slot.stop_sent.swap(true, Ordering::SeqCst)
            {
                burst.push(Message::Stop { job: name.clone() });
            }
            slot.polls.fetch_add(1, Ordering::Relaxed);
            inner.polls_sent.inc();
            burst.push(Message::PollRequest {
                job: name.clone(),
                max_steps: inner.batch_steps,
            });
            // a generation-1 worker cannot decode Batch: fall back to
            // one frame per message for it
            if burst.len() == 1 || lane_ref.proto.load(Ordering::SeqCst) < 2 {
                burst.iter().try_for_each(|m| transport.send(m))
            } else {
                transport.send(&Message::Batch { messages: burst })
            }
        })();
        if result.is_err() {
            if quota_held {
                release_quota(inner, &slot);
            }
            on_worker_death(inner, idx, Some(entry));
            return;
        }
        telemetry::trace::event_for(&name, "dispatch");

        // await the slice's verdict, applying deltas as they arrive
        let dispatched = Instant::now();
        let mut sent_at = dispatched;
        let reply = loop {
            if inner.shutdown.load(Ordering::SeqCst) {
                if quota_held {
                    release_quota(inner, &slot);
                }
                let _ = transport.send(&Message::Drain);
                return;
            }
            match transport.recv(slice) {
                Ok(Some(Message::SliceResult { job, records, reply, trace })) => {
                    last_seen = Instant::now();
                    sent_at = last_seen;
                    inner.slice_messages.inc();
                    // the echoed trace id proves the wire field made
                    // the full round trip — a pre-gen-3 worker echoes
                    // nothing and the phase is simply absent
                    if job == name
                        && trace.is_some()
                        && trace == telemetry::trace::trace_id(&name)
                    {
                        telemetry::trace::event_for(&name, "worker_poll");
                    }
                    // one coalesced frame: mutations apply before the
                    // verdict is acted on, exactly as in the legacy
                    // delta-then-result order
                    apply_delta(inner, &records);
                    if job == name {
                        telemetry::trace::event_for(&name, "delta_apply");
                        break Ok(reply);
                    }
                    // out-of-band result (mis-poll rejection): ignore
                }
                // legacy two-message workers: still first-class
                Ok(Some(Message::StoreDelta { records, .. })) => {
                    last_seen = Instant::now();
                    sent_at = last_seen;
                    inner.slice_messages.inc();
                    apply_delta(inner, &records);
                }
                Ok(Some(Message::PollResult { job, reply })) => {
                    last_seen = Instant::now();
                    inner.slice_messages.inc();
                    if job == name {
                        break Ok(reply);
                    }
                    // out-of-band result (duplicate rejection): ignore
                }
                Ok(Some(msg)) => {
                    last_seen = Instant::now();
                    if let Message::Hello { worker, backend, proto } = &msg {
                        // a lane only reaches mid-slice after its first
                        // accepted Hello, so this cannot be a duplicate
                        let _ = note_hello(inner, idx, worker, backend, *proto);
                    }
                }
                Ok(None) => {
                    // a worker mid-poll cannot heartbeat (single
                    // threaded), so the in-flight bound is the compute
                    // budget, not the idle lease
                    if sent_at.elapsed() > inner.poll_timeout {
                        break Err(());
                    }
                }
                Err(_) => break Err(()),
            }
        };
        if reply.is_ok() && telemetry::enabled() {
            inner.rtt_us.record_duration(dispatched.elapsed());
        }
        match reply {
            Ok(PollReply::Pending { due }) => {
                push_lane_entry(inner, idx, due, slot.weight, name.clone());
                if quota_held {
                    release_quota(inner, &slot);
                }
                commit_wal(inner);
                telemetry::trace::event_for(&name, "group_commit");
            }
            Ok(PollReply::Complete(outcome)) => {
                if quota_held {
                    release_quota(inner, &slot);
                }
                // durability before acknowledgment, like the scheduler
                commit_wal(inner);
                telemetry::trace::event_for(&name, "group_commit");
                publish(inner, &slot, *outcome);
                telemetry::trace::event_for(&name, "outcome");
                // the ring keeps the job's events; the name→id binding
                // is released so the sink's map stays bounded
                telemetry::trace::forget(&name);
            }
            Ok(PollReply::Rejected { reason }) => {
                if quota_held {
                    release_quota(inner, &slot);
                }
                mark_failed(inner, &slot, &name, &format!("worker rejected job: {reason}"));
                telemetry::trace::event_for(&name, "outcome");
                telemetry::trace::forget(&name);
            }
            Err(()) => {
                if quota_held {
                    release_quota(inner, &slot);
                }
                on_worker_death(inner, idx, Some(entry));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::worker::spawn_loopback_worker;

    fn spec(name: &str, evals: u32, seed: u64) -> RemoteJobSpec {
        RemoteJobSpec {
            request: TuningJobRequest {
                name: name.into(),
                objective: "branin".into(),
                strategy: "random".into(),
                max_training_jobs: evals,
                max_parallel_jobs: 2,
                seed,
                ..Default::default()
            },
            platform: PlatformConfig::noiseless(),
            transfer: Vec::new(),
            backend: "native".into(),
        }
    }

    fn pool(workers: usize) -> (RemoteWorkerPool, Vec<std::thread::JoinHandle<()>>) {
        let mut transports = Vec::new();
        let mut handles = Vec::new();
        for i in 0..workers {
            let (t, _fault, h) = spawn_loopback_worker(&format!("lead-{i}"));
            transports.push(t);
            handles.push(h);
        }
        let p = RemoteWorkerPool::new(
            transports,
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            None,
            RemoteConfig::default(),
        );
        (p, handles)
    }

    #[test]
    fn jobs_complete_through_remote_workers() {
        let (pool, handles) = pool(2);
        for i in 0..6u64 {
            assert!(pool.register(spec(&format!("r-{i}"), 3, i)));
            pool.activate(&format!("r-{i}"));
        }
        assert!(!pool.register(spec("r-0", 3, 0)), "duplicate names rejected");
        for i in 0..6u64 {
            let out = pool.wait(&format!("r-{i}")).unwrap();
            assert_eq!(out.evaluations.len(), 3);
            assert_eq!(out.status, ExecutionStatus::Succeeded);
        }
        assert_eq!(pool.running_jobs(), 0);
        assert_eq!(pool.worker_count(), 2);
        assert_eq!(pool.live_workers(), 2);
        assert!(pool.poll_count("r-0").unwrap() > 0);
        assert!(pool.poll_count("ghost").is_none());
        assert!(pool.wait("ghost").is_none());
        drop(pool);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn stop_reaches_remote_job() {
        let (pool, handles) = pool(1);
        assert!(pool.register(spec("stoppable", 10_000, 3)));
        pool.activate("stoppable");
        assert!(pool.stop("stoppable"));
        assert!(!pool.stop("ghost"));
        let out = pool.wait("stoppable").unwrap();
        assert!(out.evaluations.len() < 10_000);
        drop(pool);
        for h in handles {
            h.join().unwrap();
        }
    }

    /// Backend pinning: jobs route only to lanes advertising their
    /// backend; a job with no compatible worker fails loudly.
    #[test]
    fn backend_pinning_routes_and_fails_loudly() {
        use crate::distributed::worker::spawn_loopback_worker_with_backend;
        let (t_native, _f1, h1) = spawn_loopback_worker("bk-native");
        let (t_hlo, _f2, h2) = spawn_loopback_worker_with_backend("bk-hlo", "hlo");
        let pool = RemoteWorkerPool::new(
            vec![t_native, t_hlo],
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            None,
            RemoteConfig::default(),
        );
        assert!(pool.supports_backend("native"));
        assert!(pool.supports_backend("hlo"));
        assert!(!pool.supports_backend("tpu"));
        assert_eq!(
            pool.lane_backends(),
            vec![Some("native".to_string()), Some("hlo".to_string())]
        );

        let mut s = spec("pin-hlo", 3, 1);
        s.backend = "hlo".into();
        assert!(pool.register(s));
        pool.activate("pin-hlo");
        let out = pool.wait("pin-hlo").unwrap();
        assert_eq!(out.status, ExecutionStatus::Succeeded, "hlo lane must host the job");

        let mut s = spec("pin-nowhere", 2, 2);
        s.backend = "tpu".into();
        assert!(pool.register(s));
        pool.activate("pin-nowhere");
        let out = pool.wait("pin-nowhere").unwrap();
        assert!(
            matches!(out.status, ExecutionStatus::Failed(ref e) if e.contains("tpu")),
            "incompatible job must fail loudly, got {:?}",
            out.status
        );
        drop(pool);
        h1.join().unwrap();
        h2.join().unwrap();
    }

    #[test]
    fn unknown_objective_job_fails_loudly() {
        let (pool, handles) = pool(1);
        let mut s = spec("bad-objective", 3, 1);
        s.request.objective = "no-such-workload".into();
        assert!(pool.register(s));
        pool.activate("bad-objective");
        let out = pool.wait("bad-objective").unwrap();
        assert!(matches!(out.status, ExecutionStatus::Failed(_)));
        drop(pool);
        for h in handles {
            h.join().unwrap();
        }
    }
}
