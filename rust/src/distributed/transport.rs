//! Transports for the distributed execution plane: how framed
//! [`Message`]s move between a leader and a worker.
//!
//! Two implementations of the one [`Transport`] trait:
//!
//! * [`loopback_pair`] — an in-process byte channel that still runs the
//!   full encode → frame → decode pipeline, so deterministic tests (and
//!   the bit-identity property against the in-process pool) exercise
//!   exactly the wire path a socket would, minus the kernel. Its
//!   [`LoopbackFault`] handle kills the link at any instant — both ends
//!   start failing immediately, queued messages included — which is how
//!   the worker-kill integration test simulates a dead worker process.
//! * [`SocketTransport`] — a TCP or Unix-domain stream for real
//!   multi-process deployments (`amt worker --listen` / `amt serve
//!   --workers`). Reads are deadline-bounded and buffer partial frames,
//!   so a slow peer never desynchronizes the stream.
//!
//! Error contract shared by both: `Ok(None)` from `recv` means "nothing
//! arrived in time" (the caller decides about lease expiry); any `Err`
//! means the link is dead and the peer's jobs must be requeued.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::frame;
use super::proto::Message;

/// A bidirectional, message-oriented link to one peer.
pub trait Transport: Send {
    /// Frame and ship one message. `Err` = the link is dead.
    fn send(&mut self, msg: &Message) -> std::io::Result<()>;
    /// Wait up to `timeout` for the next message. `Ok(None)` = nothing
    /// arrived in time; `Err` = the link is dead.
    fn recv(&mut self, timeout: Duration) -> std::io::Result<Option<Message>>;
    /// Human-readable peer label for diagnostics.
    fn peer(&self) -> String;
}

fn dead_link(what: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::BrokenPipe, what.to_string())
}

/// True for "nobody is listening there (yet)" errors — the retryable
/// class a reconnecting worker's backoff loop keeps waiting on
/// (`ConnectionRefused`; a missing Unix socket file is mapped to it by
/// [`SocketTransport::connect`]).
pub fn is_not_listening(e: &std::io::Error) -> bool {
    e.kind() == std::io::ErrorKind::ConnectionRefused
}

/// True for "the link existed and then died" errors — the class after
/// which a reconnecting worker restarts its session (as opposed to a
/// hard verdict like `PermissionDenied`, which must end the retry loop).
pub fn is_dead_link(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::BrokenPipe
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::UnexpectedEof
    )
}

/// Kill switch shared by both ends of a loopback link (fault injection
/// for worker-death tests): after [`LoopbackFault::kill`], every send
/// and recv on either end fails immediately — queued messages are
/// unreachable, exactly as if the peer process had died.
pub struct LoopbackFault {
    killed: AtomicBool,
}

impl LoopbackFault {
    /// Sever the link permanently.
    pub fn kill(&self) {
        self.killed.store(true, Ordering::SeqCst);
    }

    /// True once the link was severed.
    pub fn is_killed(&self) -> bool {
        self.killed.load(Ordering::SeqCst)
    }
}

/// One end of an in-process loopback link. Messages cross as framed
/// bytes (encode on send, decode on recv), so the wire codec is fully
/// exercised.
pub struct LoopbackTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    fault: Arc<LoopbackFault>,
    label: String,
}

/// Build a connected loopback pair `(leader_end, worker_end)` plus the
/// fault handle that severs it.
pub fn loopback_pair(label: &str) -> (LoopbackTransport, LoopbackTransport, Arc<LoopbackFault>) {
    let (to_worker, from_leader) = mpsc::channel();
    let (to_leader, from_worker) = mpsc::channel();
    let fault = Arc::new(LoopbackFault { killed: AtomicBool::new(false) });
    let leader = LoopbackTransport {
        tx: to_worker,
        rx: from_worker,
        fault: Arc::clone(&fault),
        label: format!("loopback:{label}"),
    };
    let worker = LoopbackTransport {
        tx: to_leader,
        rx: from_leader,
        fault: Arc::clone(&fault),
        label: format!("loopback:{label}"),
    };
    (leader, worker, fault)
}

impl Transport for LoopbackTransport {
    fn send(&mut self, msg: &Message) -> std::io::Result<()> {
        if self.fault.is_killed() {
            return Err(dead_link("loopback link killed"));
        }
        self.tx.send(msg.encode()).map_err(|_| dead_link("loopback peer gone"))
    }

    fn recv(&mut self, timeout: Duration) -> std::io::Result<Option<Message>> {
        if self.fault.is_killed() {
            return Err(dead_link("loopback link killed"));
        }
        match self.rx.recv_timeout(timeout) {
            Ok(bytes) => {
                // a kill that lands while a message is in flight still
                // severs the link: queued bytes are part of the dead peer
                if self.fault.is_killed() {
                    return Err(dead_link("loopback link killed"));
                }
                // zero-copy parse: the payload is borrowed straight from
                // the received buffer, never re-allocated
                let (payload, consumed) = frame::decode_borrowed(&bytes)?
                    .ok_or_else(|| dead_link("loopback frame truncated"))?;
                debug_assert_eq!(consumed, bytes.len());
                Ok(Some(Message::decode(payload)?))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(dead_link("loopback peer gone")),
        }
    }

    fn peer(&self) -> String {
        self.label.clone()
    }
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn set_read_timeout(&self, t: Duration) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(Some(t)),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(Some(t)),
        }
    }

    fn read_chunk(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }

    fn write_all_flush(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => {
                s.write_all(bytes)?;
                s.flush()
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                s.write_all(bytes)?;
                s.flush()
            }
        }
    }
}

/// A framed TCP or Unix-domain stream transport. Addresses starting
/// with `unix:` (or containing a `/`) are Unix socket paths; anything
/// else is a TCP `host:port`.
pub struct SocketTransport {
    stream: Stream,
    peer: String,
    /// Bytes received but not yet forming a complete frame.
    pending: Vec<u8>,
}

fn is_unix_addr(addr: &str) -> bool {
    addr.starts_with("unix:") || addr.contains('/')
}

#[cfg(unix)]
fn unix_path(addr: &str) -> &str {
    addr.strip_prefix("unix:").unwrap_or(addr)
}

impl SocketTransport {
    /// Connect to a listening worker/leader.
    pub fn connect(addr: &str) -> std::io::Result<SocketTransport> {
        let stream = if is_unix_addr(addr) {
            #[cfg(unix)]
            {
                // a socket file that does not exist yet is the Unix
                // analogue of TCP's ConnectionRefused: classify it as
                // "not listening" so backoff loops retry it
                let s = UnixStream::connect(unix_path(addr)).map_err(|e| {
                    if e.kind() == std::io::ErrorKind::NotFound {
                        std::io::Error::new(
                            std::io::ErrorKind::ConnectionRefused,
                            format!("no listener at {addr}"),
                        )
                    } else {
                        e
                    }
                })?;
                Stream::Unix(s)
            }
            #[cfg(not(unix))]
            {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Unsupported,
                    "unix sockets unavailable on this platform",
                ));
            }
        } else {
            let s = TcpStream::connect(addr)?;
            s.set_nodelay(true)?;
            Stream::Tcp(s)
        };
        Ok(SocketTransport { stream, peer: addr.to_string(), pending: Vec::new() })
    }
}

impl Transport for SocketTransport {
    fn send(&mut self, msg: &Message) -> std::io::Result<()> {
        self.stream.write_all_flush(&msg.encode())
    }

    fn recv(&mut self, timeout: Duration) -> std::io::Result<Option<Message>> {
        let deadline = Instant::now() + timeout;
        loop {
            // zero-copy parse: decode the message while the payload still
            // borrows `pending`, then drain the consumed prefix
            let parsed = match frame::decode_borrowed(&self.pending)? {
                Some((payload, consumed)) => Some((Message::decode(payload)?, consumed)),
                None => None,
            };
            if let Some((msg, consumed)) = parsed {
                self.pending.drain(..consumed);
                return Ok(Some(msg));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            self.stream.set_read_timeout(deadline - now)?;
            let mut chunk = [0u8; 4096];
            match self.stream.read_chunk(&mut chunk) {
                Ok(0) => return Err(dead_link("peer closed the connection")),
                Ok(n) => self.pending.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    // deadline-bounded read expired mid-frame: report
                    // "nothing yet"; the partial bytes stay buffered
                    return Ok(None);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

/// Listening socket for `amt worker --listen`.
pub enum SocketListener {
    /// TCP listener.
    Tcp(TcpListener),
    /// Unix-domain listener.
    #[cfg(unix)]
    Unix(UnixListener),
}

impl SocketListener {
    /// Bind a listener (same address grammar as
    /// [`SocketTransport::connect`]; an existing Unix socket file is
    /// replaced).
    pub fn bind(addr: &str) -> std::io::Result<SocketListener> {
        if is_unix_addr(addr) {
            #[cfg(unix)]
            {
                let path = unix_path(addr);
                let _ = std::fs::remove_file(path);
                return Ok(SocketListener::Unix(UnixListener::bind(path)?));
            }
            #[cfg(not(unix))]
            {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Unsupported,
                    "unix sockets unavailable on this platform",
                ));
            }
        }
        Ok(SocketListener::Tcp(TcpListener::bind(addr)?))
    }

    /// Block for the next leader connection.
    pub fn accept(&self) -> std::io::Result<SocketTransport> {
        self.set_nonblocking(false)?;
        self.try_accept()
    }

    /// Wait up to `timeout` for the next connection. `Ok(None)` =
    /// nothing arrived in time — the shape a shutdown-aware accept loop
    /// needs (the blocking [`SocketListener::accept`] cannot observe a
    /// shutdown flag).
    pub fn accept_timeout(&self, timeout: Duration) -> std::io::Result<Option<SocketTransport>> {
        self.set_nonblocking(true)?;
        let deadline = Instant::now() + timeout;
        let result = loop {
            match self.try_accept() {
                Ok(t) => break Ok(Some(t)),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if Instant::now() >= deadline {
                        break Ok(None);
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => break Err(e),
            }
        };
        let _ = self.set_nonblocking(false);
        result
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            SocketListener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            SocketListener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    /// One accept attempt under the listener's current blocking mode;
    /// an accepted stream is always switched back to blocking.
    fn try_accept(&self) -> std::io::Result<SocketTransport> {
        match self {
            SocketListener::Tcp(l) => {
                let (s, peer) = l.accept()?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                Ok(SocketTransport {
                    stream: Stream::Tcp(s),
                    peer: peer.to_string(),
                    pending: Vec::new(),
                })
            }
            #[cfg(unix)]
            SocketListener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(SocketTransport {
                    stream: Stream::Unix(s),
                    peer: "unix-peer".to_string(),
                    pending: Vec::new(),
                })
            }
        }
    }

    /// The bound address (for logs; TCP resolves the ephemeral port).
    pub fn local_addr(&self) -> String {
        match self {
            SocketListener::Tcp(l) => {
                l.local_addr().map(|a| a.to_string()).unwrap_or_default()
            }
            #[cfg(unix)]
            SocketListener::Unix(l) => l
                .local_addr()
                .ok()
                .and_then(|a| a.as_pathname().map(|p| p.display().to_string()))
                .unwrap_or_default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_carries_messages_both_ways() {
        let (mut leader, mut worker, _fault) = loopback_pair("t");
        leader.send(&Message::PollRequest { job: "j".into(), max_steps: 8 }).unwrap();
        let got = worker.recv(Duration::from_secs(1)).unwrap().unwrap();
        assert!(matches!(got, Message::PollRequest { max_steps: 8, .. }));
        worker.send(&Message::Heartbeat).unwrap();
        assert!(matches!(
            leader.recv(Duration::from_secs(1)).unwrap(),
            Some(Message::Heartbeat)
        ));
        // nothing queued: timeout reports None, not an error
        assert!(leader.recv(Duration::from_millis(5)).unwrap().is_none());
        assert!(leader.peer().starts_with("loopback:"));
    }

    #[test]
    fn killed_loopback_fails_both_ends_even_with_queued_messages() {
        let (mut leader, mut worker, fault) = loopback_pair("kill");
        worker.send(&Message::Heartbeat).unwrap();
        fault.kill();
        assert!(leader.recv(Duration::from_millis(5)).is_err());
        assert!(leader.send(&Message::Drain).is_err());
        assert!(worker.recv(Duration::from_millis(5)).is_err());
        assert!(worker.send(&Message::Heartbeat).is_err());
        assert!(fault.is_killed());
    }

    #[test]
    fn dropped_peer_is_a_dead_link() {
        let (mut leader, worker, _fault) = loopback_pair("drop");
        drop(worker);
        let e = leader.send(&Message::Heartbeat).unwrap_err();
        assert!(is_dead_link(&e), "dropped peer must classify as dead link, got {e:?}");
        assert!(!is_not_listening(&e));
    }

    /// Reconnect hygiene: "leader not up yet" (retryable) must be
    /// distinguishable from "link died mid-session" (session restart).
    #[test]
    fn refused_connect_classifies_as_not_listening() {
        // bind an ephemeral port, then close it: nothing listens there
        let addr = {
            let l = SocketListener::bind("127.0.0.1:0").unwrap();
            l.local_addr()
        };
        let e = SocketTransport::connect(&addr).unwrap_err();
        assert!(is_not_listening(&e), "refused connect must be not_listening, got {e:?}");
        assert!(!is_dead_link(&e));
        #[cfg(unix)]
        {
            // a Unix socket path that does not exist is the same class
            let e = SocketTransport::connect("unix:/tmp/amt-no-such-socket.sock")
                .unwrap_err();
            assert!(is_not_listening(&e), "missing socket file, got {e:?}");
        }
    }

    #[test]
    fn accept_timeout_reports_none_then_accepts() {
        let listener = SocketListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        // nobody connecting: times out with None, not an error
        assert!(listener.accept_timeout(Duration::from_millis(30)).unwrap().is_none());
        let client = std::thread::spawn(move || {
            let mut c = SocketTransport::connect(&addr).unwrap();
            c.send(&Message::Heartbeat).unwrap();
            // hold the connection open until the server is done reading
            let _ = c.recv(Duration::from_secs(5));
        });
        let mut t = loop {
            if let Some(t) = listener.accept_timeout(Duration::from_secs(5)).unwrap() {
                break t;
            }
        };
        assert!(matches!(
            t.recv(Duration::from_secs(5)).unwrap(),
            Some(Message::Heartbeat)
        ));
        t.send(&Message::Drain).unwrap();
        client.join().unwrap();
    }

    #[test]
    fn tcp_transport_roundtrip() {
        let listener = SocketListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr();
        let server = std::thread::spawn(move || {
            let mut t = listener.accept().unwrap();
            let msg = t.recv(Duration::from_secs(5)).unwrap().unwrap();
            assert!(matches!(msg, Message::Hello { .. }));
            t.send(&Message::DrainAck).unwrap();
            // hold the connection open until the client is done reading
            let _ = t.recv(Duration::from_secs(5));
        });
        let mut client = SocketTransport::connect(&addr).unwrap();
        client
            .send(&Message::Hello { worker: "w".into(), backend: "native".into(), proto: 2 })
            .unwrap();
        assert!(matches!(
            client.recv(Duration::from_secs(5)).unwrap(),
            Some(Message::DrainAck)
        ));
        assert!(client.recv(Duration::from_millis(10)).unwrap().is_none());
        drop(client);
        server.join().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn unix_transport_roundtrip() {
        let path = std::env::temp_dir().join(format!(
            "amt-uds-{}-{}.sock",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let addr = format!("unix:{}", path.display());
        let listener = SocketListener::bind(&addr).unwrap();
        let server = std::thread::spawn(move || {
            let mut t = listener.accept().unwrap();
            let msg = t.recv(Duration::from_secs(5)).unwrap().unwrap();
            assert!(matches!(msg, Message::Heartbeat));
            t.send(&Message::Drain).unwrap();
            let _ = t.recv(Duration::from_secs(5));
        });
        let mut client = SocketTransport::connect(&addr).unwrap();
        client.send(&Message::Heartbeat).unwrap();
        assert!(matches!(
            client.recv(Duration::from_secs(5)).unwrap(),
            Some(Message::Drain)
        ));
        drop(client);
        server.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
