//! Distributed execution plane (DESIGN.md §11): the tuning control
//! plane and the training workloads on **separate fleets**, the way the
//! paper's AMT actually deploys (§4's managed service: evaluations fan
//! out across machines while the scheduler stays put).
//!
//! Layering, bottom to top:
//!
//! * [`frame`] — length+crc32 message framing, the WAL's on-disk frame
//!   discipline applied to a byte stream;
//! * [`proto`] — the leader⇄worker message vocabulary; `StoreDelta`s
//!   carry literal [`crate::durability::wal::WalRecord`]s (the WAL
//!   record format is the wire format, f64s bit-exact);
//! * [`transport`] — one trait, two carriers: an in-process loopback
//!   (deterministic tests, fault injection) and TCP/Unix sockets
//!   (real multi-process deployments);
//! * [`worker`] — hosts [`crate::coordinator::JobActor`]s next to
//!   job-local stores whose mutations are captured via a never-committed
//!   WAL and shipped back as deltas;
//! * [`leader`] — the [`leader::RemoteWorkerPool`]: per-worker
//!   virtual-time heaps with the scheduler's `(due ÷ weight, seq)` key,
//!   surrogate-backend pinning (jobs route only to lanes advertising a
//!   matching backend), lease-based liveness, delta application through
//!   the leader's store (and durability WAL, when attached), and — on
//!   worker death — requeue from the job's last delta-acked
//!   [`crate::coordinator::ResumeSnapshot`] (O(remaining work),
//!   DESIGN.md §12), falling back to requeue-from-reset when no
//!   checkpoint has been acked. The fleet is **elastic** (DESIGN.md
//!   §13): workers join mid-run (`add_worker` / an `accept_workers`
//!   listener admitting late `Hello`s; duplicate names get a hard
//!   `Deny`), drain gracefully (`drain_worker`: every job migrates on
//!   its retained snapshot — zero re-executed proposals — or parks when
//!   no compatible lane survives), and queued work is stolen from
//!   skewed lanes onto idle ones (`joins`/`drains`/`steals` counters).
//!
//! Single-process behavior is untouched: with the loopback transport a
//! job's trajectory, final store contents and item versions are
//! bit-identical to the in-process scheduler (property-tested in
//! `rust/tests/distributed_integration.rs`).

pub mod frame;
pub mod leader;
pub mod proto;
pub mod transport;
pub mod worker;
