//! Hyperparameter selection strategies (§2, §4): the algorithm side of the
//! Hyperparameter Selection Service.
//!
//! All strategies speak *minimization*; the coordinator negates metrics for
//! maximization objectives before they reach this layer. Strategies are
//! stateful (they own their RNG / Sobol cursor / GPHP chain state) and are
//! driven by the coordinator with the full observation history plus the
//! currently *pending* configurations (asynchronous parallelism, §4.4).

use std::sync::Arc;

use crate::acquisition::{propose, AcquisitionConfig, Proposal};
use crate::gp::slice::{sample_gphp, SliceConfig};
use crate::gp::{fit::fit_empirical_bayes, kernel, Dataset, GpModel, SurrogateBackend, Theta};
use crate::json::{self, Json};
use crate::linalg::{chol_append_row, Matrix};
use crate::rng::Rng;
use crate::sobol::Sobol;
use crate::space::{Config, SearchSpace};

/// One finished evaluation: configuration and its (minimized) final metric.
#[derive(Clone, Debug)]
pub struct Observation {
    /// The evaluated configuration.
    pub config: Config,
    /// Final objective value (lower is better at this layer).
    pub value: f64,
}

/// Wire form of a list of observations: the `warm_start` table's
/// `observations` field, the distributed `Assign` message's `transfer`
/// field, and the history/transfer blocks of resume snapshots. Configs
/// use the type-tagged encoding ([`crate::space::config_to_json_typed`])
/// and f64s round-trip bit-exactly, so a thawed strategy sees *exactly*
/// the observations the original held.
pub fn observations_to_json(obs: &[Observation]) -> Json {
    Json::Arr(
        obs.iter()
            .map(|o| {
                Json::obj(vec![
                    ("config", crate::space::config_to_json_typed(&o.config)),
                    ("value", Json::Num(o.value)),
                ])
            })
            .collect(),
    )
}

/// Reader for [`observations_to_json`] (takes the array).
pub fn observations_from_json(arr: &Json) -> Option<Vec<Observation>> {
    let arr = arr.as_arr()?;
    let mut out = Vec::with_capacity(arr.len());
    for entry in arr {
        out.push(Observation {
            config: crate::space::config_from_json_typed(entry.get("config")?)?,
            value: entry.get("value")?.as_f64()?,
        });
    }
    Some(out)
}

/// Mid-job strategy state, frozen into versioned resume snapshots
/// (DESIGN.md §12). `state_to_json` captures everything that changes as
/// a strategy proposes — RNG words, Sobol/grid cursors, warm-start
/// observations, the BO engine's MCMC warm start and EB refit cache —
/// and `restore_state` thaws it into a freshly constructed strategy of
/// the same kind, after which the strategy's remaining proposal stream
/// is **bit-identical** to the uninterrupted original's. Strategies are
/// otherwise pure functions of `(request, history, pending)`, so this
/// state is exactly the part recovery cannot rebuild without replaying
/// every past proposal.
pub trait StrategyState {
    /// Freeze the mutable strategy state (always carries a `kind` tag).
    fn state_to_json(&self) -> Json;
    /// Thaw a [`StrategyState::state_to_json`] payload into this
    /// strategy. Returns false on any kind/schema mismatch, leaving the
    /// caller to fall back to scratch replay; partial application is
    /// allowed on a false return (the strategy must then be discarded).
    fn restore_state(&mut self, state: &Json) -> bool;
}

/// A proposal source for the selection service.
pub trait Strategy: Send + StrategyState {
    /// Short name for logs and benches.
    fn name(&self) -> &'static str;
    /// Propose the next configuration given history and pending evaluations.
    fn next_config(&mut self, history: &[Observation], pending: &[Config]) -> Config;
    /// [`Strategy::next_config`] plus a flag telling the speculative
    /// pipeline whether observation *values* influenced the proposal.
    /// `false` (model-free strategies, BO's initial design) means a
    /// speculative call with a fantasy value is byte-equivalent to the
    /// synchronous recompute with the real value, so a commit needs no
    /// fantasy-consistency check. The default is conservatively `true`.
    fn next_config_tracked(
        &mut self,
        history: &[Observation],
        pending: &[Config],
    ) -> (Config, bool) {
        (self.next_config(history, pending), true)
    }
}

// ---------------------------------------------------------------------------
// Speculative proposal pipeline (DESIGN.md §17)
// ---------------------------------------------------------------------------

/// Deterministic constant-liar fantasy value (DESIGN.md §17): the current
/// best (minimum — strategies speak minimization) observed value, or 0.0
/// when no observation has landed yet. Pinned here so both execution
/// planes and every resume path fantasize identically.
pub fn fantasy_value(history: &[Observation]) -> f64 {
    match history.iter().map(|o| o.value).fold(f64::INFINITY, f64::min) {
        best if best.is_finite() => best,
        _ => 0.0,
    }
}

/// One in-flight speculative proposal: the pre-computed next config plus
/// everything needed to decide commit vs discard when the real outcome
/// lands, and to roll the strategy back on discard. Frozen into resume
/// snapshots (an optional `speculation` block of the coordinator state)
/// so PR 5 crash recovery and PR 6 drain/steal migration keep the
/// pipeline's zero-replay guarantee.
#[derive(Clone, Debug)]
pub struct Speculation {
    /// The speculatively proposed next configuration.
    pub config: Config,
    /// Config of the in-flight evaluation we fantasized an outcome for.
    pub fantasy_config: Config,
    /// The constant-liar value used ([`fantasy_value`] at speculate time).
    pub fantasy_value: f64,
    /// History length when the speculation was computed.
    pub history_len: usize,
    /// The pending set the speculative call saw (the in-flight configs
    /// minus `fantasy_config`) — a commit requires the synchronous call
    /// would have seen exactly this set.
    pub pending: Vec<Config>,
    /// Whether observation values influenced the proposal. `false` ⇒
    /// commit unconditionally on a structural match; `true` ⇒ commit only
    /// when the real outcome equals the fantasy bit-for-bit.
    pub value_dependent: bool,
    /// Strategy state frozen *before* the speculative call — restored on
    /// discard, making the fallback bit-identical to the synchronous path.
    pub saved: Json,
}

impl Speculation {
    /// Commit check: the speculative call was byte-equivalent to the
    /// synchronous recompute iff exactly one observation landed since,
    /// it is the fantasized evaluation, the pending set shrank to what
    /// the speculation assumed, and (for value-dependent proposals) the
    /// real value equals the fantasy bit-for-bit. Anything else — a
    /// different eval finishing first, a no-retry failure shrinking the
    /// pending set, a multi-outcome slice — forces the discard path.
    pub fn matches(&self, history: &[Observation], pending: &[Config]) -> bool {
        history.len() == self.history_len + 1
            && pending == &self.pending[..]
            && history.last().is_some_and(|o| {
                o.config == self.fantasy_config
                    && if self.value_dependent {
                        o.value.to_bits() == self.fantasy_value.to_bits()
                    } else {
                        // value-free proposals never *read* y, but
                        // encoders may *filter* non-finite observations —
                        // and the fantasy value is always finite, so a
                        // non-finite real value could change history
                        // cardinality downstream. Require finiteness to
                        // keep the commit provably byte-equivalent.
                        o.value.is_finite()
                    }
            })
    }

    /// Wire form (typed configs, bit-exact f64s) for resume snapshots.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("config", crate::space::config_to_json_typed(&self.config)),
            (
                "fantasy_config",
                crate::space::config_to_json_typed(&self.fantasy_config),
            ),
            ("fantasy_value", Json::Num(self.fantasy_value)),
            ("history_len", Json::Num(self.history_len as f64)),
            (
                "pending",
                Json::Arr(
                    self.pending
                        .iter()
                        .map(crate::space::config_to_json_typed)
                        .collect(),
                ),
            ),
            ("value_dependent", Json::Bool(self.value_dependent)),
            ("saved", self.saved.clone()),
        ])
    }

    /// Reader for [`Speculation::to_json`].
    pub fn from_json(j: &Json) -> Option<Speculation> {
        let pending = j
            .get("pending")?
            .as_arr()?
            .iter()
            .map(crate::space::config_from_json_typed)
            .collect::<Option<Vec<_>>>()?;
        Some(Speculation {
            config: crate::space::config_from_json_typed(j.get("config")?)?,
            fantasy_config: crate::space::config_from_json_typed(
                j.get("fantasy_config")?,
            )?,
            fantasy_value: j.get("fantasy_value")?.as_f64()?,
            history_len: j.get("history_len")?.as_i64()? as usize,
            pending,
            value_dependent: j.get("value_dependent")?.as_bool()?,
            saved: j.get("saved")?.clone(),
        })
    }
}

/// Speculatively compute the next proposal while `fantasy_config` is
/// still in flight: freeze the strategy state, append the constant-liar
/// fantasy observation, and run the ordinary proposal path against the
/// post-completion view (`pending_after` = in-flight configs minus the
/// fantasized one). The strategy is left *advanced* — on commit nothing
/// recomputes; on discard the caller restores `saved` and the strategy
/// is bit-identical to one that never speculated.
pub fn speculate(
    strategy: &mut dyn Strategy,
    history: &[Observation],
    pending_after: &[Config],
    fantasy_config: Config,
) -> Speculation {
    let saved = strategy.state_to_json();
    let fantasy = fantasy_value(history);
    let mut fantasized: Vec<Observation> = history.to_vec();
    fantasized.push(Observation { config: fantasy_config.clone(), value: fantasy });
    let (config, value_dependent) = strategy.next_config_tracked(&fantasized, pending_after);
    Speculation {
        config,
        fantasy_config,
        fantasy_value: fantasy,
        history_len: history.len(),
        pending: pending_after.to_vec(),
        value_dependent,
        saved,
    }
}

fn sobol_to_json(s: &Sobol) -> Json {
    let (index, x) = s.state();
    Json::obj(vec![
        ("index", json::u64_to_json(index)),
        ("x", Json::Arr(x.iter().map(|&w| json::u64_to_json(w)).collect())),
    ])
}

fn sobol_from_json(dim: usize, j: &Json) -> Option<Sobol> {
    let index = json::u64_from_json(j.get("index")?)?;
    let x: Vec<u64> =
        j.get("x")?.as_arr()?.iter().map(json::u64_from_json).collect::<Option<_>>()?;
    Sobol::from_state(dim, index, &x)
}

fn dataset_to_json(d: &Dataset) -> Json {
    Json::obj(vec![
        ("n", Json::Num(d.len() as f64)),
        ("d", Json::Num(d.dim() as f64)),
        ("flat", Json::Arr(d.flat().iter().map(|&v| Json::Num(v)).collect())),
    ])
}

fn dataset_from_json(j: &Json) -> Option<Dataset> {
    let n = j.get("n")?.as_i64()? as usize;
    let d = j.get("d")?.as_i64()? as usize;
    let flat: Vec<f64> =
        j.get("flat")?.as_arr()?.iter().map(Json::as_f64).collect::<Option<_>>()?;
    if flat.len() != n * d {
        return None;
    }
    Some(Dataset::from_flat(n, d, flat))
}

fn matrix_to_json(m: &Matrix) -> Json {
    Json::obj(vec![
        ("rows", Json::Num(m.rows as f64)),
        ("cols", Json::Num(m.cols as f64)),
        ("data", Json::Arr(m.data.iter().map(|&v| Json::Num(v)).collect())),
    ])
}

fn matrix_from_json(j: &Json) -> Option<Matrix> {
    let rows = j.get("rows")?.as_i64()? as usize;
    let cols = j.get("cols")?.as_i64()? as usize;
    let data: Vec<f64> =
        j.get("data")?.as_arr()?.iter().map(Json::as_f64).collect::<Option<_>>()?;
    if data.len() != rows * cols {
        return None;
    }
    Some(Matrix::from_rows(rows, cols, data))
}

fn kind_matches(state: &Json, kind: &str) -> bool {
    state.get("kind").and_then(Json::as_str) == Some(kind)
}

// ---------------------------------------------------------------------------
// Model-free baselines (§2.1)
// ---------------------------------------------------------------------------

/// Uniform random search in the *transformed* space (log scaling applies).
pub struct RandomSearch {
    space: SearchSpace,
    rng: Rng,
}

impl RandomSearch {
    /// New sampler over `space`.
    pub fn new(space: SearchSpace, seed: u64) -> Self {
        RandomSearch { space, rng: Rng::new(seed) }
    }
}

impl Strategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }
    fn next_config(&mut self, _history: &[Observation], _pending: &[Config]) -> Config {
        self.space.sample(&mut self.rng)
    }
    fn next_config_tracked(
        &mut self,
        history: &[Observation],
        pending: &[Config],
    ) -> (Config, bool) {
        (self.next_config(history, pending), false)
    }
}

impl StrategyState for RandomSearch {
    fn state_to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("random".into())),
            ("rng", self.rng.state_to_json()),
        ])
    }
    fn restore_state(&mut self, state: &Json) -> bool {
        if !kind_matches(state, "random") {
            return false;
        }
        match state.get("rng").and_then(Rng::from_state_json) {
            Some(rng) => {
                self.rng = rng;
                true
            }
            None => false,
        }
    }
}

/// Quasi-random search on a Sobol sequence (§2.1's "pseudo-random points").
pub struct SobolSearch {
    space: SearchSpace,
    sobol: Sobol,
}

impl SobolSearch {
    /// New Sobol cursor over `space`.
    pub fn new(space: SearchSpace) -> Self {
        let dim = space.encoded_dim().min(crate::sobol::MAX_DIM);
        SobolSearch { space, sobol: Sobol::new(dim) }
    }
}

impl Strategy for SobolSearch {
    fn name(&self) -> &'static str {
        "sobol"
    }
    fn next_config_tracked(
        &mut self,
        history: &[Observation],
        pending: &[Config],
    ) -> (Config, bool) {
        (self.next_config(history, pending), false)
    }
    fn next_config(&mut self, _history: &[Observation], _pending: &[Config]) -> Config {
        let mut u = self.sobol.next_point();
        while u.len() < self.space.encoded_dim() {
            let l = u.len();
            u.push(u[l % self.sobol.dim()]);
        }
        self.space.decode(&u)
    }
}

impl StrategyState for SobolSearch {
    fn state_to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("sobol".into())),
            ("sobol", sobol_to_json(&self.sobol)),
        ])
    }
    fn restore_state(&mut self, state: &Json) -> bool {
        if !kind_matches(state, "sobol") {
            return false;
        }
        match state.get("sobol").and_then(|s| sobol_from_json(self.sobol.dim(), s)) {
            Some(sobol) => {
                self.sobol = sobol;
                true
            }
            None => false,
        }
    }
}

/// Exhaustive grid search with `k` points per numeric axis (§2.1). Cycles
/// if asked for more configurations than the grid holds.
pub struct GridSearch {
    grid: Vec<Config>,
    cursor: usize,
}

impl GridSearch {
    /// Materialize the grid.
    pub fn new(space: &SearchSpace, k: usize) -> Self {
        GridSearch { grid: space.grid(k), cursor: 0 }
    }
    /// Total grid size K^d.
    pub fn len(&self) -> usize {
        self.grid.len()
    }
    /// Whether the grid is empty (never true for valid spaces).
    pub fn is_empty(&self) -> bool {
        self.grid.is_empty()
    }
}

impl Strategy for GridSearch {
    fn name(&self) -> &'static str {
        "grid"
    }
    fn next_config(&mut self, _history: &[Observation], _pending: &[Config]) -> Config {
        let c = self.grid[self.cursor % self.grid.len()].clone();
        self.cursor += 1;
        c
    }
    fn next_config_tracked(
        &mut self,
        history: &[Observation],
        pending: &[Config],
    ) -> (Config, bool) {
        (self.next_config(history, pending), false)
    }
}

impl StrategyState for GridSearch {
    fn state_to_json(&self) -> Json {
        // the grid itself is a pure function of (space, k): only the
        // cursor needs to travel
        Json::obj(vec![
            ("kind", Json::Str("grid".into())),
            ("cursor", Json::Num(self.cursor as f64)),
        ])
    }
    fn restore_state(&mut self, state: &Json) -> bool {
        if !kind_matches(state, "grid") {
            return false;
        }
        match state.get("cursor").and_then(Json::as_i64) {
            Some(cursor) if cursor >= 0 => {
                self.cursor = cursor as usize;
                true
            }
            _ => false,
        }
    }
}

// ---------------------------------------------------------------------------
// Bayesian optimization (§4)
// ---------------------------------------------------------------------------

/// GPHP treatment (§4.2): full slice-sampling MCMC or empirical Bayes.
#[derive(Clone, Debug)]
pub enum GphpMode {
    /// Slice sampling (AMT default; paper: less prone to early overfit).
    Mcmc(SliceConfig),
    /// Marginal-likelihood maximization with `restarts` Nelder–Mead starts.
    EmpiricalBayes { restarts: usize },
}

/// How pending (still-running) evaluations inform new proposals (§4.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AsyncMode {
    /// AMT's production scheme: exclude the neighbourhoods of the L−1
    /// pending candidates through the acquisition penalty.
    #[default]
    Exclusion,
    /// The improvement §4.4 sketches ("asynchronous processing could be
    /// based on fantasizing"): kriging-believer fantasies — pending
    /// configurations enter the fit with their posterior-mean values, so
    /// the surrogate's uncertainty collapses around in-flight work.
    Fantasies,
}

/// BO engine configuration.
#[derive(Clone, Debug)]
pub struct BoConfig {
    /// Random/Sobol evaluations before the GP turns on.
    pub init_random: usize,
    /// GPHP treatment.
    pub gphp: GphpMode,
    /// Acquisition optimizer settings.
    pub acq: AcquisitionConfig,
    /// Enable Kumaraswamy input warping (ablation toggle; default on).
    pub input_warping: bool,
    /// Cap on GP training-set size (the paper notes the cubic scaling of
    /// GPs; beyond this the most recent observations are kept).
    pub max_fit_points: usize,
    /// Pending-candidate handling under parallelism.
    pub async_mode: AsyncMode,
    /// Empirical-Bayes refit cadence: reuse the cached theta and extend
    /// its Cholesky factor by rank-1 row appends (O(N²) per new
    /// observation) until this many rows have been appended, then run the
    /// full marginal-likelihood optimization again. 0 disables the cache
    /// (every refit is a full O(N³) optimization). Ignored in MCMC mode.
    pub eb_refit_every: usize,
}

impl Default for BoConfig {
    fn default() -> Self {
        BoConfig {
            init_random: 4,
            gphp: GphpMode::Mcmc(SliceConfig::light()),
            acq: AcquisitionConfig::default(),
            input_warping: true,
            max_fit_points: 512,
            async_mode: AsyncMode::Exclusion,
            eb_refit_every: 5,
        }
    }
}

/// Cached empirical-Bayes posterior basis: the fitted theta plus the
/// Cholesky factor over the rows it covers, extendable in O(N²) per fresh
/// observation via [`chol_append_row`] (DESIGN.md §4).
struct EbCache {
    theta: Theta,
    /// Rows the factor covers (must stay a prefix of the live dataset).
    x: Dataset,
    /// Cholesky factor of K(x, x) + reg I under `theta`.
    l: Matrix,
    /// Dataset size when theta was last fully re-optimized.
    fitted_n: usize,
}

/// GP-based Bayesian optimization: the algorithm of §4, end to end.
pub struct BayesianOptimization {
    space: SearchSpace,
    backend: Arc<dyn SurrogateBackend>,
    config: BoConfig,
    rng: Rng,
    sobol_init: Sobol,
    /// Last accepted theta — used to warm-start the next MCMC chain.
    last_theta: Option<Theta>,
    /// Observations injected by warm start (§5.3), prepended to history.
    transferred: Vec<Observation>,
    /// Rank-1-extendable EB posterior basis.
    eb_cache: Option<EbCache>,
}

impl BayesianOptimization {
    /// New BO strategy over `space` with the given surrogate backend.
    pub fn new(
        space: SearchSpace,
        backend: Arc<dyn SurrogateBackend>,
        config: BoConfig,
        seed: u64,
    ) -> Self {
        let dim = space.encoded_dim().min(crate::sobol::MAX_DIM);
        BayesianOptimization {
            space,
            backend,
            config,
            rng: Rng::new(seed),
            sobol_init: Sobol::new(dim),
            last_theta: None,
            transferred: Vec::new(),
            eb_cache: None,
        }
    }

    /// Inject warm-start observations (already remapped into this space).
    pub fn add_transferred(&mut self, obs: Vec<Observation>) {
        self.transferred.extend(obs);
    }

    /// Count of transferred observations currently held.
    pub fn transferred_len(&self) -> usize {
        self.transferred.len()
    }

    fn initial_design(&mut self) -> Config {
        // Sobol-spread initial design, a standard upgrade over pure random
        let mut u = self.sobol_init.next_point();
        while u.len() < self.space.encoded_dim() {
            let l = u.len();
            u.push(self.rng.uniform());
            let _ = l;
        }
        // jitter to avoid deterministic collisions across parallel workers
        for v in u.iter_mut() {
            *v = (*v + 0.02 * self.rng.normal()).clamp(0.0, 1.0);
        }
        self.space.decode(&u)
    }

    /// Encode (transferred + live) history into a contiguous dataset.
    fn encode_history(&self, history: &[Observation]) -> (Dataset, Vec<f64>) {
        let mut all: Vec<&Observation> =
            self.transferred.iter().chain(history.iter()).collect();
        if all.len() > self.config.max_fit_points {
            let skip = all.len() - self.config.max_fit_points;
            all.drain(..skip);
        }
        let d = self.space.encoded_dim();
        let mut xs = Dataset::with_capacity(d, all.len());
        let mut ys = Vec::with_capacity(all.len());
        for o in &all {
            if let Ok(x) = self.space.encode(&o.config) {
                xs.push_row(&x);
                ys.push(o.value);
            }
        }
        (xs, ys)
    }

    /// Try the O(N²) empirical-Bayes refit: the cached factor must cover a
    /// prefix of `xs`, and no more than `eb_refit_every` rows may have
    /// accumulated since the last full theta optimization. Appended rows
    /// extend the factor via [`chol_append_row`]. Returns the refitted
    /// model (re-arming the cache) or `None` when a full refit is due.
    fn try_eb_rank1(&mut self, xs: &Dataset, ys: &[f64]) -> Option<GpModel> {
        if self.config.eb_refit_every == 0 {
            return None;
        }
        let cache = self.eb_cache.take()?;
        let d = xs.dim();
        let covered = cache.x.len();
        let usable = covered <= xs.len()
            && xs.len() >= 2
            && xs.len() - cache.fitted_n <= self.config.eb_refit_every
            && cache.x.flat() == &xs.flat()[..covered * d];
        if !usable {
            return None;
        }
        let mut cache = cache;
        let reg = cache.theta.noise() + kernel::JITTER;
        let k_diag = cache.theta.amp() + reg;
        for i in covered..xs.len() {
            let row = xs.row(i);
            let col = kernel::cross_row(row, &cache.x, &cache.theta);
            match chol_append_row(&cache.l, &col, k_diag) {
                Ok(l) => {
                    cache.l = l;
                    cache.x.push_row(row);
                }
                Err(_) => return None, // numerically degenerate ⇒ full refit
            }
        }
        let model = GpModel::fit_from_factor(xs, ys, cache.theta.clone(), cache.l.clone())?;
        self.last_theta = Some(cache.theta.clone());
        self.eb_cache = Some(cache);
        Some(model)
    }

    /// Fit the surrogate on (transferred + live) history. Public so benches
    /// can measure the fit in isolation.
    pub fn fit_model(&mut self, history: &[Observation]) -> Option<GpModel> {
        let (xs, ys) = self.encode_history(history);
        if xs.len() < 2 {
            return None;
        }
        let d = self.space.encoded_dim();
        let (m, s) = crate::gp::normalization(&ys);
        let yn: Vec<f64> = ys.iter().map(|v| (v - m) / s).collect();

        if let GphpMode::EmpiricalBayes { restarts } = self.config.gphp {
            if let Some(model) = self.try_eb_rank1(&xs, &ys) {
                return Some(model);
            }
            // full O(N³) refit: optimize theta, factorize once, re-arm the
            // rank-1 cache with the fresh factor
            let mut theta = fit_empirical_bayes(
                self.backend.as_ref(),
                &xs,
                &yn,
                d,
                restarts,
                &mut self.rng,
            );
            if !self.config.input_warping {
                theta = theta.with_identity_warp();
            }
            self.last_theta = Some(theta.clone());
            let model = GpModel::fit(self.backend.as_ref(), &xs, &ys, vec![theta.clone()])?;
            self.eb_cache = Some(EbCache {
                theta,
                x: xs.clone(),
                l: model.posteriors[0].l.clone(),
                fitted_n: xs.len(),
            });
            return Some(model);
        }

        let GphpMode::Mcmc(cfg) = &self.config.gphp else { unreachable!() };
        let mut thetas =
            sample_gphp(self.backend.as_ref(), &xs, &yn, d, cfg, &mut self.rng, self.last_theta.clone());
        if !self.config.input_warping {
            thetas = thetas.into_iter().map(|t| t.with_identity_warp()).collect();
        }
        self.last_theta = thetas.last().cloned();
        GpModel::fit(self.backend.as_ref(), &xs, &ys, thetas)
    }

    /// Run one full propose step and also return the acquisition details.
    pub fn propose_detailed(
        &mut self,
        history: &[Observation],
        pending: &[Config],
    ) -> (Config, Option<Proposal>) {
        let live = history.len();
        if live + pending.len() < self.config.init_random && self.transferred.is_empty() {
            return (self.initial_design(), None);
        }
        let Some(model) = self.fit_model(history) else {
            return (self.initial_design(), None);
        };
        let pending_enc: Vec<Vec<f64>> =
            pending.iter().filter_map(|c| self.space.encode(c).ok()).collect();
        let d = self.space.encoded_dim();
        let acq = self.config.acq;

        // §4.4 fantasizing: refit with pending points at their posterior
        // means, then propose with no exclusion penalty — the collapsed
        // uncertainty at in-flight locations provides the diversity.
        let (model, pending_enc) = if self.config.async_mode == AsyncMode::Fantasies
            && !pending_enc.is_empty()
        {
            let mut fantasized: Vec<Observation> = history.to_vec();
            for (cfg, enc) in pending.iter().zip(&pending_enc) {
                let (mu_raw, _) = model.predict_raw(self.backend.as_ref(), enc);
                fantasized.push(Observation { config: cfg.clone(), value: mu_raw });
            }
            match self.fit_model(&fantasized) {
                Some(m) => (m, Vec::new()),
                None => (model, pending_enc),
            }
        } else {
            (model, pending_enc)
        };

        let prop =
            propose(&model, self.backend.as_ref(), d, &pending_enc, &acq, &mut self.rng);
        let config = self.space.decode(&prop.x);
        // integer/categorical rounding can collide with a pending config;
        // fall back to a random sample to keep workers busy with new points
        let clash = pending.iter().any(|p| *p == config)
            || history.iter().any(|o| o.config == config);
        if clash {
            (self.space.sample(&mut self.rng), Some(prop))
        } else {
            (config, Some(prop))
        }
    }
}

impl Strategy for BayesianOptimization {
    fn name(&self) -> &'static str {
        "bayesian"
    }
    fn next_config(&mut self, history: &[Observation], pending: &[Config]) -> Config {
        self.propose_detailed(history, pending).0
    }
    fn next_config_tracked(
        &mut self,
        history: &[Observation],
        pending: &[Config],
    ) -> (Config, bool) {
        // value-free only on the paths that provably never reach the GP
        // fit: the initial design, and histories too small to fit (where
        // `fit_model` bails before touching the RNG). Everything past
        // that is value-dependent — even a failed fit may have consumed
        // RNG draws in a y-dependent way (MCMC slice sampling), so the
        // conservative flag keeps commits byte-equivalent to the
        // synchronous recompute.
        let live = history.len();
        if live + pending.len() < self.config.init_random && self.transferred.is_empty() {
            return (self.initial_design(), false);
        }
        if self.encode_history(history).0.len() < 2 {
            return (self.initial_design(), false);
        }
        (self.propose_detailed(history, pending).0, true)
    }
}

impl StrategyState for BayesianOptimization {
    fn state_to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("bayesian".into())),
            ("rng", self.rng.state_to_json()),
            ("sobol_init", sobol_to_json(&self.sobol_init)),
            (
                "last_theta",
                self.last_theta.as_ref().map(Theta::to_json).unwrap_or(Json::Null),
            ),
            ("transferred", observations_to_json(&self.transferred)),
            (
                "eb_cache",
                match &self.eb_cache {
                    None => Json::Null,
                    // the exact Cholesky factor must travel: a fresh
                    // factorization under the same theta is only equal
                    // to ~1e-10, not bit-equal, and the invariant is
                    // a bit-identical remaining proposal stream
                    Some(c) => Json::obj(vec![
                        ("theta", c.theta.to_json()),
                        ("x", dataset_to_json(&c.x)),
                        ("l", matrix_to_json(&c.l)),
                        ("fitted_n", Json::Num(c.fitted_n as f64)),
                    ]),
                },
            ),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> bool {
        if !kind_matches(state, "bayesian") {
            return false;
        }
        let Some(rng) = state.get("rng").and_then(Rng::from_state_json) else { return false };
        let Some(sobol_init) = state
            .get("sobol_init")
            .and_then(|s| sobol_from_json(self.sobol_init.dim(), s))
        else {
            return false;
        };
        let last_theta = match state.get("last_theta") {
            None | Some(Json::Null) => None,
            Some(t) => match Theta::from_json(t) {
                Some(t) => Some(t),
                None => return false,
            },
        };
        let Some(transferred) =
            state.get("transferred").and_then(observations_from_json)
        else {
            return false;
        };
        let eb_cache = match state.get("eb_cache") {
            None | Some(Json::Null) => None,
            Some(c) => {
                let parsed = (|| {
                    Some(EbCache {
                        theta: Theta::from_json(c.get("theta")?)?,
                        x: dataset_from_json(c.get("x")?)?,
                        l: matrix_from_json(c.get("l")?)?,
                        fitted_n: c.get("fitted_n")?.as_i64()? as usize,
                    })
                })();
                match parsed {
                    Some(cache) => Some(cache),
                    None => return false,
                }
            }
        };
        self.rng = rng;
        self.sobol_init = sobol_init;
        self.last_theta = last_theta;
        self.transferred = transferred;
        self.eb_cache = eb_cache;
        true
    }
}

/// Build a strategy by CLI name.
pub fn by_name(
    name: &str,
    space: &SearchSpace,
    backend: Arc<dyn SurrogateBackend>,
    seed: u64,
) -> Option<Box<dyn Strategy>> {
    Some(match name {
        "random" => Box::new(RandomSearch::new(space.clone(), seed)),
        "sobol" => Box::new(SobolSearch::new(space.clone())),
        "grid" => Box::new(GridSearch::new(space, 4)),
        "bayesian" | "bo" => {
            Box::new(BayesianOptimization::new(space.clone(), backend, BoConfig::default(), seed))
        }
        _ => return None,
    })
}

/// Build the strategy a validated tuning-job request names, seeding BO
/// with warm-start transfer observations. This is the **single**
/// construction path shared by the API layer (`AmtService`) and remote
/// workers (`distributed::worker`): cross-plane bit-identity depends on
/// both sides wiring strategies exactly the same way, so any change to
/// the wiring belongs here, not in either caller.
pub fn for_request(
    name: &str,
    space: &SearchSpace,
    backend: Arc<dyn SurrogateBackend>,
    seed: u64,
    transferred: Vec<Observation>,
) -> Option<Box<dyn Strategy>> {
    match name {
        "bayesian" | "bo" => {
            let mut bo =
                BayesianOptimization::new(space.clone(), backend, BoConfig::default(), seed);
            bo.add_transferred(transferred);
            Some(Box::new(bo))
        }
        other => by_name(other, space, backend, seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::NativeBackend;
    use crate::space::{continuous, Scaling, Value};

    fn space_2d() -> SearchSpace {
        SearchSpace::new(vec![
            continuous("a", 0.0, 1.0, Scaling::Linear),
            continuous("b", 0.0, 1.0, Scaling::Linear),
        ])
        .unwrap()
    }

    fn quadratic(config: &Config) -> f64 {
        let a = config.get("a").unwrap().as_f64().unwrap();
        let b = config.get("b").unwrap().as_f64().unwrap();
        (a - 0.3).powi(2) + (b - 0.6).powi(2)
    }

    #[test]
    fn random_search_stays_in_space() {
        let mut s = RandomSearch::new(space_2d(), 1);
        for _ in 0..50 {
            let c = s.next_config(&[], &[]);
            assert!(space_2d().encode(&c).is_ok());
        }
    }

    #[test]
    fn sobol_search_covers_space() {
        let mut s = SobolSearch::new(space_2d());
        let configs: Vec<Config> = (0..64).map(|_| s.next_config(&[], &[])).collect();
        let any_low = configs
            .iter()
            .any(|c| c.get("a").unwrap().as_f64().unwrap() < 0.25);
        let any_high = configs
            .iter()
            .any(|c| c.get("a").unwrap().as_f64().unwrap() > 0.75);
        assert!(any_low && any_high);
    }

    #[test]
    fn grid_search_cycles() {
        let mut s = GridSearch::new(&space_2d(), 3);
        assert_eq!(s.len(), 9);
        let first = s.next_config(&[], &[]);
        for _ in 0..8 {
            s.next_config(&[], &[]);
        }
        let again = s.next_config(&[], &[]);
        assert_eq!(first, again);
    }

    #[test]
    fn bo_beats_random_on_quadratic() {
        // small, seeded head-to-head: BO should reach a better best-so-far
        // than random search with the same budget on a smooth function
        let budget = 18;
        let run = |mut strat: Box<dyn Strategy>, seed: u64| -> f64 {
            let mut rng = Rng::new(seed);
            let mut history: Vec<Observation> = Vec::new();
            for _ in 0..budget {
                let c = strat.next_config(&history, &[]);
                let v = quadratic(&c) + 0.001 * rng.normal();
                history.push(Observation { config: c, value: v });
            }
            history.iter().map(|o| o.value).fold(f64::INFINITY, f64::min)
        };
        let mut bo_wins = 0;
        for seed in 0..3 {
            let bo = run(
                Box::new(BayesianOptimization::new(
                    space_2d(),
                    Arc::new(NativeBackend),
                    BoConfig {
                        init_random: 4,
                        gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                        acq: AcquisitionConfig { num_anchors: 128, ..Default::default() },
                        ..Default::default()
                    },
                    seed,
                )),
                seed,
            );
            let rnd = run(Box::new(RandomSearch::new(space_2d(), seed)), seed);
            if bo <= rnd {
                bo_wins += 1;
            }
        }
        assert!(bo_wins >= 2, "BO won only {bo_wins}/3 against random");
    }

    #[test]
    fn eb_rank1_cache_matches_full_refit_quality() {
        // the rank-1 path must produce the same posterior as a fresh
        // factorization under the same theta and data
        let mut bo = BayesianOptimization::new(
            space_2d(),
            Arc::new(NativeBackend),
            BoConfig {
                init_random: 2,
                gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                acq: AcquisitionConfig { num_anchors: 32, ..Default::default() },
                eb_refit_every: 8,
                ..Default::default()
            },
            41,
        );
        let mut rng = Rng::new(42);
        let mut history = Vec::new();
        for _ in 0..6 {
            let c = space_2d().sample(&mut rng);
            let v = quadratic(&c);
            history.push(Observation { config: c, value: v });
        }
        // first fit: full refit, arms the cache
        let m_full = bo.fit_model(&history).unwrap();
        let cached_theta = bo.eb_cache.as_ref().unwrap().theta.clone();
        // add one observation: the next fit must take the rank-1 path
        let c = space_2d().sample(&mut rng);
        history.push(Observation { config: c, value: 0.4 });
        let m_rank1 = bo.fit_model(&history).unwrap();
        assert_eq!(m_rank1.posteriors.len(), 1);
        assert_eq!(m_rank1.posteriors[0].theta, cached_theta, "theta must be reused");
        assert_eq!(m_rank1.posteriors[0].x.len(), 7);
        // cross-check against a from-scratch factorization with that theta
        let (xs, ys) = bo.encode_history(&history);
        let reference =
            GpModel::fit(&NativeBackend, &xs, &ys, vec![cached_theta]).unwrap();
        let probe = Dataset::from_row(&[0.35, 0.55]);
        let a = m_rank1.score(&NativeBackend, &probe)[0];
        let b = reference.score(&NativeBackend, &probe)[0];
        assert!((a.mu - b.mu).abs() < 1e-9, "{} vs {}", a.mu, b.mu);
        assert!((a.var - b.var).abs() < 1e-9, "{} vs {}", a.var, b.var);
        let _ = m_full;
    }

    #[test]
    fn eb_cache_expires_after_refit_cadence() {
        let mut bo = BayesianOptimization::new(
            space_2d(),
            Arc::new(NativeBackend),
            BoConfig {
                init_random: 2,
                gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                acq: AcquisitionConfig { num_anchors: 32, ..Default::default() },
                eb_refit_every: 2,
                ..Default::default()
            },
            43,
        );
        let mut rng = Rng::new(44);
        let mut history = Vec::new();
        for _ in 0..5 {
            let c = space_2d().sample(&mut rng);
            let v = quadratic(&c);
            history.push(Observation { config: c, value: v });
        }
        bo.fit_model(&history).unwrap();
        let fitted_n = bo.eb_cache.as_ref().unwrap().fitted_n;
        assert_eq!(fitted_n, 5);
        // exceed the cadence: 3 appended rows > eb_refit_every = 2 forces
        // a full refit, which re-arms the cache at the new size
        for _ in 0..3 {
            let c = space_2d().sample(&mut rng);
            history.push(Observation { config: c, value: quadratic(&c) });
        }
        bo.fit_model(&history).unwrap();
        assert_eq!(bo.eb_cache.as_ref().unwrap().fitted_n, 8, "full refit must re-arm");
    }

    #[test]
    fn bo_avoids_pending_duplicates() {
        let mut bo = BayesianOptimization::new(
            space_2d(),
            Arc::new(NativeBackend),
            BoConfig {
                init_random: 2,
                gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                acq: AcquisitionConfig { num_anchors: 64, ..Default::default() },
                ..Default::default()
            },
            7,
        );
        let mut history = Vec::new();
        for i in 0..6 {
            let c = bo.next_config(&history, &[]);
            history.push(Observation { config: c, value: (i as f64 - 3.0).abs() });
        }
        let pending = vec![history[0].config.clone()];
        let c = bo.next_config(&history, &pending);
        assert_ne!(c, pending[0]);
    }

    #[test]
    fn warm_start_observations_activate_model_immediately() {
        let mut bo = BayesianOptimization::new(
            space_2d(),
            Arc::new(NativeBackend),
            BoConfig {
                init_random: 4,
                gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                acq: AcquisitionConfig { num_anchors: 64, ..Default::default() },
                ..Default::default()
            },
            11,
        );
        let mut parent = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let c = space_2d().sample(&mut rng);
            let v = quadratic(&c);
            parent.push(Observation { config: c, value: v });
        }
        bo.add_transferred(parent);
        // with zero live history but 10 transferred points, the model fits
        let (c, prop) = bo.propose_detailed(&[], &[]);
        assert!(prop.is_some(), "warm-started BO should be model-driven");
        assert!(space_2d().encode(&c).is_ok());
    }

    #[test]
    fn fantasizing_avoids_pending_without_penalty() {
        // with fantasies, the engine should not re-propose an in-flight
        // point even though the exclusion penalty is disabled (radius ~0)
        let mut bo = BayesianOptimization::new(
            space_2d(),
            Arc::new(NativeBackend),
            BoConfig {
                init_random: 2,
                gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                acq: AcquisitionConfig {
                    num_anchors: 128,
                    exclusion_radius: 1e-12,
                    ..Default::default()
                },
                async_mode: AsyncMode::Fantasies,
                ..Default::default()
            },
            19,
        );
        let mut history = Vec::new();
        for i in 0..6 {
            let c = bo.next_config(&history, &[]);
            history.push(Observation { config: c, value: quadratic_i(i) });
        }
        // the point the model itself would pick next becomes "pending"
        let (next, _) = bo.propose_detailed(&history, &[]);
        let pending = vec![next.clone()];
        let (under_fantasy, prop) = bo.propose_detailed(&history, &pending);
        assert!(prop.is_some());
        let d: f64 = space_2d()
            .encode(&under_fantasy)
            .unwrap()
            .iter()
            .zip(space_2d().encode(&next).unwrap().iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(d > 1e-3, "fantasy proposal duplicated the pending point (d={d})");
    }

    fn quadratic_i(i: usize) -> f64 {
        (i as f64 * 0.13 - 0.3).powi(2)
    }

    /// Drive `a` for `warmup` proposals, freeze, thaw into `b`, then
    /// require the next `run` proposals (with history evolving the same
    /// way on both sides) to be identical.
    fn assert_resumes_identically(
        mut a: Box<dyn Strategy>,
        mut b: Box<dyn Strategy>,
        warmup: usize,
        run: usize,
    ) {
        let mut history = Vec::new();
        for _ in 0..warmup {
            let c = a.next_config(&history, &[]);
            let v = quadratic(&c);
            history.push(Observation { config: c, value: v });
        }
        let frozen = a.state_to_json().to_string();
        assert!(
            b.restore_state(&crate::json::parse(&frozen).unwrap()),
            "{}: restore_state failed",
            a.name()
        );
        let mut hist_b = history.clone();
        for _ in 0..run {
            let ca = a.next_config(&history, &[]);
            let cb = b.next_config(&hist_b, &[]);
            assert_eq!(ca, cb, "{}: thawed proposal stream diverged", a.name());
            let v = quadratic(&ca);
            history.push(Observation { config: ca, value: v });
            hist_b.push(Observation { config: cb, value: v });
        }
    }

    #[test]
    fn model_free_strategy_state_roundtrips_bit_identical() {
        let space = space_2d();
        assert_resumes_identically(
            Box::new(RandomSearch::new(space.clone(), 5)),
            Box::new(RandomSearch::new(space.clone(), 5)),
            9,
            20,
        );
        assert_resumes_identically(
            Box::new(SobolSearch::new(space.clone())),
            Box::new(SobolSearch::new(space.clone())),
            9,
            20,
        );
        assert_resumes_identically(
            Box::new(GridSearch::new(&space, 3)),
            Box::new(GridSearch::new(&space, 3)),
            5,
            10,
        );
    }

    #[test]
    fn bo_state_roundtrips_bit_identical_including_eb_cache() {
        let make = || {
            BayesianOptimization::new(
                space_2d(),
                Arc::new(NativeBackend),
                BoConfig {
                    init_random: 2,
                    gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                    acq: AcquisitionConfig { num_anchors: 32, ..Default::default() },
                    eb_refit_every: 8,
                    ..Default::default()
                },
                47,
            )
        };
        // warm past the initial design so the EB cache is armed when frozen
        let mut a = make();
        let mut history = Vec::new();
        let mut rng = Rng::new(48);
        for _ in 0..6 {
            let c = a.next_config(&history, &[]);
            history.push(Observation { config: c, value: rng.uniform() });
        }
        assert!(a.eb_cache.is_some(), "cache must be armed before the freeze");
        let frozen = a.state_to_json().to_string();
        let mut b = make();
        assert!(b.restore_state(&crate::json::parse(&frozen).unwrap()));
        let mut hist_b = history.clone();
        for _ in 0..4 {
            let ca = a.next_config(&history, &[]);
            let cb = b.next_config(&hist_b, &[]);
            assert_eq!(ca, cb, "thawed BO proposal stream diverged");
            let v = rng.uniform();
            history.push(Observation { config: ca, value: v });
            hist_b.push(Observation { config: cb, value: v });
        }
    }

    #[test]
    fn bo_mcmc_state_roundtrips_with_transferred_observations() {
        let make = || {
            let mut bo = BayesianOptimization::new(
                space_2d(),
                Arc::new(NativeBackend),
                BoConfig {
                    init_random: 2,
                    gphp: GphpMode::Mcmc(SliceConfig::light()),
                    acq: AcquisitionConfig { num_anchors: 32, ..Default::default() },
                    ..Default::default()
                },
                51,
            );
            let mut prng = Rng::new(52);
            let parent: Vec<Observation> = (0..5)
                .map(|_| {
                    let c = space_2d().sample(&mut prng);
                    let v = quadratic(&c);
                    Observation { config: c, value: v }
                })
                .collect();
            bo.add_transferred(parent);
            bo
        };
        assert_resumes_identically(Box::new(make()), Box::new(make()), 3, 3);
    }

    #[test]
    fn restore_state_rejects_kind_mismatch() {
        let space = space_2d();
        let frozen = RandomSearch::new(space.clone(), 1).state_to_json();
        let mut sobol = SobolSearch::new(space.clone());
        assert!(!sobol.restore_state(&frozen));
        let mut grid = GridSearch::new(&space, 3);
        assert!(!grid.restore_state(&frozen));
        let mut random = RandomSearch::new(space, 2);
        assert!(random.restore_state(&frozen));
        assert!(!random.restore_state(&Json::Null));
    }

    #[test]
    fn fantasy_value_is_current_best_or_zero() {
        assert_eq!(fantasy_value(&[]).to_bits(), 0.0f64.to_bits());
        let mut rng = Rng::new(3);
        let obs: Vec<Observation> = [0.7, 0.2, 0.9]
            .iter()
            .map(|&v| Observation { config: space_2d().sample(&mut rng), value: v })
            .collect();
        assert_eq!(fantasy_value(&obs).to_bits(), 0.2f64.to_bits());
    }

    #[test]
    fn value_free_speculation_commits_and_matches_synchronous_path() {
        // a random-search speculation ignores values entirely: committing
        // it must be byte-equivalent to the synchronous recompute with
        // the real (different) outcome value
        let mut spec_strat = RandomSearch::new(space_2d(), 9);
        let mut sync_strat = RandomSearch::new(space_2d(), 9);
        let mut rng = Rng::new(10);
        let mut history = Vec::new();
        for _ in 0..3 {
            let c = space_2d().sample(&mut rng);
            history.push(Observation { config: c, value: rng.uniform() });
        }
        let in_flight = space_2d().sample(&mut rng);
        let spec = speculate(&mut spec_strat, &history, &[], in_flight.clone());
        assert!(!spec.value_dependent);

        // the real outcome lands with a value far from the fantasy
        history.push(Observation { config: in_flight, value: 123.456 });
        assert!(spec.matches(&history, &[]));
        let sync = sync_strat.next_config(&history, &[]);
        assert_eq!(spec.config, sync, "committed speculation diverged from sync");
        // and the advanced strategy state agrees too
        assert_eq!(
            spec_strat.state_to_json().to_string(),
            sync_strat.state_to_json().to_string()
        );
    }

    #[test]
    fn value_dependent_speculation_discards_bit_identically() {
        let make = || {
            BayesianOptimization::new(
                space_2d(),
                Arc::new(NativeBackend),
                BoConfig {
                    init_random: 2,
                    gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                    acq: AcquisitionConfig { num_anchors: 32, ..Default::default() },
                    eb_refit_every: 8,
                    ..Default::default()
                },
                61,
            )
        };
        let mut spec_strat = make();
        let mut sync_strat = make();
        let mut rng = Rng::new(62);
        let mut history = Vec::new();
        for _ in 0..5 {
            let c = space_2d().sample(&mut rng);
            let v = quadratic(&c);
            history.push(Observation { config: c, value: v });
        }
        // keep both strategies at the same warmed state
        let warm = spec_strat.next_config(&history, &[]);
        let warm_sync = sync_strat.next_config(&history, &[]);
        assert_eq!(warm, warm_sync);
        history.push(Observation { config: warm.clone(), value: quadratic(&warm) });

        let in_flight = space_2d().sample(&mut rng);
        let mut spec =
            speculate(&mut spec_strat, &history, &[], in_flight.clone());
        assert!(spec.value_dependent, "model-driven BO must be value-dependent");

        // the real value differs from the constant-liar fantasy ⇒ discard
        history.push(Observation { config: in_flight, value: 7.5 });
        assert!(!spec.matches(&history, &[]));
        assert!(spec_strat.restore_state(&spec.saved));
        let a = spec_strat.next_config(&history, &[]);
        let b = sync_strat.next_config(&history, &[]);
        assert_eq!(a, b, "discard fallback diverged from synchronous propose");

        // structural mismatches also refuse the commit
        spec.value_dependent = false;
        assert!(!spec.matches(&history[..history.len() - 1], &[])); // wrong len
        let other = space_2d().sample(&mut rng);
        assert!(!spec.matches(&history, &[other])); // pending set changed
    }

    #[test]
    fn speculation_json_roundtrips() {
        let mut strat = RandomSearch::new(space_2d(), 77);
        let mut rng = Rng::new(78);
        let history = vec![Observation {
            config: space_2d().sample(&mut rng),
            value: 0.25,
        }];
        let pending = vec![space_2d().sample(&mut rng)];
        let fantasy = space_2d().sample(&mut rng);
        let spec = speculate(&mut strat, &history, &pending, fantasy);
        let j = crate::json::parse(&spec.to_json().to_string()).unwrap();
        let back = Speculation::from_json(&j).unwrap();
        assert_eq!(back.config, spec.config);
        assert_eq!(back.fantasy_config, spec.fantasy_config);
        assert_eq!(back.fantasy_value.to_bits(), spec.fantasy_value.to_bits());
        assert_eq!(back.history_len, spec.history_len);
        assert_eq!(back.pending, spec.pending);
        assert_eq!(back.value_dependent, spec.value_dependent);
        assert_eq!(back.saved.to_string(), spec.saved.to_string());
        assert!(Speculation::from_json(&Json::Null).is_none());
    }

    #[test]
    fn by_name_builds_all() {
        let space = space_2d();
        for n in ["random", "sobol", "grid", "bayesian"] {
            assert!(by_name(n, &space, Arc::new(NativeBackend), 1).is_some());
        }
        assert!(by_name("nope", &space, Arc::new(NativeBackend), 1).is_none());
    }

    #[test]
    fn bo_integer_categorical_space_works() {
        use crate::space::{categorical, integer};
        let space = SearchSpace::new(vec![
            integer("n", 1, 20, Scaling::Linear),
            categorical("kind", &["x", "y"]),
        ])
        .unwrap();
        let mut bo = BayesianOptimization::new(
            space.clone(),
            Arc::new(NativeBackend),
            BoConfig {
                init_random: 3,
                gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                acq: AcquisitionConfig { num_anchors: 32, ..Default::default() },
                ..Default::default()
            },
            3,
        );
        let mut history = Vec::new();
        for i in 0..8 {
            let c = bo.next_config(&history, &[]);
            assert!(space.encode(&c).is_ok());
            let n = c.get("n").unwrap().as_f64().unwrap();
            history.push(Observation { config: c, value: (n - 7.0).abs() + i as f64 * 0.01 });
        }
        // model-driven proposals must still produce valid Int/Cat values
        let c = bo.next_config(&history, &[]);
        assert!(matches!(c.get("n"), Some(Value::Int(_))));
    }
}
