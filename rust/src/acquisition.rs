//! Acquisition functions and their optimization (§4.3–§4.4).
//!
//! AMT's scheme, reproduced here: a Sobol sequence populates the encoded
//! search space with a dense pseudo-random grid; marginal posterior scores
//! are evaluated at those anchors in one batch (the AOT `posterior_ei`
//! artifact, or the native backend); the top anchors seed a local
//! Nelder–Mead optimization of the EI; and an asynchronous-parallelism
//! penalty keeps new proposals away from the L−1 *pending* candidates so a
//! worker slot freed mid-tuning never receives a duplicate suggestion
//! (§4.4: "making sure, of course, not to select one of the L−1 pending
//! candidates", with diversity induced through the acquisition optimizer).
//!
//! The anchor grid lives in one contiguous [`Dataset`]; when the model
//! holds a single posterior (empirical Bayes) the grid is scored in
//! parallel anchor blocks, and with multiple posteriors (MCMC) the
//! fan-out happens across posterior samples inside [`GpModel::score`] —
//! either way the reduction is order-stable, so proposals are bit-identical
//! to the sequential path (DESIGN.md §5).

use crate::gp::fit::{nelder_mead, NmOptions};
use crate::gp::{Dataset, GpModel, Score, SurrogateBackend};
use crate::parallel;
use crate::rng::Rng;
use crate::sobol::Sobol;

/// Anchor rows per parallel scoring block.
const ANCHOR_BLOCK: usize = 128;

/// Which acquisition rule picks the next candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AcquisitionKind {
    /// Expected improvement (AMT's default).
    ExpectedImprovement,
    /// Marginal Thompson sampling on the Sobol grid (the tractable
    /// approximation described in §4.3).
    ThompsonMarginal,
    /// Cost-aware EI (§4.3's "alternative acquisition functions to make
    /// the EI cost-aware and steer the hyperparameter search towards
    /// cheaper configurations", Lee et al. / Guinet et al.):
    /// EI(x) / cost(x)^alpha with the exponent in per-mille (integer to
    /// keep the config `Copy`; 1000 = EI-per-unit-cost, 0 = plain EI).
    CostAwareEi { alpha_millis: u32 },
}

/// Acquisition optimizer configuration.
#[derive(Clone, Copy, Debug)]
pub struct AcquisitionConfig {
    /// Acquisition rule.
    pub kind: AcquisitionKind,
    /// Number of Sobol anchor points scored per proposal.
    pub num_anchors: usize,
    /// How many top anchors get a local EI optimization.
    pub num_local_starts: usize,
    /// Max function evaluations per local optimization.
    pub local_evals: usize,
    /// Radius of the pending-candidate exclusion penalty (encoded units).
    pub exclusion_radius: f64,
}

impl Default for AcquisitionConfig {
    fn default() -> Self {
        AcquisitionConfig {
            kind: AcquisitionKind::ExpectedImprovement,
            num_anchors: 512,
            num_local_starts: 3,
            local_evals: 60,
            exclusion_radius: 0.08,
        }
    }
}

/// Multiplicative penalty pushing proposals away from pending evaluations:
/// ∏ (1 − exp(−‖x − p‖² / r²)). 0 at a pending point, →1 far away.
pub fn pending_penalty(x: &[f64], pending: &[Vec<f64>], radius: f64) -> f64 {
    let mut m = 1.0;
    for p in pending {
        let d2: f64 = x.iter().zip(p).map(|(a, b)| (a - b) * (a - b)).sum();
        m *= 1.0 - (-d2 / (radius * radius)).exp();
    }
    m
}

/// Result of one acquisition round.
#[derive(Clone, Debug)]
pub struct Proposal {
    /// Encoded location of the chosen candidate.
    pub x: Vec<f64>,
    /// Acquisition value at the choice (penalized).
    pub acq_value: f64,
    /// Posterior score at the choice.
    pub score: Score,
}

/// Evaluation-cost model over encoded configurations, used by
/// [`AcquisitionKind::CostAwareEi`] (e.g. predicted training seconds).
pub type CostModel = std::sync::Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>;

/// Score the anchor grid: across posterior samples when the model carries
/// an MCMC ensemble, across contiguous anchor blocks when it carries a
/// single (empirical-Bayes) posterior. Block results are concatenated in
/// grid order, so the output equals one sequential `model.score` call.
fn score_anchors(
    model: &GpModel,
    backend: &dyn SurrogateBackend,
    anchors: &Dataset,
) -> Vec<Score> {
    let single_posterior = model.posteriors.len() == 1;
    // Block splitting is a native-backend optimization only: the HLO
    // artifact pads every execution to its compiled candidate batch, so
    // sub-batch blocks would multiply PJRT executions instead of saving
    // wall clock.
    if single_posterior
        && backend.name() == "native"
        && anchors.len() >= 2 * ANCHOR_BLOCK
        && parallel::max_threads() > 1
    {
        let blocks = anchors.blocks(ANCHOR_BLOCK);
        let per: Vec<Vec<Score>> = parallel::par_map(&blocks, |b| model.score(backend, b));
        per.into_iter().flatten().collect()
    } else {
        model.score(backend, anchors)
    }
}

/// Propose the next encoded candidate.
///
/// `dim` is the encoded dimension; `pending` holds encoded locations whose
/// evaluations are still running (asynchronous mode).
pub fn propose(
    model: &GpModel,
    backend: &dyn SurrogateBackend,
    dim: usize,
    pending: &[Vec<f64>],
    config: &AcquisitionConfig,
    rng: &mut Rng,
) -> Proposal {
    propose_with_cost(model, backend, dim, pending, config, rng, None)
}

/// [`propose`] with an optional cost model for cost-aware EI.
#[allow(clippy::too_many_arguments)]
pub fn propose_with_cost(
    model: &GpModel,
    backend: &dyn SurrogateBackend,
    dim: usize,
    pending: &[Vec<f64>],
    config: &AcquisitionConfig,
    rng: &mut Rng,
    cost: Option<&CostModel>,
) -> Proposal {
    // 1. Sobol anchor grid (§4.3: "populating the search space as densely
    //    as possible"), plus a few uniform points to break Sobol alignment
    //    across repeated calls. The grid is one contiguous dataset.
    let sdim = dim.min(crate::sobol::MAX_DIM);
    let mut sobol = Sobol::new(sdim);
    let mut anchors = Dataset::with_capacity(dim, config.num_anchors + config.num_anchors / 8);
    let mut row = vec![0.0; dim];
    for p in sobol.take_points(config.num_anchors) {
        for (j, v) in row.iter_mut().enumerate() {
            *v = p[j % sdim];
        }
        anchors.push_row(&row);
    }
    for _ in 0..config.num_anchors / 8 {
        for v in row.iter_mut() {
            *v = rng.uniform();
        }
        anchors.push_row(&row);
    }

    // 2. batch-score all anchors (one artifact execution per theta sample;
    //    parallel across posterior samples or anchor blocks)
    let scores = score_anchors(model, backend, &anchors);

    // 3. anchor utility
    let cost_factor = |x: &[f64]| -> f64 {
        match (config.kind, cost) {
            (AcquisitionKind::CostAwareEi { alpha_millis }, Some(c)) => {
                let alpha = alpha_millis as f64 / 1000.0;
                1.0 / c(x).max(1e-9).powf(alpha)
            }
            _ => 1.0,
        }
    };
    let mut ranked: Vec<(usize, f64)> = scores
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let pen = pending_penalty(anchors.row(i), pending, config.exclusion_radius);
            let u = match config.kind {
                AcquisitionKind::ExpectedImprovement => s.ei * pen,
                AcquisitionKind::CostAwareEi { .. } => {
                    s.ei * pen * cost_factor(anchors.row(i))
                }
                AcquisitionKind::ThompsonMarginal => {
                    let draw = s.mu + s.var.max(1e-12).sqrt() * rng.normal();
                    -draw * pen.max(1e-9)
                }
            };
            (i, u)
        })
        .collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    // Thompson: return the best grid draw directly (its classic form)
    if config.kind == AcquisitionKind::ThompsonMarginal {
        let (idx, val) = ranked[0];
        return Proposal { x: anchors.row(idx).to_vec(), acq_value: val, score: scores[idx] };
    }

    // 4. local EI refinement from the top anchors (§4.3: the pseudo-random
    //    grid is "a set of anchor points to initialize the local
    //    optimization of the EI")
    let mut neg_ei = |x: &[f64]| -> Option<f64> {
        if x.iter().any(|v| !(0.0..=1.0).contains(v)) {
            return None; // clamp by rejection: keeps NM inside the cube
        }
        let s = model.score(backend, &Dataset::from_row(x));
        Some(
            -s[0].ei
                * pending_penalty(x, pending, config.exclusion_radius)
                * cost_factor(x),
        )
    };

    let mut best_x = anchors.row(ranked[0].0).to_vec();
    let mut best_v = ranked[0].1;
    for &(idx, anchor_val) in ranked.iter().take(config.num_local_starts) {
        let (x_loc, f_loc) = nelder_mead(
            &mut neg_ei,
            anchors.row(idx),
            &NmOptions { max_evals: config.local_evals, init_step: 0.05, f_tol: 1e-12 },
        );
        let v = -f_loc;
        if v > best_v {
            best_v = v;
            best_x = x_loc;
        } else if anchor_val > best_v {
            best_v = anchor_val;
            best_x = anchors.row(idx).to_vec();
        }
    }
    for v in best_x.iter_mut() {
        *v = v.clamp(0.0, 1.0);
    }
    let score = model.score(backend, &Dataset::from_row(&best_x))[0];
    Proposal { x: best_x, acq_value: best_v, score }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{NativeBackend, Theta};

    fn fitted_model(seed: u64) -> GpModel {
        let mut rng = Rng::new(seed);
        let mut x = Dataset::new(2);
        for _ in 0..15 {
            x.push_row(&[rng.uniform(), rng.uniform()]);
        }
        // minimum near (0.25, 0.75)
        let y: Vec<f64> = x
            .rows()
            .map(|p| (p[0] - 0.25).powi(2) + (p[1] - 0.75).powi(2) + 0.01 * rng.normal())
            .collect();
        GpModel::fit(&NativeBackend, &x, &y, vec![Theta::default_for_dim(2)]).unwrap()
    }

    #[test]
    fn pending_penalty_zero_at_pending_one_far() {
        let pending = vec![vec![0.5, 0.5]];
        assert!(pending_penalty(&[0.5, 0.5], &pending, 0.1) < 1e-9);
        assert!(pending_penalty(&[0.0, 0.0], &pending, 0.1) > 0.999);
        assert_eq!(pending_penalty(&[0.3, 0.3], &[], 0.1), 1.0);
    }

    #[test]
    fn proposal_is_in_unit_cube() {
        let model = fitted_model(1);
        let mut rng = Rng::new(2);
        let p = propose(
            &model,
            &NativeBackend,
            2,
            &[],
            &AcquisitionConfig { num_anchors: 64, ..Default::default() },
            &mut rng,
        );
        assert_eq!(p.x.len(), 2);
        for v in &p.x {
            assert!((0.0..=1.0).contains(v));
        }
        assert!(p.acq_value >= 0.0);
    }

    #[test]
    fn proposal_gravitates_to_good_region() {
        let model = fitted_model(3);
        let mut rng = Rng::new(4);
        let p = propose(
            &model,
            &NativeBackend,
            2,
            &[],
            &AcquisitionConfig { num_anchors: 256, ..Default::default() },
            &mut rng,
        );
        // minimum is at (0.25, 0.75); EI should propose within a reasonable ball
        let d = ((p.x[0] - 0.25).powi(2) + (p.x[1] - 0.75).powi(2)).sqrt();
        assert!(d < 0.45, "proposal {:?} too far from optimum", p.x);
    }

    #[test]
    fn seeded_proposals_are_bit_identical() {
        // the parallel scoring paths must not perturb proposals: two runs
        // from identical seeds produce identical bits
        let model = fitted_model(13);
        let cfg = AcquisitionConfig { num_anchors: 512, ..Default::default() };
        let mut r1 = Rng::new(17);
        let mut r2 = Rng::new(17);
        let a = propose(&model, &NativeBackend, 2, &[], &cfg, &mut r1);
        let b = propose(&model, &NativeBackend, 2, &[], &cfg, &mut r2);
        assert_eq!(a.x, b.x);
        assert_eq!(a.acq_value.to_bits(), b.acq_value.to_bits());
        assert_eq!(a.score, b.score);
    }

    #[test]
    fn block_parallel_anchor_scores_match_sequential() {
        let model = fitted_model(23); // single posterior ⇒ block path
        let mut rng = Rng::new(5);
        let mut anchors = Dataset::new(2);
        for _ in 0..700 {
            anchors.push_row(&[rng.uniform(), rng.uniform()]);
        }
        let blocked = super::score_anchors(&model, &NativeBackend, &anchors);
        let sequential = model.score_sequential(&NativeBackend, &anchors);
        assert_eq!(blocked.len(), sequential.len());
        for (a, b) in blocked.iter().zip(&sequential) {
            assert_eq!(a.ei.to_bits(), b.ei.to_bits());
            assert_eq!(a.mu.to_bits(), b.mu.to_bits());
            assert_eq!(a.var.to_bits(), b.var.to_bits());
        }
    }

    #[test]
    fn pending_exclusion_moves_proposal() {
        let model = fitted_model(5);
        let cfg = AcquisitionConfig { num_anchors: 256, ..Default::default() };
        let mut rng = Rng::new(6);
        let first = propose(&model, &NativeBackend, 2, &[], &cfg, &mut rng);
        // now pretend `first` is pending: next proposal must be elsewhere
        let mut rng = Rng::new(6);
        let second =
            propose(&model, &NativeBackend, 2, &[first.x.clone()], &cfg, &mut rng);
        let d: f64 = first
            .x
            .iter()
            .zip(&second.x)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(d > 0.02, "pending exclusion ignored: d={d}");
    }

    #[test]
    fn thompson_marginal_returns_grid_point() {
        let model = fitted_model(7);
        let mut rng = Rng::new(8);
        let cfg = AcquisitionConfig {
            kind: AcquisitionKind::ThompsonMarginal,
            num_anchors: 128,
            ..Default::default()
        };
        let p = propose(&model, &NativeBackend, 2, &[], &cfg, &mut rng);
        for v in &p.x {
            assert!((0.0..=1.0).contains(v));
        }
    }

    #[test]
    fn cost_aware_ei_prefers_cheaper_region() {
        // two symmetric minima; the cost model makes the x0>0.5 half 10x
        // more expensive — cost-aware EI should propose in the cheap half
        let mut rng = Rng::new(21);
        let mut x = Dataset::new(2);
        for _ in 0..20 {
            x.push_row(&[rng.uniform(), rng.uniform()]);
        }
        let y: Vec<f64> = x
            .rows()
            .map(|p| {
                let d1 = (p[0] - 0.2).powi(2) + (p[1] - 0.5).powi(2);
                let d2 = (p[0] - 0.8).powi(2) + (p[1] - 0.5).powi(2);
                d1.min(d2)
            })
            .collect();
        let model =
            GpModel::fit(&NativeBackend, &x, &y, vec![Theta::default_for_dim(2)]).unwrap();
        let cost: super::CostModel =
            std::sync::Arc::new(|p: &[f64]| if p[0] > 0.5 { 10.0 } else { 1.0 });
        let cfg = AcquisitionConfig {
            kind: AcquisitionKind::CostAwareEi { alpha_millis: 1000 },
            num_anchors: 256,
            ..Default::default()
        };
        let mut cheap_wins = 0;
        for seed in 0..5 {
            let mut rng = Rng::new(100 + seed);
            let p = super::propose_with_cost(
                &model, &NativeBackend, 2, &[], &cfg, &mut rng, Some(&cost),
            );
            if p.x[0] <= 0.5 {
                cheap_wins += 1;
            }
        }
        assert!(cheap_wins >= 4, "cost-aware EI chose the expensive half: {cheap_wins}/5");
    }

    #[test]
    fn local_refinement_beats_plain_grid() {
        // with very few anchors the local optimizer must still find high EI
        let model = fitted_model(9);
        let mut rng_a = Rng::new(10);
        let coarse = propose(
            &model,
            &NativeBackend,
            2,
            &[],
            &AcquisitionConfig {
                num_anchors: 8,
                num_local_starts: 0,
                ..Default::default()
            },
            &mut rng_a,
        );
        let mut rng_b = Rng::new(10);
        let refined = propose(
            &model,
            &NativeBackend,
            2,
            &[],
            &AcquisitionConfig {
                num_anchors: 8,
                num_local_starts: 3,
                local_evals: 120,
                ..Default::default()
            },
            &mut rng_b,
        );
        assert!(refined.acq_value >= coarse.acq_value - 1e-12);
    }
}
