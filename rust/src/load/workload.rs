//! Declarative workload specification for the load & chaos observatory.
//!
//! A [`Workload`] is a JSON-codable description (via the crate's own
//! `json.rs`, like `PlatformConfig`) of a mixed operation stream against
//! [`crate::api::AmtService`]: weighted create traffic (BO / random / grid /
//! warm-start / early-stopping / multi-objective) across weighted tenants
//! with in-flight quotas, polling traffic (describe / list / stop / wait), a
//! throughput schedule of steady / ramp / burst phases, and an inline chaos
//! track (worker kills, late joins, graceful drains, leader close+reopen).
//!
//! `Workload::plan()` expands the spec into a concrete [`Plan`] — the exact
//! op sequence with fully-built `TuningJobRequest`s and chaos firing points —
//! using a single seeded [`Rng`], so the same spec + seed always yields the
//! bit-identical plan (property-tested in `rust/tests/load_harness.rs`).

use crate::config::TuningJobRequest;
use crate::json::{self, Json};
use crate::objectives::{Analytic, Objective};
use crate::rng::Rng;
use crate::space::{Config, SearchSpace};

/// One operation kind in the mix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    CreateBo,
    CreateRandom,
    CreateGrid,
    CreateWarmStart,
    CreateEarlyStopping,
    CreateMultiObjective,
    Describe,
    List,
    Stop,
    Wait,
}

impl OpKind {
    /// Every kind, in canonical order (used by the JSON codec docs).
    pub const ALL: [OpKind; 10] = [
        OpKind::CreateBo,
        OpKind::CreateRandom,
        OpKind::CreateGrid,
        OpKind::CreateWarmStart,
        OpKind::CreateEarlyStopping,
        OpKind::CreateMultiObjective,
        OpKind::Describe,
        OpKind::List,
        OpKind::Stop,
        OpKind::Wait,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            OpKind::CreateBo => "create_bo",
            OpKind::CreateRandom => "create_random",
            OpKind::CreateGrid => "create_grid",
            OpKind::CreateWarmStart => "create_warm_start",
            OpKind::CreateEarlyStopping => "create_early_stopping",
            OpKind::CreateMultiObjective => "create_multiobjective",
            OpKind::Describe => "describe",
            OpKind::List => "list",
            OpKind::Stop => "stop",
            OpKind::Wait => "wait",
        }
    }

    pub fn parse(s: &str) -> Option<OpKind> {
        OpKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }

    /// Whether this kind creates a tuning job.
    pub fn is_create(self) -> bool {
        matches!(
            self,
            OpKind::CreateBo
                | OpKind::CreateRandom
                | OpKind::CreateGrid
                | OpKind::CreateWarmStart
                | OpKind::CreateEarlyStopping
                | OpKind::CreateMultiObjective
        )
    }
}

/// A tenant lane: all creates drawn for this tenant carry its fair-share
/// weight and in-flight quota (0 = unlimited).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    pub weight: u32,
    pub max_in_flight: u32,
}

/// One weighted entry in the operation mix.
#[derive(Clone, Debug, PartialEq)]
pub struct OpMix {
    pub op: OpKind,
    pub weight: u32,
}

/// Throughput shape of one phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    /// Constant target rate.
    Steady,
    /// Linear interpolation from `rate` to `rate_end` across the phase.
    Ramp,
    /// Unpaced: issue ops as fast as the service absorbs them.
    Burst,
}

impl PhaseKind {
    pub fn as_str(self) -> &'static str {
        match self {
            PhaseKind::Steady => "steady",
            PhaseKind::Ramp => "ramp",
            PhaseKind::Burst => "burst",
        }
    }

    pub fn parse(s: &str) -> Option<PhaseKind> {
        match s {
            "steady" => Some(PhaseKind::Steady),
            "ramp" => Some(PhaseKind::Ramp),
            "burst" => Some(PhaseKind::Burst),
            _ => None,
        }
    }
}

/// One phase of the throughput schedule. Rates are ops/second of wall (or
/// virtual) clock; `rate == 0` means unpaced regardless of kind.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PhaseSpec {
    pub kind: PhaseKind,
    pub ops: u32,
    pub rate: f64,
    pub rate_end: f64,
}

/// A chaos event riding the elastic-fleet / recovery machinery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosAction {
    /// Hard-kill worker lane `worker` (index into the initial fleet).
    KillWorker(usize),
    /// Spawn and admit one extra loopback worker mid-run.
    JoinWorker,
    /// Gracefully drain worker lane `worker`.
    DrainWorker(usize),
    /// Close the (durable) leader and reopen it from disk mid-run.
    ReopenLeader,
}

/// A chaos event pinned to a position in the op stream: it fires just
/// before the `at_op`-th operation (0-based, across all phases).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosSpec {
    pub at_op: u32,
    pub action: ChaosAction,
}

/// Shape shared by every created tuning job.
#[derive(Clone, Debug, PartialEq)]
pub struct JobShape {
    pub objective: String,
    pub max_training_jobs: u32,
    pub max_parallel_jobs: u32,
    pub max_retries_per_job: u32,
}

impl Default for JobShape {
    fn default() -> Self {
        JobShape {
            objective: "branin".to_string(),
            max_training_jobs: 3,
            max_parallel_jobs: 2,
            max_retries_per_job: 2,
        }
    }
}

/// Which execution plane the runner drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Plane {
    /// In-process actor scheduler.
    Local,
    /// Loopback distributed worker fleet (RemoteWorkerPool).
    Distributed,
}

impl Plane {
    pub fn as_str(self) -> &'static str {
        match self {
            Plane::Local => "local",
            Plane::Distributed => "distributed",
        }
    }

    pub fn parse(s: &str) -> Option<Plane> {
        match s {
            "local" => Some(Plane::Local),
            "distributed" => Some(Plane::Distributed),
            _ => None,
        }
    }
}

/// The full declarative workload (DESIGN.md §16).
#[derive(Clone, Debug, PartialEq)]
pub struct Workload {
    /// Prefix for every created job name (`{name}-{seq:05}`).
    pub name: String,
    /// Master seed: same spec + seed ⇒ bit-identical plan.
    pub seed: u64,
    pub plane: Plane,
    /// Initial fleet size on the distributed plane.
    pub workers: usize,
    /// Open the service durably (WAL + snapshots); required for
    /// `ReopenLeader` chaos.
    pub durable: bool,
    /// `false` paces phases against the wall clock; `true` skips pacing
    /// sleeps entirely (virtual clock — CI-friendly).
    pub virtual_clock: bool,
    /// Use the noiseless platform model (deterministic objective curves).
    pub noiseless: bool,
    pub tenants: Vec<TenantSpec>,
    pub mix: Vec<OpMix>,
    pub job: JobShape,
    pub phases: Vec<PhaseSpec>,
    pub chaos: Vec<ChaosSpec>,
}

impl Workload {
    /// Total ops across all phases.
    pub fn total_ops(&self) -> u32 {
        self.phases.iter().map(|p| p.ops).sum()
    }

    /// Canonical name of the `seq`-th created job.
    pub fn job_name(&self, seq: usize) -> String {
        format!("{}-{seq:05}", self.name)
    }

    /// The canned mixed workload used by `load_smoke`, `benches/load.rs`,
    /// `amt load --canned` and `scale_soak --chaos`: three tenants, every
    /// create flavor plus polling traffic, a steady→ramp→burst schedule and
    /// a kill / late-join / drain chaos track on a 3-worker loopback fleet.
    /// `scale` multiplies the per-phase op counts.
    pub fn canned_mixed(name: &str, seed: u64, scale: u32) -> Workload {
        let s = scale.max(1);
        Workload {
            name: name.to_string(),
            seed,
            plane: Plane::Distributed,
            workers: 3,
            durable: false,
            virtual_clock: true,
            noiseless: true,
            tenants: vec![
                TenantSpec { name: "acme".into(), weight: 3, max_in_flight: 4 },
                TenantSpec { name: "zephyr".into(), weight: 2, max_in_flight: 2 },
                TenantSpec { name: "solo".into(), weight: 1, max_in_flight: 0 },
            ],
            mix: vec![
                OpMix { op: OpKind::CreateBo, weight: 2 },
                OpMix { op: OpKind::CreateRandom, weight: 6 },
                OpMix { op: OpKind::CreateGrid, weight: 3 },
                OpMix { op: OpKind::CreateWarmStart, weight: 2 },
                OpMix { op: OpKind::CreateEarlyStopping, weight: 2 },
                OpMix { op: OpKind::CreateMultiObjective, weight: 2 },
                OpMix { op: OpKind::Describe, weight: 5 },
                OpMix { op: OpKind::List, weight: 2 },
                OpMix { op: OpKind::Stop, weight: 1 },
                OpMix { op: OpKind::Wait, weight: 2 },
            ],
            job: JobShape::default(),
            phases: vec![
                PhaseSpec { kind: PhaseKind::Steady, ops: 30 * s, rate: 150.0, rate_end: 150.0 },
                PhaseSpec { kind: PhaseKind::Ramp, ops: 30 * s, rate: 75.0, rate_end: 300.0 },
                PhaseSpec { kind: PhaseKind::Burst, ops: 20 * s, rate: 0.0, rate_end: 0.0 },
            ],
            chaos: vec![
                ChaosSpec { at_op: 20 * s, action: ChaosAction::KillWorker(0) },
                ChaosSpec { at_op: 40 * s, action: ChaosAction::JoinWorker },
                ChaosSpec { at_op: 60 * s, action: ChaosAction::DrainWorker(1) },
            ],
        }
    }

    /// A small durable local-plane workload whose chaos track closes and
    /// reopens the leader mid-run, exercising the recovery path under load.
    pub fn canned_reopen(name: &str, seed: u64) -> Workload {
        Workload {
            name: name.to_string(),
            seed,
            plane: Plane::Local,
            workers: 0,
            durable: true,
            virtual_clock: true,
            noiseless: true,
            tenants: vec![TenantSpec { name: "acme".into(), weight: 1, max_in_flight: 0 }],
            mix: vec![
                OpMix { op: OpKind::CreateRandom, weight: 5 },
                OpMix { op: OpKind::CreateBo, weight: 1 },
                OpMix { op: OpKind::Describe, weight: 3 },
                OpMix { op: OpKind::Wait, weight: 2 },
            ],
            job: JobShape::default(),
            phases: vec![PhaseSpec { kind: PhaseKind::Burst, ops: 24, rate: 0.0, rate_end: 0.0 }],
            chaos: vec![ChaosSpec { at_op: 12, action: ChaosAction::ReopenLeader }],
        }
    }

    /// Structural validation; returns the first problem found.
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() || self.name.len() > 40 {
            return Err("workload name must be 1..=40 chars".into());
        }
        if self.name.contains("-train-") {
            return Err("workload name must not contain \"-train-\"".into());
        }
        if self.tenants.is_empty() {
            return Err("workload needs at least one tenant".into());
        }
        for t in &self.tenants {
            if t.name.len() > 64 {
                return Err(format!("tenant name too long: {}", t.name));
            }
            if t.weight == 0 || t.weight > 100 {
                return Err(format!("tenant {} weight must be 1..=100", t.name));
            }
            if t.max_in_flight > 1000 {
                return Err(format!("tenant {} max_in_flight must be <= 1000", t.name));
            }
        }
        if self.mix.is_empty() {
            return Err("workload needs a non-empty op mix".into());
        }
        if !self.mix.iter().any(|m| m.op.is_create() && m.weight > 0) {
            return Err("op mix needs at least one create kind with weight > 0".into());
        }
        if self.mix.iter().map(|m| m.weight as u64).sum::<u64>() == 0 {
            return Err("op mix weights sum to zero".into());
        }
        if self.phases.is_empty() {
            return Err("workload needs at least one phase".into());
        }
        for (i, p) in self.phases.iter().enumerate() {
            if p.ops == 0 {
                return Err(format!("phase {i} has zero ops"));
            }
            if !p.rate.is_finite() || p.rate < 0.0 || !p.rate_end.is_finite() || p.rate_end < 0.0 {
                return Err(format!("phase {i} rates must be finite and >= 0"));
            }
        }
        if self.job.max_training_jobs == 0 || self.job.max_training_jobs > 10_000 {
            return Err("job.max_training_jobs must be 1..=10000".into());
        }
        if self.job.max_parallel_jobs == 0 || self.job.max_parallel_jobs > 100 {
            return Err("job.max_parallel_jobs must be 1..=100".into());
        }
        let total = self.total_ops();
        for (i, c) in self.chaos.iter().enumerate() {
            if c.at_op >= total {
                return Err(format!("chaos[{i}] at_op {} beyond total ops {total}", c.at_op));
            }
            match c.action {
                ChaosAction::KillWorker(w) | ChaosAction::DrainWorker(w) => {
                    if self.plane != Plane::Distributed {
                        return Err(format!("chaos[{i}] needs the distributed plane"));
                    }
                    if w >= self.workers {
                        return Err(format!(
                            "chaos[{i}] worker index {w} out of range (workers = {})",
                            self.workers
                        ));
                    }
                }
                ChaosAction::JoinWorker => {
                    if self.plane != Plane::Distributed {
                        return Err(format!("chaos[{i}] needs the distributed plane"));
                    }
                }
                ChaosAction::ReopenLeader => {
                    if !self.durable {
                        return Err(format!("chaos[{i}] reopen_leader requires durable: true"));
                    }
                }
            }
        }
        if self.plane == Plane::Distributed && self.workers == 0 {
            return Err("distributed plane needs workers >= 1".into());
        }
        Ok(())
    }

    // -- JSON codec ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("seed", json::u64_to_json(self.seed)),
            ("plane", Json::Str(self.plane.as_str().to_string())),
            ("workers", Json::Num(self.workers as f64)),
            ("durable", Json::Bool(self.durable)),
            ("clock", Json::Str(
                if self.virtual_clock { "virtual" } else { "wall" }.to_string(),
            )),
            ("platform", Json::Str(
                if self.noiseless { "noiseless" } else { "default" }.to_string(),
            )),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::Str(t.name.clone())),
                                ("weight", Json::Num(t.weight as f64)),
                                ("max_in_flight", Json::Num(t.max_in_flight as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "mix",
                Json::Arr(
                    self.mix
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("op", Json::Str(m.op.as_str().to_string())),
                                ("weight", Json::Num(m.weight as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "job",
                Json::obj(vec![
                    ("objective", Json::Str(self.job.objective.clone())),
                    ("max_training_jobs", Json::Num(self.job.max_training_jobs as f64)),
                    ("max_parallel_jobs", Json::Num(self.job.max_parallel_jobs as f64)),
                    ("max_retries_per_job", Json::Num(self.job.max_retries_per_job as f64)),
                ]),
            ),
            (
                "phases",
                Json::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("kind", Json::Str(p.kind.as_str().to_string())),
                                ("ops", Json::Num(p.ops as f64)),
                                ("rate", Json::Num(p.rate)),
                                ("rate_end", Json::Num(p.rate_end)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "chaos",
                Json::Arr(
                    self.chaos
                        .iter()
                        .map(|c| {
                            let (action, worker) = match c.action {
                                ChaosAction::KillWorker(w) => ("kill_worker", Some(w)),
                                ChaosAction::JoinWorker => ("join_worker", None),
                                ChaosAction::DrainWorker(w) => ("drain_worker", Some(w)),
                                ChaosAction::ReopenLeader => ("reopen_leader", None),
                            };
                            let mut pairs = vec![
                                ("at_op", Json::Num(c.at_op as f64)),
                                ("action", Json::Str(action.to_string())),
                            ];
                            if let Some(w) = worker {
                                pairs.push(("worker", Json::Num(w as f64)));
                            }
                            Json::obj(pairs)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Workload, String> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("workload: missing \"name\"")?
            .to_string();
        // Seed: accept both the crate's lossless hex form and a plain number.
        let seed = match j.get("seed") {
            None => 0,
            Some(v) => json::u64_from_json(v)
                .or_else(|| v.as_i64().map(|n| n as u64))
                .ok_or("workload: bad \"seed\"")?,
        };
        let plane = match j.get("plane").and_then(Json::as_str) {
            None => Plane::Distributed,
            Some(s) => Plane::parse(s).ok_or_else(|| format!("workload: unknown plane {s:?}"))?,
        };
        let workers = j.get("workers").and_then(Json::as_i64).unwrap_or(3).max(0) as usize;
        let durable = j.get("durable").and_then(Json::as_bool).unwrap_or(false);
        let virtual_clock = match j.get("clock").and_then(Json::as_str) {
            None => false,
            Some("virtual") => true,
            Some("wall") => false,
            Some(s) => return Err(format!("workload: unknown clock {s:?}")),
        };
        let noiseless = match j.get("platform").and_then(Json::as_str) {
            None | Some("noiseless") => true,
            Some("default") => false,
            Some(s) => return Err(format!("workload: unknown platform {s:?}")),
        };
        let mut tenants = Vec::new();
        if let Some(arr) = j.get("tenants").and_then(Json::as_arr) {
            for t in arr {
                tenants.push(TenantSpec {
                    name: t
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or("tenant: missing \"name\"")?
                        .to_string(),
                    weight: t.get("weight").and_then(Json::as_i64).unwrap_or(1) as u32,
                    max_in_flight: t.get("max_in_flight").and_then(Json::as_i64).unwrap_or(0)
                        as u32,
                });
            }
        }
        if tenants.is_empty() {
            tenants.push(TenantSpec { name: String::new(), weight: 1, max_in_flight: 0 });
        }
        let mut mix = Vec::new();
        if let Some(arr) = j.get("mix").and_then(Json::as_arr) {
            for m in arr {
                let op_str = m.get("op").and_then(Json::as_str).ok_or("mix: missing \"op\"")?;
                let op = OpKind::parse(op_str)
                    .ok_or_else(|| format!("mix: unknown op {op_str:?}"))?;
                mix.push(OpMix {
                    op,
                    weight: m.get("weight").and_then(Json::as_i64).unwrap_or(1) as u32,
                });
            }
        }
        let job = match j.get("job") {
            None => JobShape::default(),
            Some(g) => {
                let d = JobShape::default();
                JobShape {
                    objective: g
                        .get("objective")
                        .and_then(Json::as_str)
                        .unwrap_or(&d.objective)
                        .to_string(),
                    max_training_jobs: g
                        .get("max_training_jobs")
                        .and_then(Json::as_i64)
                        .unwrap_or(d.max_training_jobs as i64) as u32,
                    max_parallel_jobs: g
                        .get("max_parallel_jobs")
                        .and_then(Json::as_i64)
                        .unwrap_or(d.max_parallel_jobs as i64) as u32,
                    max_retries_per_job: g
                        .get("max_retries_per_job")
                        .and_then(Json::as_i64)
                        .unwrap_or(d.max_retries_per_job as i64) as u32,
                }
            }
        };
        let mut phases = Vec::new();
        if let Some(arr) = j.get("phases").and_then(Json::as_arr) {
            for p in arr {
                let kind_str =
                    p.get("kind").and_then(Json::as_str).ok_or("phase: missing \"kind\"")?;
                let kind = PhaseKind::parse(kind_str)
                    .ok_or_else(|| format!("phase: unknown kind {kind_str:?}"))?;
                let rate = p.get("rate").and_then(Json::as_f64).unwrap_or(0.0);
                phases.push(PhaseSpec {
                    kind,
                    ops: p.get("ops").and_then(Json::as_i64).unwrap_or(0) as u32,
                    rate,
                    rate_end: p.get("rate_end").and_then(Json::as_f64).unwrap_or(rate),
                });
            }
        }
        let mut chaos = Vec::new();
        if let Some(arr) = j.get("chaos").and_then(Json::as_arr) {
            for c in arr {
                let at_op = c.get("at_op").and_then(Json::as_i64).unwrap_or(0) as u32;
                let action_str =
                    c.get("action").and_then(Json::as_str).ok_or("chaos: missing \"action\"")?;
                let worker = c.get("worker").and_then(Json::as_i64).unwrap_or(0) as usize;
                let action = match action_str {
                    "kill_worker" => ChaosAction::KillWorker(worker),
                    "join_worker" => ChaosAction::JoinWorker,
                    "drain_worker" => ChaosAction::DrainWorker(worker),
                    "reopen_leader" => ChaosAction::ReopenLeader,
                    other => return Err(format!("chaos: unknown action {other:?}")),
                };
                chaos.push(ChaosSpec { at_op, action });
            }
        }
        Ok(Workload {
            name,
            seed,
            plane,
            workers,
            durable,
            virtual_clock,
            noiseless,
            tenants,
            mix,
            job,
            phases,
            chaos,
        })
    }

    pub fn from_json_str(text: &str) -> Result<Workload, String> {
        let j = json::parse(text).map_err(|e| format!("workload JSON parse error: {e:?}"))?;
        Workload::from_json(&j)
    }

    // -- Planner ------------------------------------------------------------

    /// Expand the spec into the concrete deterministic op sequence. A single
    /// `Rng::new(seed)` drives every draw (op kind, tenant, per-job seed,
    /// scalarization weight, poll target), so two plans from the same spec
    /// are bit-identical and chaos soaks are replayable.
    pub fn plan(&self) -> Plan {
        let mut rng = Rng::new(self.seed);
        let mut ops: Vec<PlannedOp> = Vec::new();
        let mut creates: Vec<OpKind> = Vec::new();
        // Seqs eligible as warm-start parents: registry objectives only
        // (custom multi-objective jobs cannot be resolved as parents).
        let mut warm_eligible: Vec<usize> = Vec::new();
        let mut fired = vec![false; self.chaos.len()];
        let mix_total: usize = self.mix.iter().map(|m| m.weight as usize).sum();
        let tenant_total: usize = self.tenants.iter().map(|t| t.weight as usize).sum();
        let mut global: u32 = 0;

        for (phase_idx, phase) in self.phases.iter().enumerate() {
            for _ in 0..phase.ops {
                for (ci, c) in self.chaos.iter().enumerate() {
                    if !fired[ci] && c.at_op <= global {
                        fired[ci] = true;
                        ops.push(PlannedOp::Chaos { index: ci });
                    }
                }
                let mut kind = self.draw_mix(&mut rng, mix_total);
                // Deterministic plan-time degradations: polls with nothing
                // to poll become lists; warm starts with no eligible parent
                // become plain random creates.
                if matches!(kind, OpKind::Describe | OpKind::Stop | OpKind::Wait)
                    && creates.is_empty()
                {
                    kind = OpKind::List;
                }
                if kind == OpKind::CreateWarmStart && warm_eligible.is_empty() {
                    kind = OpKind::CreateRandom;
                }
                if kind.is_create() {
                    let tenant = self.draw_tenant(&mut rng, tenant_total);
                    let seq = creates.len();
                    // Keep generated seeds < 2^48 so the Num(f64) codec in
                    // TuningJobRequest round-trips them exactly.
                    let job_seed = rng.next_u64() >> 16;
                    let mut theta = None;
                    let mut parents = Vec::new();
                    let (strategy, early, objective) = match kind {
                        OpKind::CreateBo => ("bayesian", "off", self.job.objective.clone()),
                        OpKind::CreateRandom => ("random", "off", self.job.objective.clone()),
                        OpKind::CreateGrid => ("grid", "off", self.job.objective.clone()),
                        OpKind::CreateWarmStart => {
                            let p = warm_eligible[rng.below(warm_eligible.len())];
                            parents.push(self.job_name(p));
                            ("bayesian", "off", self.job.objective.clone())
                        }
                        OpKind::CreateEarlyStopping => {
                            ("random", "median", self.job.objective.clone())
                        }
                        OpKind::CreateMultiObjective => {
                            theta = Some(0.1 + 0.8 * rng.uniform());
                            ("random", "off", "scalarized-bi".to_string())
                        }
                        _ => unreachable!(),
                    };
                    let t = &self.tenants[tenant];
                    let request = TuningJobRequest {
                        name: self.job_name(seq),
                        objective,
                        strategy: strategy.to_string(),
                        max_training_jobs: self.job.max_training_jobs,
                        max_parallel_jobs: self.job.max_parallel_jobs,
                        early_stopping: early.to_string(),
                        seed: job_seed,
                        warm_start_parents: parents,
                        max_retries_per_job: self.job.max_retries_per_job,
                        tenant_weight: t.weight,
                        tenant: t.name.clone(),
                        max_in_flight: t.max_in_flight,
                        ..TuningJobRequest::default()
                    };
                    if kind != OpKind::CreateMultiObjective {
                        warm_eligible.push(seq);
                    }
                    creates.push(kind);
                    ops.push(PlannedOp::Create(CreateOp { seq, kind, tenant, theta, request }));
                } else {
                    let op = match kind {
                        OpKind::Describe => {
                            PlannedOp::Describe { target: rng.below(creates.len()) }
                        }
                        OpKind::List => PlannedOp::List,
                        OpKind::Stop => PlannedOp::Stop { target: rng.below(creates.len()) },
                        OpKind::Wait => PlannedOp::Wait { target: rng.below(creates.len()) },
                        _ => unreachable!(),
                    };
                    ops.push(op);
                }
                global += 1;
            }
            ops.push(PlannedOp::PhaseEnd { phase: phase_idx });
        }
        // Any chaos entry validated as in-range has fired by now; fire
        // stragglers defensively anyway so counts always reconcile.
        for (ci, _) in self.chaos.iter().enumerate() {
            if !fired[ci] {
                ops.push(PlannedOp::Chaos { index: ci });
            }
        }
        Plan { ops, creates: creates.len() }
    }

    fn draw_mix(&self, rng: &mut Rng, total: usize) -> OpKind {
        let mut roll = rng.below(total);
        for m in &self.mix {
            if roll < m.weight as usize {
                return m.op;
            }
            roll -= m.weight as usize;
        }
        self.mix.last().expect("mix validated non-empty").op
    }

    fn draw_tenant(&self, rng: &mut Rng, total: usize) -> usize {
        let mut roll = rng.below(total);
        for (i, t) in self.tenants.iter().enumerate() {
            if roll < t.weight as usize {
                return i;
            }
            roll -= t.weight as usize;
        }
        self.tenants.len() - 1
    }
}

/// One fully-resolved create operation.
#[derive(Clone, Debug, PartialEq)]
pub struct CreateOp {
    /// Creation sequence number (names are `{workload}-{seq:05}`).
    pub seq: usize,
    pub kind: OpKind,
    /// Index into `Workload::tenants`.
    pub tenant: usize,
    /// Scalarization weight for multi-objective creates.
    pub theta: Option<f64>,
    /// The complete request submitted to the service.
    pub request: TuningJobRequest,
}

/// One planned operation. Poll targets are creation sequence numbers
/// resolved at plan time, so the whole sequence is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum PlannedOp {
    Create(CreateOp),
    Describe { target: usize },
    List,
    Stop { target: usize },
    Wait { target: usize },
    /// Fire `Workload::chaos[index]`.
    Chaos { index: usize },
    /// End of `Workload::phases[phase]`: run mid-run observers.
    PhaseEnd { phase: usize },
}

/// The expanded deterministic op sequence.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Plan {
    pub ops: Vec<PlannedOp>,
    /// Number of create operations in `ops`.
    pub creates: usize,
}

impl Plan {
    /// Creation-sequence numbers targeted by a planned `Stop`.
    pub fn stop_targets(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .ops
            .iter()
            .filter_map(|op| match op {
                PlannedOp::Stop { target } => Some(*target),
                _ => None,
            })
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// All planned creates, in sequence order.
    pub fn creates(&self) -> Vec<&CreateOp> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                PlannedOp::Create(c) => Some(c),
                _ => None,
            })
            .collect()
    }

    /// Number of chaos firing points in the plan.
    pub fn chaos_count(&self) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, PlannedOp::Chaos { .. }))
            .count()
    }
}

// ---------------------------------------------------------------------------
// Multi-objective scalarization
// ---------------------------------------------------------------------------

/// Bi-objective workload scalarized with an augmented-Chebyshev combination
/// (the ParEGO construction): objective one is the Branin value, objective
/// two a deterministic "resource cost" proxy derived from the config's
/// numeric magnitude. Submitted through `create_custom_tuning_job`, which
/// always runs on the local scheduler even when a remote plane is attached.
pub struct ScalarizedBiObjective {
    base: Analytic,
    theta: f64,
}

impl ScalarizedBiObjective {
    pub fn new(theta: f64) -> Self {
        ScalarizedBiObjective { base: Analytic::branin(), theta: theta.clamp(0.01, 0.99) }
    }

    fn scalarize(&self, quality: f64, cost: f64) -> f64 {
        let a = self.theta * quality;
        let b = (1.0 - self.theta) * cost;
        a.max(b) + 0.05 * (a + b)
    }
}

impl Objective for ScalarizedBiObjective {
    fn name(&self) -> &str {
        "scalarized-bi"
    }

    fn space(&self) -> SearchSpace {
        self.base.space()
    }

    fn max_epochs(&self) -> u32 {
        self.base.max_epochs()
    }

    fn curve(&self, config: &Config, seed: u64) -> Vec<f64> {
        // Cost proxy in [0, 1): RMS magnitude of the numeric hyperparameters,
        // squashed. Deterministic in the config alone.
        let mut sq = 0.0;
        let mut n = 0u32;
        for v in config.values() {
            if let Some(x) = v.as_f64() {
                sq += x * x;
                n += 1;
            }
        }
        let rms = if n > 0 { (sq / n as f64).sqrt() } else { 0.0 };
        let cost = rms / (1.0 + rms);
        self.base
            .curve(config, seed)
            .into_iter()
            .map(|f1| {
                let quality = f1 / (1.0 + f1.abs());
                self.scalarize(quality, cost)
            })
            .collect()
    }

    fn epoch_seconds(&self, config: &Config) -> f64 {
        self.base.epoch_seconds(config)
    }
}
