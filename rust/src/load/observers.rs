//! Invariant observers for the load & chaos observatory.
//!
//! Observers are cheap assertions evaluated between phases and at the end of
//! a run: they consume only public service surfaces (the metadata store, the
//! pool/recovery counters, job outcomes) and report pass/fail with a
//! human-readable detail line, so a chaos soak fails loudly instead of
//! silently converging to a wrong state.

use std::collections::BTreeMap;

use crate::json::Json;
use crate::store::MetadataStore;

/// One evaluated invariant.
#[derive(Clone, Debug)]
pub struct ObserverCheck {
    pub name: &'static str,
    pub passed: bool,
    pub detail: String,
}

/// All invariants evaluated over a run.
#[derive(Clone, Debug, Default)]
pub struct ObserverReport {
    pub checks: Vec<ObserverCheck>,
}

impl ObserverReport {
    pub fn push(&mut self, name: &'static str, passed: bool, detail: String) {
        self.checks.push(ObserverCheck { name, passed, detail });
    }

    pub fn all_passed(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }

    pub fn failed(&self) -> Vec<&ObserverCheck> {
        self.checks.iter().filter(|c| !c.passed).collect()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in &self.checks {
            let mark = if c.passed { "PASS" } else { "FAIL" };
            out.push_str(&format!("  {mark}  {:<26} {}\n", c.name, c.detail));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.checks
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        ("name", Json::Str(c.name.to_string())),
                        ("passed", Json::Bool(c.passed)),
                        ("detail", Json::Str(c.detail.clone())),
                    ])
                })
                .collect(),
        )
    }
}

/// Watches per-key store versions across observations and records any
/// decrease — store versions must be monotone even across a leader
/// close+reopen (they are rebuilt from the WAL/snapshot, never reset).
#[derive(Default)]
pub struct VersionWatch {
    last: BTreeMap<String, u64>,
    pub violations: Vec<String>,
    pub observations: u64,
}

impl VersionWatch {
    pub fn observe(&mut self, store: &MetadataStore, table: &str, prefix: &str) {
        self.observations += 1;
        for key in store.list_keys(table, prefix) {
            if let Some((version, _)) = store.get(table, &key) {
                if let Some(prev) = self.last.get(&key) {
                    if version < *prev {
                        self.violations.push(format!(
                            "{table}/{key}: version regressed {prev} -> {version}"
                        ));
                    }
                }
                self.last.insert(key, version);
            }
        }
    }
}
