//! Always-on load & chaos observatory (DESIGN.md §16).
//!
//! Declarative mixed workloads against the full service surface, with
//! built-in chaos injection and invariant observers:
//!
//! - [`Workload`] — a JSON-codable spec: weighted multi-tenant create
//!   traffic (BO / random / grid / warm-start / early-stopping /
//!   multi-objective), polling ops (describe / list / stop / wait), a
//!   steady / ramp / burst throughput schedule, and a chaos track (worker
//!   kills, late joins, graceful drains, leader close+reopen). A seeded
//!   RNG expands the spec into a deterministic [`Plan`], so every soak is
//!   replayable bit-for-bit.
//! - [`Runner`] — drives the plan against [`crate::api::AmtService`] on
//!   either execution plane, records per-op SLO histograms
//!   (`load.create_us`, `load.describe_us`, `load.list_us`,
//!   `load.stop_us`, `load.wait_us`) and fires the chaos track through
//!   the elastic-fleet and durability surfaces.
//! - [`ObserverReport`] — invariant observers evaluated between phases
//!   and at the end: zero lost/duplicated jobs, terminal status for every
//!   job, store-version monotonicity, conservation of the fleet's
//!   join/drain/steal/WAL counters, replay attribution (zero replayed
//!   proposals on snapshot-path legs), and bit-identity of probe jobs
//!   against an uninterrupted reference run.
//!
//! Surfaces: `amt load <workload.json>` (CLI), the `Runner` API (tests:
//! `rust/tests/load_harness.rs`), and `benches/load.rs` → BENCH_load.json.

pub mod observers;
pub mod runner;
pub mod workload;

pub use observers::{ObserverCheck, ObserverReport, VersionWatch};
pub use runner::{PhaseReport, PoolTotals, RecoveryTotals, RunReport, Runner};
pub use workload::{
    ChaosAction, ChaosSpec, CreateOp, JobShape, OpKind, OpMix, PhaseKind, PhaseSpec, Plan,
    Plane, PlannedOp, ScalarizedBiObjective, TenantSpec, Workload,
};
