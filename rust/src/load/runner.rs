//! Executes a [`Workload`] plan against a live [`AmtService`].
//!
//! The runner owns the whole lifecycle: service construction on either
//! plane (local scheduler or loopback distributed fleet, optionally
//! durable), paced execution of the planned op stream, chaos injection
//! through the elastic-fleet / recovery surfaces, per-op SLO histograms
//! (`load.create_us`, `load.describe_us`, …) in its own telemetry
//! [`Registry`], and the invariant observers evaluated between phases and
//! at the end. `run()` returns a [`RunReport`] merging the service's
//! telemetry snapshot with the runner's own, plus every observer verdict.

use std::collections::{BTreeSet, HashMap};
use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{AmtService, ApiError};
use crate::distributed::transport::{LoopbackFault, Transport};
use crate::distributed::worker::spawn_loopback_worker;
use crate::distributed::leader::RemoteConfig;
use crate::durability::DurabilityOptions;
use crate::gp::NativeBackend;
use crate::json::Json;
use crate::platform::PlatformConfig;
use crate::scheduler::SchedulerConfig;
use crate::telemetry::{Histogram, Registry, TelemetrySnapshot};

use super::observers::{ObserverReport, VersionWatch};
use super::workload::{
    ChaosAction, CreateOp, OpKind, Plan, PlannedOp, PhaseKind, PhaseSpec, Plane,
    ScalarizedBiObjective, Workload,
};
use crate::coordinator::TuningJobOutcome;

/// Per-phase throughput accounting.
#[derive(Clone, Debug)]
pub struct PhaseReport {
    pub kind: PhaseKind,
    pub ops: u32,
    /// Mean target rate over the phase (0 = unpaced).
    pub target_rate: f64,
    pub achieved_rate: f64,
    pub wall_s: f64,
}

/// Conserved elastic-fleet counters, accumulated across every pool epoch
/// (a leader reopen starts a new pool; totals absorb the old one first).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolTotals {
    pub joins: u64,
    pub drains: u64,
    pub steals: u64,
    pub snapshot_requeues: u64,
    pub scratch_requeues: u64,
    pub replayed_proposals: u64,
    pub wal_commit_errors: u64,
}

/// Recovery-on-open totals accumulated across leader reopens.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryTotals {
    pub fast_resumed: usize,
    pub scratch_resumed: usize,
    pub replayed_proposals: u64,
}

/// Everything a finished run reports.
pub struct RunReport {
    pub workload_name: String,
    pub wall_s: f64,
    pub ops_executed: u64,
    pub ops_failed: u64,
    pub jobs_created: u64,
    pub evaluations: u64,
    pub chaos_fired: u64,
    /// Warm-start creates degraded to plain creates at runtime (parent
    /// finished without a completed observation, e.g. stopped early).
    pub degraded_creates: u64,
    pub phases: Vec<PhaseReport>,
    pub observers: ObserverReport,
    pub pool: PoolTotals,
    pub recovery: RecoveryTotals,
    /// Service metrics merged with the runner's `load.*` histograms.
    pub snapshot: TelemetrySnapshot,
}

impl RunReport {
    /// True iff every invariant observer passed.
    pub fn all_passed(&self) -> bool {
        self.observers.all_passed()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", Json::Str(self.workload_name.clone())),
            ("wall_s", Json::Num(self.wall_s)),
            ("ops_executed", Json::Num(self.ops_executed as f64)),
            ("ops_failed", Json::Num(self.ops_failed as f64)),
            ("jobs_created", Json::Num(self.jobs_created as f64)),
            ("evaluations", Json::Num(self.evaluations as f64)),
            ("chaos_fired", Json::Num(self.chaos_fired as f64)),
            ("degraded_creates", Json::Num(self.degraded_creates as f64)),
            (
                "phases",
                Json::Arr(
                    self.phases
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("kind", Json::Str(p.kind.as_str().to_string())),
                                ("ops", Json::Num(p.ops as f64)),
                                ("target_rate", Json::Num(p.target_rate)),
                                ("achieved_rate", Json::Num(p.achieved_rate)),
                                ("wall_s", Json::Num(p.wall_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "pool",
                Json::obj(vec![
                    ("joins", Json::Num(self.pool.joins as f64)),
                    ("drains", Json::Num(self.pool.drains as f64)),
                    ("steals", Json::Num(self.pool.steals as f64)),
                    ("snapshot_requeues", Json::Num(self.pool.snapshot_requeues as f64)),
                    ("scratch_requeues", Json::Num(self.pool.scratch_requeues as f64)),
                    ("replayed_proposals", Json::Num(self.pool.replayed_proposals as f64)),
                    ("wal_commit_errors", Json::Num(self.pool.wal_commit_errors as f64)),
                ]),
            ),
            ("observers", self.observers.to_json()),
            ("all_passed", Json::Bool(self.all_passed())),
            ("telemetry", self.snapshot.to_json()),
        ])
    }

    /// Human-readable multi-line summary (the non-`--json` CLI output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let rate = if self.wall_s > 0.0 { self.ops_executed as f64 / self.wall_s } else { 0.0 };
        out.push_str(&format!(
            "workload {}: {} ops in {:.2}s ({:.0} ops/s), {} jobs, {} evaluations, \
             {} chaos events, {} op errors\n",
            self.workload_name,
            self.ops_executed,
            self.wall_s,
            rate,
            self.jobs_created,
            self.evaluations,
            self.chaos_fired,
            self.ops_failed,
        ));
        for p in &self.phases {
            let target = if p.target_rate > 0.0 {
                format!("target {:.0}/s", p.target_rate)
            } else {
                "unpaced".to_string()
            };
            out.push_str(&format!(
                "  phase {:<7} {:>5} ops  {}  achieved {:.0}/s in {:.2}s\n",
                p.kind.as_str(),
                p.ops,
                target,
                p.achieved_rate,
                p.wall_s,
            ));
        }
        out.push_str(&format!(
            "  fleet: joins={} drains={} steals={} snapshot_requeues={} \
             scratch_requeues={} replayed={} wal_errors={}\n",
            self.pool.joins,
            self.pool.drains,
            self.pool.steals,
            self.pool.snapshot_requeues,
            self.pool.scratch_requeues,
            self.pool.replayed_proposals,
            self.pool.wal_commit_errors,
        ));
        for name in ["create", "describe", "list", "stop", "wait"] {
            if let Some(h) = self.snapshot.histogram(&format!("load.{name}_us")) {
                if h.count > 0 {
                    out.push_str(&format!(
                        "  load.{:<12} n={:<6} p50={}us p99={}us p999={}us max={}us\n",
                        format!("{name}_us"),
                        h.count,
                        h.p50,
                        h.p99,
                        h.p999,
                        h.max,
                    ));
                }
            }
        }
        out.push_str("observers:\n");
        out.push_str(&self.observers.render());
        out
    }
}

struct Fleet {
    tag: String,
    spawned: usize,
    faults: Vec<Arc<LoopbackFault>>,
    handles: Vec<JoinHandle<()>>,
}

impl Fleet {
    fn new(tag: &str) -> Fleet {
        Fleet { tag: tag.to_string(), spawned: 0, faults: Vec::new(), handles: Vec::new() }
    }

    fn spawn_one(&mut self) -> Box<dyn Transport> {
        let label = format!("{}-w{}", self.tag, self.spawned);
        self.spawned += 1;
        let (transport, fault, handle) = spawn_loopback_worker(&label);
        self.faults.push(fault);
        self.handles.push(handle);
        transport
    }

    /// Join every worker thread of the current epoch. Must only be called
    /// after the leader-side transports dropped (pool closed), which is
    /// what makes loopback workers exit.
    fn join_all(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.faults.clear();
    }
}

struct LedgerEntry {
    name: String,
    created: bool,
    waited: bool,
}

/// Drives one [`Workload`] to completion. Cheap to construct (planning
/// only); `run()` owns the service lifecycle.
pub struct Runner {
    workload: Workload,
    plan: Plan,
    report_every: Option<Duration>,
}

impl Runner {
    pub fn new(workload: Workload) -> Result<Runner, String> {
        workload.validate()?;
        let plan = workload.plan();
        Ok(Runner { workload, plan, report_every: None })
    }

    pub fn from_json_str(text: &str) -> Result<Runner, String> {
        Runner::new(Workload::from_json_str(text)?)
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    /// The expanded deterministic op sequence (what the determinism
    /// property test compares).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Emit a one-line live stats report (stderr) at most this often.
    pub fn set_report_every(&mut self, every: Option<Duration>) {
        self.report_every = every;
    }

    /// Execute the workload and evaluate every invariant observer.
    pub fn run(&self) -> Result<RunReport, String> {
        Exec::new(self)?.run()
    }
}

/// Mutable state of one run.
struct Exec<'a> {
    wl: &'a Workload,
    plan: &'a Plan,
    report_every: Option<Duration>,
    registry: Registry,
    h_create: Arc<Histogram>,
    h_describe: Arc<Histogram>,
    h_list: Arc<Histogram>,
    h_stop: Arc<Histogram>,
    h_wait: Arc<Histogram>,
    service: Option<AmtService>,
    fleet: Fleet,
    data_dir: Option<PathBuf>,
    ledger: Vec<LedgerEntry>,
    name_to_seq: HashMap<String, usize>,
    probe_seqs: BTreeSet<usize>,
    outcomes: HashMap<usize, TuningJobOutcome>,
    watch: VersionWatch,
    pool: PoolTotals,
    recovery: RecoveryTotals,
    // Conservation expectations for the current pool epoch.
    epoch_initial_workers: u64,
    epoch_joins_fired: u64,
    epoch_drains_fired: u64,
    expected_joins: u64,
    expected_drains: u64,
    ops_executed: u64,
    ops_failed: u64,
    evaluations: u64,
    chaos_fired: u64,
    degraded_creates: u64,
}

impl<'a> Exec<'a> {
    fn new(runner: &'a Runner) -> Result<Exec<'a>, String> {
        let registry = Registry::default();
        let h_create = registry.histogram("load.create_us");
        let h_describe = registry.histogram("load.describe_us");
        let h_list = registry.histogram("load.list_us");
        let h_stop = registry.histogram("load.stop_us");
        let h_wait = registry.histogram("load.wait_us");
        // Probes for the bit-identity observer: registry-objective creates
        // with no warm-start parent and no planned stop, so their outcome
        // is a pure function of (request, platform) on any plane.
        let stops: BTreeSet<usize> = runner.plan.stop_targets().into_iter().collect();
        let probe_seqs: BTreeSet<usize> = runner
            .plan
            .creates()
            .into_iter()
            .filter(|c| {
                matches!(
                    c.kind,
                    OpKind::CreateBo
                        | OpKind::CreateRandom
                        | OpKind::CreateGrid
                        | OpKind::CreateEarlyStopping
                )
            })
            .filter(|c| c.request.warm_start_parents.is_empty())
            .filter(|c| !stops.contains(&c.seq))
            .take(3)
            .map(|c| c.seq)
            .collect();
        let data_dir = if runner.workload.durable {
            Some(std::env::temp_dir().join(format!(
                "amt-load-{}-{}",
                std::process::id(),
                runner.workload.name
            )))
        } else {
            None
        };
        Ok(Exec {
            wl: &runner.workload,
            plan: &runner.plan,
            report_every: runner.report_every,
            registry,
            h_create,
            h_describe,
            h_list,
            h_stop,
            h_wait,
            service: None,
            fleet: Fleet::new(&runner.workload.name),
            data_dir,
            ledger: Vec::new(),
            name_to_seq: HashMap::new(),
            probe_seqs,
            outcomes: HashMap::new(),
            watch: VersionWatch::default(),
            pool: PoolTotals::default(),
            recovery: RecoveryTotals::default(),
            epoch_initial_workers: 0,
            epoch_joins_fired: 0,
            epoch_drains_fired: 0,
            expected_joins: 0,
            expected_drains: 0,
            ops_executed: 0,
            ops_failed: 0,
            evaluations: 0,
            chaos_fired: 0,
            degraded_creates: 0,
        })
    }

    fn platform(&self) -> PlatformConfig {
        if self.wl.noiseless {
            PlatformConfig::noiseless()
        } else {
            PlatformConfig::default()
        }
    }

    fn svc(&self) -> &AmtService {
        self.service.as_ref().expect("service alive during run")
    }

    fn open_service(&mut self) -> Result<(), String> {
        let mut svc = if let Some(dir) = &self.data_dir {
            AmtService::open_with_durability(
                dir,
                self.platform(),
                Arc::new(NativeBackend),
                SchedulerConfig::default(),
                DurabilityOptions::default(),
            )
            .map_err(|e| format!("open durable service: {e}"))?
        } else {
            AmtService::new(self.platform())
        };
        let rs = svc.recovery_stats();
        self.recovery.fast_resumed += rs.fast_resumed;
        self.recovery.scratch_resumed += rs.scratch_resumed;
        self.recovery.replayed_proposals += rs.replayed_proposals;
        if self.wl.plane == Plane::Distributed {
            let transports: Vec<Box<dyn Transport>> =
                (0..self.wl.workers).map(|_| self.fleet.spawn_one()).collect();
            svc.attach_remote_workers(
                transports,
                RemoteConfig { batch_steps: 16, ..RemoteConfig::default() },
            );
            self.epoch_initial_workers = self.wl.workers as u64;
            self.expected_joins += self.wl.workers as u64;
        }
        self.epoch_joins_fired = 0;
        self.epoch_drains_fired = 0;
        self.service = Some(svc);
        Ok(())
    }

    /// Wait (bounded) for the current epoch's join/drain counters to
    /// converge, then fold the pool's conserved counters into the totals.
    /// Called before every pool teardown and at the end of the run, so
    /// reopen epochs never lose counts.
    fn absorb_pool(&mut self) {
        let Some(pool) = self.svc().remote_pool() else { return };
        let want_joins = self.epoch_initial_workers + self.epoch_joins_fired;
        let want_drains = self.epoch_drains_fired;
        let deadline = Instant::now() + Duration::from_secs(10);
        while (pool.joins() < want_joins || pool.drains() < want_drains)
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.pool.joins += pool.joins();
        self.pool.drains += pool.drains();
        self.pool.steals += pool.steals();
        self.pool.snapshot_requeues += pool.snapshot_requeues();
        self.pool.scratch_requeues += pool.scratch_requeues();
        self.pool.replayed_proposals += pool.replayed_proposals();
        self.pool.wal_commit_errors += pool.wal_commit_errors();
        self.epoch_initial_workers = 0;
    }

    fn fire_chaos(&mut self, index: usize) -> Result<(), String> {
        self.chaos_fired += 1;
        match self.wl.chaos[index].action {
            ChaosAction::KillWorker(w) => {
                if let Some(fault) = self.fleet.faults.get(w) {
                    fault.kill();
                }
            }
            ChaosAction::JoinWorker => {
                let transport = self.fleet.spawn_one();
                if self.svc().add_remote_worker(transport).is_some() {
                    self.epoch_joins_fired += 1;
                    self.expected_joins += 1;
                }
            }
            ChaosAction::DrainWorker(w) => {
                if self.svc().drain_remote_worker(w) {
                    self.epoch_drains_fired += 1;
                    self.expected_drains += 1;
                }
            }
            ChaosAction::ReopenLeader => {
                // Outcomes are consumed by `wait` and do not survive a
                // reopen (the store keeps the terminal record, not the
                // in-memory outcome) — so secure the bit-identity probes
                // first. Everything else rides the recovery path.
                let probes: Vec<usize> = self
                    .probe_seqs
                    .iter()
                    .copied()
                    .filter(|&seq| seq < self.ledger.len())
                    .collect();
                for seq in probes {
                    self.wait_job(seq, false);
                }
                self.absorb_pool();
                let svc = self.service.take().expect("service alive");
                svc.close().map_err(|e| format!("close leader: {e}"))?;
                self.fleet.join_all();
                self.open_service()?;
            }
        }
        Ok(())
    }

    /// Block until `name` finishes and fold its outcome into the run
    /// accounting. Service `wait` is consuming, so each job is waited at
    /// most once; `timed` controls whether the wait lands in
    /// `load.wait_us` (warm-start parent barriers are untimed).
    fn wait_job(&mut self, seq: usize, timed: bool) {
        if !self.ledger[seq].created || self.ledger[seq].waited {
            return;
        }
        let name = self.ledger[seq].name.clone();
        let start = Instant::now();
        let result = self.svc().wait(&name);
        if timed {
            self.h_wait.record_duration(start.elapsed());
        }
        self.ledger[seq].waited = true;
        match result {
            Ok(outcome) => {
                self.evaluations += outcome.evaluations.len() as u64;
                if self.probe_seqs.contains(&seq) {
                    self.outcomes.insert(seq, outcome);
                }
            }
            Err(_) => {
                // A job that completed before a leader reopen has no
                // waitable outcome on the reopened service — its terminal
                // store record is the ground truth. Only a genuinely
                // non-terminal job is an op failure.
                let terminal = self
                    .svc()
                    .describe_tuning_job(&name)
                    .map(|s| s.status != "InProgress")
                    .unwrap_or(false);
                if !terminal {
                    self.ops_failed += 1;
                }
            }
        }
    }

    fn exec_create(&mut self, c: &CreateOp) {
        // Warm-start parents must hold a completed observation before the
        // child resolves them: barrier on any still-running parent first.
        for parent in c.request.warm_start_parents.clone() {
            if let Some(&pseq) = self.name_to_seq.get(&parent) {
                self.wait_job(pseq, false);
            }
        }
        let start = Instant::now();
        let mut result = if let Some(theta) = c.theta {
            self.svc().create_custom_tuning_job(
                c.request.clone(),
                Arc::new(ScalarizedBiObjective::new(theta)),
            )
        } else {
            self.svc().create_tuning_job(c.request.clone())
        };
        if matches!(result, Err(ApiError::BadParent(_))) {
            // Parent finished without a completed observation (stopped or
            // failed): degrade to a plain create, keeping the planned name
            // and seed so the ledger stays dense.
            self.degraded_creates += 1;
            let mut request = c.request.clone();
            request.warm_start_parents.clear();
            result = self.svc().create_tuning_job(request);
        }
        self.h_create.record_duration(start.elapsed());
        let created = result.is_ok();
        if !created {
            self.ops_failed += 1;
        }
        debug_assert_eq!(c.seq, self.ledger.len());
        self.name_to_seq.insert(c.request.name.clone(), c.seq);
        self.ledger.push(LedgerEntry { name: c.request.name.clone(), created, waited: false });
    }

    fn exec_op(&mut self, op: &PlannedOp) -> Result<(), String> {
        match op {
            PlannedOp::Create(c) => self.exec_create(c),
            PlannedOp::Describe { target } => {
                let name = self.ledger[*target].name.clone();
                let start = Instant::now();
                let result = self.svc().describe_tuning_job(&name);
                self.h_describe.record_duration(start.elapsed());
                if result.is_err() && self.ledger[*target].created {
                    self.ops_failed += 1;
                }
            }
            PlannedOp::List => {
                let prefix = format!("{}-", self.wl.name);
                let start = Instant::now();
                let _ = self.svc().list_tuning_jobs(&prefix);
                self.h_list.record_duration(start.elapsed());
            }
            PlannedOp::Stop { target } => {
                let name = self.ledger[*target].name.clone();
                let start = Instant::now();
                // NotFound simply means the job already reached a terminal
                // state — stop is asynchronous and racing completion is the
                // expected case under load.
                let _ = self.svc().stop_tuning_job(&name);
                self.h_stop.record_duration(start.elapsed());
            }
            PlannedOp::Wait { target } => {
                if self.ledger[*target].created && !self.ledger[*target].waited {
                    self.wait_job(*target, true);
                } else {
                    // Already consumed: a describe keeps the polling
                    // pressure (and the op count) without double-waiting.
                    let name = self.ledger[*target].name.clone();
                    let start = Instant::now();
                    let _ = self.svc().describe_tuning_job(&name);
                    self.h_wait.record_duration(start.elapsed());
                }
            }
            PlannedOp::Chaos { index } => self.fire_chaos(*index)?,
            PlannedOp::PhaseEnd { .. } => unreachable!("handled by the phase loop"),
        }
        Ok(())
    }

    fn run(mut self) -> Result<RunReport, String> {
        if let Some(dir) = &self.data_dir {
            let _ = std::fs::remove_dir_all(dir);
        }
        self.open_service()?;
        let run_start = Instant::now();
        let mut last_report = Instant::now();
        let mut phases: Vec<PhaseReport> = Vec::new();
        let mut phase_idx = 0usize;
        let mut phase_start = Instant::now();
        let mut phase_ops = 0u32;
        let mut due_s = 0.0f64;
        let prefix = format!("{}-", self.wl.name);

        for op in &self.plan.ops {
            if let PlannedOp::PhaseEnd { phase } = op {
                let spec = &self.wl.phases[*phase];
                let wall = phase_start.elapsed().as_secs_f64();
                phases.push(PhaseReport {
                    kind: spec.kind,
                    ops: phase_ops,
                    target_rate: target_rate(spec),
                    achieved_rate: if wall > 0.0 { phase_ops as f64 / wall } else { 0.0 },
                    wall_s: wall,
                });
                // Mid-run observer: store versions must stay monotone at
                // every phase boundary, chaos or not.
                let store = self.svc().store();
                self.watch.observe(store.as_ref(), "tuning_jobs", &prefix);
                phase_idx += 1;
                phase_start = Instant::now();
                phase_ops = 0;
                due_s = 0.0;
                continue;
            }
            if let PlannedOp::Chaos { index } = op {
                self.fire_chaos(*index)?;
                continue;
            }
            // Pace against the schedule (wall clock only).
            if !self.wl.virtual_clock {
                let spec = &self.wl.phases[phase_idx];
                let rate = rate_at(spec, phase_ops);
                if rate > 0.0 {
                    due_s += 1.0 / rate;
                    let target = phase_start + Duration::from_secs_f64(due_s);
                    let now = Instant::now();
                    if target > now {
                        std::thread::sleep(target - now);
                    }
                }
            }
            self.exec_op(op)?;
            self.ops_executed += 1;
            phase_ops += 1;
            if let Some(every) = self.report_every {
                if last_report.elapsed() >= every {
                    last_report = Instant::now();
                    self.live_line(run_start);
                }
            }
        }

        // Drain: every created job must reach a terminal state.
        for seq in 0..self.ledger.len() {
            self.wait_job(seq, true);
        }
        self.absorb_pool();
        let store = self.svc().store();
        self.watch.observe(store.as_ref(), "tuning_jobs", &prefix);
        drop(store);

        let snapshot = TelemetrySnapshot::from_parts(vec![
            self.svc().telemetry_snapshot().metrics,
            self.registry.snapshot(),
        ]);
        let observers = self.final_observers(&snapshot, &prefix);
        let wall_s = run_start.elapsed().as_secs_f64();

        // Teardown: close (checkpoint) durable services, then join the
        // worker threads freed by the pool drop.
        if let Some(svc) = self.service.take() {
            if self.data_dir.is_some() {
                svc.close().map_err(|e| format!("close service: {e}"))?;
            }
        }
        self.fleet.join_all();
        if let Some(dir) = &self.data_dir {
            let _ = std::fs::remove_dir_all(dir);
        }

        Ok(RunReport {
            workload_name: self.wl.name.clone(),
            wall_s,
            ops_executed: self.ops_executed,
            ops_failed: self.ops_failed,
            jobs_created: self.ledger.iter().filter(|l| l.created).count() as u64,
            evaluations: self.evaluations,
            chaos_fired: self.chaos_fired,
            degraded_creates: self.degraded_creates,
            phases,
            observers,
            pool: self.pool,
            recovery: self.recovery,
            snapshot,
        })
    }

    fn live_line(&self, run_start: Instant) {
        let snap = self.svc().telemetry_snapshot();
        let calls = snap.counter("api.calls").unwrap_or(0);
        let steals = snap.counter("leader.steals").unwrap_or(0);
        let create = self.h_create.summary();
        let wait = self.h_wait.summary();
        eprintln!(
            "[load {:>6.1}s] ops={}/{} jobs={} api.calls={} steals={} \
             create p99={}us wait p99={}us",
            run_start.elapsed().as_secs_f64(),
            self.ops_executed,
            self.plan.ops.len(),
            self.ledger.len(),
            calls,
            steals,
            create.p99,
            wait.p99,
        );
    }

    fn final_observers(&mut self, snapshot: &TelemetrySnapshot, prefix: &str) -> ObserverReport {
        let mut report = ObserverReport::default();
        let store = self.svc().store();

        // 1. Zero lost or duplicated jobs: the store's view of the job
        //    namespace must equal the runner's ledger exactly.
        let stored: BTreeSet<String> = store.list_keys("tuning_jobs", prefix).into_iter().collect();
        let created: BTreeSet<String> = self
            .ledger
            .iter()
            .filter(|l| l.created)
            .map(|l| l.name.clone())
            .collect();
        let lost: Vec<&String> = created.difference(&stored).collect();
        let phantom: Vec<&String> = stored.difference(&created).collect();
        report.push(
            "jobs_conserved",
            lost.is_empty() && phantom.is_empty(),
            format!(
                "{} created, {} stored, {} lost, {} phantom",
                created.len(),
                stored.len(),
                lost.len(),
                phantom.len()
            ),
        );

        // 2. Every job reached a terminal state.
        let mut in_progress = 0u64;
        for l in self.ledger.iter().filter(|l| l.created) {
            match self.svc().describe_tuning_job(&l.name) {
                Ok(summary) if summary.status == "InProgress" => in_progress += 1,
                Ok(_) => {}
                Err(_) => in_progress += 1,
            }
        }
        report.push(
            "terminal_status",
            in_progress == 0,
            format!("{} of {} jobs non-terminal after drain", in_progress, created.len()),
        );

        // 3. Store versions never regressed across phases or reopens.
        report.push(
            "store_version_monotonic",
            self.watch.violations.is_empty(),
            if self.watch.violations.is_empty() {
                format!("{} observations, no regressions", self.watch.observations)
            } else {
                self.watch.violations.join("; ")
            },
        );

        // 4. Conserved fleet counters: every admitted worker was counted
        //    joined, every drain completed, and no WAL commit ever failed
        //    on either plane.
        let sched_wal = snapshot.counter("scheduler.wal_commit_errors").unwrap_or(0);
        let joins_ok = self.wl.plane != Plane::Distributed
            || (self.pool.joins == self.expected_joins
                && self.pool.drains == self.expected_drains);
        report.push(
            "counter_conservation",
            joins_ok && self.pool.wal_commit_errors == 0 && sched_wal == 0,
            format!(
                "joins={}/{} drains={}/{} steals={} wal_errors={}+{}",
                self.pool.joins,
                self.expected_joins,
                self.pool.drains,
                self.expected_drains,
                self.pool.steals,
                self.pool.wal_commit_errors,
                sched_wal
            ),
        );

        // 5. Replays only ever come from scratch legs: snapshot-path
        //    requeues and snapshot-resumed recoveries re-execute zero
        //    strategy proposals.
        let replays = self.pool.replayed_proposals + self.recovery.replayed_proposals;
        let scratch_legs = self.pool.scratch_requeues + self.recovery.scratch_resumed as u64;
        report.push(
            "replays_attributable",
            replays == 0 || scratch_legs > 0,
            format!(
                "{} replayed proposals across {} scratch legs \
                 (snapshot legs: {} requeues + {} resumes, all exact)",
                replays,
                scratch_legs,
                self.pool.snapshot_requeues,
                self.recovery.fast_resumed
            ),
        );

        // 6. Bit-identity: probe jobs from the chaos run must match an
        //    uninterrupted single-job reference run on the local plane.
        let (passed, detail) = self.bit_identity();
        report.push("bit_identity", passed, detail);

        report
    }

    fn bit_identity(&self) -> (bool, String) {
        if self.probe_seqs.is_empty() {
            return (true, "no eligible probe jobs in plan (skipped)".to_string());
        }
        let reference = AmtService::new(self.platform());
        let creates = self.plan.creates();
        let mut compared = 0usize;
        for &seq in &self.probe_seqs {
            let Some(main_outcome) = self.outcomes.get(&seq) else {
                return (false, format!("probe seq {seq} has no recorded outcome"));
            };
            let c = creates.iter().find(|c| c.seq == seq).expect("probe seq in plan");
            if let Err(e) = reference.create_tuning_job(c.request.clone()) {
                return (false, format!("reference create {}: {e:?}", c.request.name));
            }
            let reference_outcome = match reference.wait(&c.request.name) {
                Ok(o) => o,
                Err(e) => return (false, format!("reference wait {}: {e:?}", c.request.name)),
            };
            if fingerprint(main_outcome) != fingerprint(&reference_outcome) {
                return (
                    false,
                    format!("{} diverged from uninterrupted reference run", c.request.name),
                );
            }
            compared += 1;
        }
        (true, format!("{compared} probe jobs bit-identical to uninterrupted reference"))
    }
}

/// Mean target rate of a phase, for reporting (0 = unpaced).
fn target_rate(spec: &PhaseSpec) -> f64 {
    match spec.kind {
        PhaseKind::Steady => spec.rate,
        PhaseKind::Ramp => (spec.rate + spec.rate_end) / 2.0,
        PhaseKind::Burst => 0.0,
    }
}

/// Instantaneous target rate before the `j`-th op of a phase.
fn rate_at(spec: &PhaseSpec, j: u32) -> f64 {
    match spec.kind {
        PhaseKind::Steady => spec.rate,
        PhaseKind::Ramp => {
            let span = (spec.ops.saturating_sub(1)).max(1) as f64;
            spec.rate + (spec.rate_end - spec.rate) * (j as f64 / span)
        }
        PhaseKind::Burst => 0.0,
    }
}

/// Exact string form of an outcome: per-evaluation JSON (bit-exact f64s,
/// virtual timestamps), best value bits, and workflow status.
fn fingerprint(outcome: &TuningJobOutcome) -> String {
    let evals =
        Json::Arr(outcome.evaluations.iter().map(|e| e.to_json()).collect()).to_string();
    let best = outcome
        .best
        .as_ref()
        .map(|(config, value)| format!("{config:?}|{:016x}", value.to_bits()))
        .unwrap_or_else(|| "none".to_string());
    format!("{evals}::{best}::{:?}", outcome.status)
}
