//! Minimal JSON implementation (parser + writer).
//!
//! The build environment is fully offline with a pinned vendored crate set
//! that does not include serde/serde_json, so the service implements its own
//! JSON layer: the API surface (§3.2) speaks JSON, the metadata store
//! persists JSON snapshots, and `artifacts/manifest.json` is read by the
//! runtime. The subset implemented is complete JSON (RFC 8259) minus
//! `\u` surrogate-pair edge cases beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object constructor from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Field access on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric view.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view (numbers that round-trip exactly).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object view.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Compact serialization into a caller-owned buffer — the
    /// allocation-free variant of [`Json::to_string`] for hot encoders
    /// (the WAL frame writer) that reuse one scratch `String` across
    /// many records.
    pub fn write_compact(&self, out: &mut String) {
        self.write(out);
    }

    /// Pretty serialization (2-space indent) for human-readable snapshots.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

/// Lossless u64 encoding. JSON numbers are f64 (53 integer bits), so raw
/// 64-bit words — RNG state, Sobol cursors — travel as fixed-width hex
/// strings in resume snapshots.
pub fn u64_to_json(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// Parse a [`u64_to_json`] value.
pub fn u64_from_json(j: &Json) -> Option<u64> {
    u64::from_str_radix(j.as_str()?, 16).ok()
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 9e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_hex_roundtrip_covers_full_range() {
        for v in [0u64, 1, (1 << 53) + 1, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            let j = u64_to_json(v);
            let text = j.to_string();
            assert_eq!(u64_from_json(&parse(&text).unwrap()), Some(v));
        }
        assert_eq!(u64_from_json(&Json::Num(1.0)), None);
    }

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e-9", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -1.5e3}"#;
        let v = parse(src).unwrap();
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""line\nbreak \"q\" \\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "line\nbreak \"q\" \\ A");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,]", "{\"a\"}", "tru", "1.2.3", "\"unterminated", "{} extra"] {
            assert!(parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::Str("amt".into())),
            ("nums", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
            ("nested", Json::obj(vec![("flag", Json::Bool(true))])),
        ]);
        let pretty = v.to_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }
}
