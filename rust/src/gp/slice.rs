//! Slice sampling of GP hyperparameters (§4.2).
//!
//! The paper's spec, implemented exactly: one chain of 300 samples with 250
//! burn-in and thinning every 5 — an effective sample size of 10 — using a
//! *random (normalized) direction* per update to reduce the multivariate
//! problem (θ ∈ ℝᵏ) to the standard univariate slice sampler (Neal 2003,
//! stepping-out + shrinkage), with box bounds on the GPHPs for numerical
//! stability.
//!
//! Every likelihood query runs through one [`GramScratch`] workspace and a
//! reusable packed-θ buffer, so the inner loop (~600 Gram + Cholesky
//! evaluations per proposal at the paper's settings) performs zero heap
//! allocations after the first evaluation (DESIGN.md §3).

use super::dataset::{Dataset, GramScratch};
use super::theta::Theta;
use super::{nll_scratch, SurrogateBackend};
use crate::rng::Rng;

/// Sampler configuration. `Default` is the paper's production setting.
#[derive(Clone, Copy, Debug)]
pub struct SliceConfig {
    /// Total samples drawn (paper: 300).
    pub samples: usize,
    /// Burn-in discarded from the front (paper: 250).
    pub burn_in: usize,
    /// Keep every `thin`-th sample after burn-in (paper: 5 ⇒ ESS 10).
    pub thin: usize,
    /// Initial slice bracket width (in packed log-space units).
    pub width: f64,
    /// Max stepping-out expansions per side.
    pub max_steps_out: usize,
}

impl Default for SliceConfig {
    fn default() -> Self {
        SliceConfig { samples: 300, burn_in: 250, thin: 5, width: 1.0, max_steps_out: 8 }
    }
}

impl SliceConfig {
    /// Cheaper preset for the figure harnesses and tests (ESS 5): same
    /// algorithm, reduced chain length.
    pub fn light() -> Self {
        SliceConfig { samples: 100, burn_in: 75, thin: 5, width: 1.0, max_steps_out: 6 }
    }
}

/// Zero-allocation log unnormalized posterior of a packed θ: −NLL + log
/// prior, or −∞ outside the stability box / on non-PD Gram matrices.
#[allow(clippy::too_many_arguments)]
fn log_target_scratch(
    backend: &dyn SurrogateBackend,
    x: &Dataset,
    y: &[f64],
    packed: &[f64],
    d: usize,
    bounds: &[(f64, f64)],
    theta_buf: &mut Theta,
    scratch: &mut GramScratch,
) -> f64 {
    // outside the stability box ⇒ reject
    for (v, (lo, hi)) in packed.iter().zip(bounds) {
        if *v < *lo || *v > *hi {
            return f64::NEG_INFINITY;
        }
    }
    theta_buf.unpack_into(packed, d);
    match nll_scratch(backend, x, y, theta_buf, scratch) {
        Some(l) => -l + theta_buf.log_prior(),
        None => f64::NEG_INFINITY,
    }
}

/// Run the chain; returns the thinned posterior samples of θ.
///
/// `x` are encoded live configurations, `y` normalized observations. The
/// chain starts at [`Theta::default_for_dim`] (or `init` if given).
pub fn sample_gphp(
    backend: &dyn SurrogateBackend,
    x: &Dataset,
    y: &[f64],
    d: usize,
    config: &SliceConfig,
    rng: &mut Rng,
    init: Option<Theta>,
) -> Vec<Theta> {
    let mut cur = init.unwrap_or_else(|| Theta::default_for_dim(d)).pack();
    Theta::clamp_packed(&mut cur, d);
    let bounds = Theta::bounds(d);
    let mut theta_buf = Theta::default_for_dim(d);
    let mut scratch = GramScratch::new();
    let mut cur_lp =
        log_target_scratch(backend, x, y, &cur, d, &bounds, &mut theta_buf, &mut scratch);
    // If even the default point fails (tiny pathological datasets), bail to
    // the prior default — callers fall back to the default theta.
    if !cur_lp.is_finite() {
        return vec![Theta::unpack(&cur, d)];
    }

    let k = cur.len();
    let mut dir = vec![0.0; k];
    let mut probe = vec![0.0; k];
    let mut kept = Vec::new();
    for step in 0..config.samples {
        // one random-direction univariate slice update
        rng.unit_vector_into(&mut dir);
        let log_y = cur_lp + rng.uniform().max(1e-300).ln(); // slice level

        // stepping out
        let mut lo = -config.width * rng.uniform();
        let mut hi = lo + config.width;
        macro_rules! eval_at {
            ($t:expr) => {{
                for ((p, c), u) in probe.iter_mut().zip(&cur).zip(&dir) {
                    *p = c + $t * u;
                }
                log_target_scratch(
                    backend, x, y, &probe, d, &bounds, &mut theta_buf, &mut scratch,
                )
            }};
        }
        for _ in 0..config.max_steps_out {
            if eval_at!(lo) <= log_y {
                break;
            }
            lo -= config.width;
        }
        for _ in 0..config.max_steps_out {
            if eval_at!(hi) <= log_y {
                break;
            }
            hi += config.width;
        }

        // shrinkage
        for _ in 0..60 {
            let t = rng.uniform_range(lo, hi);
            let lp = eval_at!(t);
            if lp > log_y {
                for (c, u) in cur.iter_mut().zip(&dir) {
                    *c += t * u;
                }
                cur_lp = lp;
                break;
            }
            if t < 0.0 {
                lo = t;
            } else {
                hi = t;
            }
        }
        // a fully shrunk bracket keeps the current point

        if step >= config.burn_in && (step - config.burn_in) % config.thin == 0 {
            kept.push(Theta::unpack(&cur, d));
        }
    }
    if kept.is_empty() {
        kept.push(Theta::unpack(&cur, d));
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::NativeBackend;

    fn toy(n: usize, seed: u64) -> (Dataset, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Dataset::new(2);
        for _ in 0..n {
            x.push_row(&[rng.uniform(), rng.uniform()]);
        }
        let y: Vec<f64> = x.rows().map(|p| (4.0 * p[0]).sin() + 0.05 * rng.normal()).collect();
        let (m, s) = crate::gp::normalization(&y);
        (x, y.iter().map(|v| (v - m) / s).collect())
    }

    #[test]
    fn paper_spec_yields_ess_10() {
        let c = SliceConfig::default();
        assert_eq!((c.samples - c.burn_in) / c.thin, 10);
    }

    #[test]
    fn samples_stay_in_bounds_and_vary() {
        let (x, y) = toy(15, 1);
        let mut rng = Rng::new(2);
        let thetas = sample_gphp(
            &NativeBackend,
            &x,
            &y,
            2,
            &SliceConfig { samples: 40, burn_in: 20, thin: 2, ..Default::default() },
            &mut rng,
            None,
        );
        assert_eq!(thetas.len(), 10);
        let bounds = Theta::bounds(2);
        for t in &thetas {
            for (v, (lo, hi)) in t.pack().iter().zip(&bounds) {
                assert!(*v >= *lo - 1e-12 && *v <= *hi + 1e-12);
            }
        }
        // the chain must actually move
        let first = thetas[0].pack();
        assert!(thetas.iter().any(|t| {
            t.pack().iter().zip(&first).any(|(a, b)| (a - b).abs() > 1e-6)
        }));
    }

    #[test]
    fn posterior_concentrates_noise_below_signal() {
        // data has tiny observation noise; sampled log_noise should sit well
        // below log signal variance on average
        let (x, y) = toy(30, 3);
        let mut rng = Rng::new(4);
        let thetas =
            sample_gphp(&NativeBackend, &x, &y, 2, &SliceConfig::light(), &mut rng, None);
        let avg_noise: f64 =
            thetas.iter().map(|t| t.log_noise).sum::<f64>() / thetas.len() as f64;
        let avg_amp: f64 = thetas.iter().map(|t| t.log_amp).sum::<f64>() / thetas.len() as f64;
        assert!(avg_noise < avg_amp, "noise {avg_noise} vs amp {avg_amp}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = toy(10, 5);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let c = SliceConfig { samples: 20, burn_in: 10, thin: 2, ..Default::default() };
        let a = sample_gphp(&NativeBackend, &x, &y, 2, &c, &mut r1, None);
        let b = sample_gphp(&NativeBackend, &x, &y, 2, &c, &mut r2, None);
        assert_eq!(a, b);
    }
}
