//! GP hyperparameters (GPHPs, §4.2): packed representation, priors, bounds.
//!
//! The packed layout is shared byte-for-byte with the AOT HLO graphs (see
//! `python/compile/model.py`):
//!
//! ```text
//! theta = [ log_amp, log_noise, log_ls[0..d), log_wa[0..d), log_wb[0..d) ]
//! ```
//!
//! All parameters live in log space, which makes the slice sampler and the
//! empirical-Bayes optimizer unconstrained up to the stability box bounds
//! the paper mentions ("we fix upper and lower bounds on the GPHPs for
//! numerical stability").

/// GP hyperparameters for a `d`-dimensional encoded space.
#[derive(Clone, Debug, PartialEq)]
pub struct Theta {
    /// log signal variance (amplitude²).
    pub log_amp: f64,
    /// log observation-noise variance.
    pub log_noise: f64,
    /// log ARD lengthscales, one per encoded dimension.
    pub log_ls: Vec<f64>,
    /// log Kumaraswamy `a` warping parameters (0 ⇒ identity warp).
    pub log_wa: Vec<f64>,
    /// log Kumaraswamy `b` warping parameters.
    pub log_wb: Vec<f64>,
}

impl Theta {
    /// Sensible starting point: unit amplitude, small noise, lengthscale
    /// 0.5 in the unit cube, identity warp.
    pub fn default_for_dim(d: usize) -> Theta {
        Theta {
            log_amp: 0.0,
            log_noise: (1e-3f64).ln(),
            log_ls: vec![0.5f64.ln(); d],
            log_wa: vec![0.0; d],
            log_wb: vec![0.0; d],
        }
    }

    /// Encoded dimensionality d.
    pub fn dim(&self) -> usize {
        self.log_ls.len()
    }

    /// Packed length 2 + 3d.
    pub fn packed_len(d: usize) -> usize {
        2 + 3 * d
    }

    /// Pack into the shared flat layout.
    pub fn pack(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(Self::packed_len(self.dim()));
        v.push(self.log_amp);
        v.push(self.log_noise);
        v.extend_from_slice(&self.log_ls);
        v.extend_from_slice(&self.log_wa);
        v.extend_from_slice(&self.log_wb);
        v
    }

    /// Unpack from the shared flat layout.
    pub fn unpack(v: &[f64], d: usize) -> Theta {
        assert_eq!(v.len(), Self::packed_len(d), "theta length mismatch");
        Theta {
            log_amp: v[0],
            log_noise: v[1],
            log_ls: v[2..2 + d].to_vec(),
            log_wa: v[2 + d..2 + 2 * d].to_vec(),
            log_wb: v[2 + 2 * d..2 + 3 * d].to_vec(),
        }
    }

    /// Unpack into an existing Theta, reusing its buffers (no allocation
    /// when the dimension is unchanged — the slice sampler calls this once
    /// per likelihood query).
    pub fn unpack_into(&mut self, v: &[f64], d: usize) {
        assert_eq!(v.len(), Self::packed_len(d), "theta length mismatch");
        self.log_amp = v[0];
        self.log_noise = v[1];
        self.log_ls.resize(d, 0.0);
        self.log_ls.copy_from_slice(&v[2..2 + d]);
        self.log_wa.resize(d, 0.0);
        self.log_wa.copy_from_slice(&v[2 + d..2 + 2 * d]);
        self.log_wb.resize(d, 0.0);
        self.log_wb.copy_from_slice(&v[2 + 2 * d..2 + 3 * d]);
    }

    /// Positive-space views.
    pub fn amp(&self) -> f64 {
        self.log_amp.exp()
    }
    /// Observation-noise variance.
    pub fn noise(&self) -> f64 {
        self.log_noise.exp()
    }
    /// ARD lengthscales.
    pub fn lengthscales(&self) -> Vec<f64> {
        self.log_ls.iter().map(|v| v.exp()).collect()
    }
    /// Kumaraswamy `a` parameters.
    pub fn warp_a(&self) -> Vec<f64> {
        self.log_wa.iter().map(|v| v.exp()).collect()
    }
    /// Kumaraswamy `b` parameters.
    pub fn warp_b(&self) -> Vec<f64> {
        self.log_wb.iter().map(|v| v.exp()).collect()
    }

    /// Stability box bounds on the packed vector (lo, hi per entry).
    pub fn bounds(d: usize) -> Vec<(f64, f64)> {
        let mut b = Vec::with_capacity(Self::packed_len(d));
        b.push(((1e-3f64).ln(), (1e3f64).ln())); // amp
        b.push(((1e-6f64).ln(), 1.0f64.ln())); // noise
        for _ in 0..d {
            b.push(((5e-3f64).ln(), (10.0f64).ln())); // lengthscale
        }
        for _ in 0..2 * d {
            b.push(((0.25f64).ln(), (4.0f64).ln())); // warp a, b
        }
        b
    }

    /// Clamp a packed vector into the stability box (in place).
    pub fn clamp_packed(v: &mut [f64], d: usize) {
        for (x, (lo, hi)) in v.iter_mut().zip(Self::bounds(d)) {
            *x = x.clamp(lo, hi);
        }
    }

    /// Log prior density (up to a constant): independent Gaussians in log
    /// space, centered on a weakly-informative configuration. Keeps the
    /// MCMC posterior proper and regularizes empirical Bayes in the
    /// few-observation regime (§4.2).
    pub fn log_prior(&self) -> f64 {
        let mut lp = 0.0;
        let g = |x: f64, mu: f64, sd: f64| -0.5 * ((x - mu) / sd).powi(2);
        lp += g(self.log_amp, 0.0, 1.0);
        lp += g(self.log_noise, (1e-3f64).ln(), 2.0);
        for &l in &self.log_ls {
            lp += g(l, (0.5f64).ln(), 1.0);
        }
        for &a in self.log_wa.iter().chain(&self.log_wb) {
            lp += g(a, 0.0, 0.55); // shrink towards the identity warp
        }
        lp
    }

    /// JSON wire form (packed layout plus the dimension). f64s round-trip
    /// bit-exactly through the JSON layer, so a thawed theta reproduces
    /// kernel evaluations bit-for-bit — required by the
    /// [`crate::coordinator`] resume snapshot, which freezes the BO
    /// strategy's `last_theta` and EB refit cache mid-job.
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        Json::obj(vec![
            ("d", Json::Num(self.dim() as f64)),
            ("packed", Json::Arr(self.pack().into_iter().map(Json::Num).collect())),
        ])
    }

    /// Parse the JSON wire form.
    pub fn from_json(j: &crate::json::Json) -> Option<Theta> {
        use crate::json::Json;
        let d = j.get("d")?.as_i64()? as usize;
        let packed: Vec<f64> =
            j.get("packed")?.as_arr()?.iter().map(Json::as_f64).collect::<Option<_>>()?;
        if packed.len() != Self::packed_len(d) {
            return None;
        }
        Some(Theta::unpack(&packed, d))
    }

    /// Disable input warping (fix a = b = 1); used by the warping ablation.
    pub fn with_identity_warp(mut self) -> Theta {
        self.log_wa.iter_mut().for_each(|v| *v = 0.0);
        self.log_wb.iter_mut().for_each(|v| *v = 0.0);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let t = Theta {
            log_amp: 0.3,
            log_noise: -5.0,
            log_ls: vec![0.1, -0.2, 0.5],
            log_wa: vec![0.0, 0.1, -0.1],
            log_wb: vec![0.2, 0.0, 0.05],
        };
        let packed = t.pack();
        assert_eq!(packed.len(), Theta::packed_len(3));
        assert_eq!(Theta::unpack(&packed, 3), t);
    }

    #[test]
    fn unpack_into_matches_unpack() {
        let t = Theta {
            log_amp: -0.4,
            log_noise: -6.0,
            log_ls: vec![0.3, -0.7],
            log_wa: vec![0.05, -0.02],
            log_wb: vec![0.0, 0.4],
        };
        let packed = t.pack();
        let mut buf = Theta::default_for_dim(2);
        buf.unpack_into(&packed, 2);
        assert_eq!(buf, Theta::unpack(&packed, 2));
    }

    #[test]
    fn bounds_cover_default() {
        let d = 5;
        let t = Theta::default_for_dim(d);
        for (v, (lo, hi)) in t.pack().iter().zip(Theta::bounds(d)) {
            assert!(*v >= lo && *v <= hi, "{v} not in [{lo}, {hi}]");
        }
    }

    #[test]
    fn clamp_respects_box() {
        let d = 2;
        let mut v = vec![100.0; Theta::packed_len(d)];
        Theta::clamp_packed(&mut v, d);
        for (x, (lo, hi)) in v.iter().zip(Theta::bounds(d)) {
            assert!(*x >= lo && *x <= hi);
        }
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let t = Theta {
            log_amp: 1.0 / 3.0,
            log_noise: -6.907755278982137,
            log_ls: vec![0.1, -0.2, 1e-300],
            log_wa: vec![0.0, 0.125, -0.1],
            log_wb: vec![0.2, 0.0, 0.05],
        };
        let text = t.to_json().to_string();
        let back = Theta::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        for (a, b) in t.pack().iter().zip(back.pack()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // wrong packed length is rejected
        let bad = crate::json::parse(r#"{"d": 2, "packed": [1, 2, 3]}"#).unwrap();
        assert!(Theta::from_json(&bad).is_none());
    }

    #[test]
    fn prior_prefers_identity_warp() {
        let d = 2;
        let base = Theta::default_for_dim(d);
        let mut warped = base.clone();
        warped.log_wa = vec![1.0; d];
        assert!(base.log_prior() > warped.log_prior());
    }
}
