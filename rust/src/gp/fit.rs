//! Empirical-Bayes GPHP fitting (§4.2's "traditional way"): maximize the
//! log marginal likelihood (plus the weak log prior, which regularizes the
//! few-observation regime the paper warns about) with a bounded
//! Nelder–Mead simplex over the packed log-space θ.
//!
//! Also home of the general-purpose [`nelder_mead`] optimizer, reused by
//! the acquisition module to locally optimize EI from Sobol anchors (§4.3).

use super::theta::Theta;
use super::{nll, SurrogateBackend};
use crate::rng::Rng;

/// Nelder–Mead options.
#[derive(Clone, Copy, Debug)]
pub struct NmOptions {
    /// Maximum function evaluations.
    pub max_evals: usize,
    /// Initial simplex scale (per coordinate).
    pub init_step: f64,
    /// Convergence: simplex f-spread below this stops.
    pub f_tol: f64,
}

impl Default for NmOptions {
    fn default() -> Self {
        NmOptions { max_evals: 400, init_step: 0.4, f_tol: 1e-8 }
    }
}

/// Derivative-free Nelder–Mead minimization of `f` from `x0`.
/// Returns (argmin, min). `f` may return `None` ⇒ treated as +∞.
pub fn nelder_mead<F>(f: F, x0: &[f64], opts: &NmOptions) -> (Vec<f64>, f64)
where
    F: Fn(&[f64]) -> Option<f64>,
{
    let n = x0.len();
    let eval = |x: &[f64]| f(x).unwrap_or(f64::INFINITY);
    // initial simplex: x0 plus per-coordinate steps
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    simplex.push((x0.to_vec(), eval(x0)));
    for i in 0..n {
        let mut xi = x0.to_vec();
        xi[i] += opts.init_step;
        let fx = eval(&xi);
        simplex.push((xi, fx));
    }
    let mut evals = n + 1;

    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);
    while evals < opts.max_evals {
        simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let spread = simplex[n].1 - simplex[0].1;
        if spread.abs() < opts.f_tol {
            break;
        }
        // centroid of all but worst
        let mut c = vec![0.0; n];
        for (x, _) in &simplex[..n] {
            for (ci, xi) in c.iter_mut().zip(x) {
                *ci += xi / n as f64;
            }
        }
        let worst = simplex[n].clone();
        let refl: Vec<f64> =
            c.iter().zip(&worst.0).map(|(ci, wi)| ci + alpha * (ci - wi)).collect();
        let f_refl = eval(&refl);
        evals += 1;

        if f_refl < simplex[0].1 {
            // expansion
            let exp: Vec<f64> =
                c.iter().zip(&refl).map(|(ci, ri)| ci + gamma * (ri - ci)).collect();
            let f_exp = eval(&exp);
            evals += 1;
            simplex[n] = if f_exp < f_refl { (exp, f_exp) } else { (refl, f_refl) };
        } else if f_refl < simplex[n - 1].1 {
            simplex[n] = (refl, f_refl);
        } else {
            // contraction
            let con: Vec<f64> =
                c.iter().zip(&worst.0).map(|(ci, wi)| ci + rho * (wi - ci)).collect();
            let f_con = eval(&con);
            evals += 1;
            if f_con < worst.1 {
                simplex[n] = (con, f_con);
            } else {
                // shrink towards best
                let best = simplex[0].0.clone();
                for (x, fx) in simplex.iter_mut().skip(1) {
                    for (xi, bi) in x.iter_mut().zip(&best) {
                        *xi = bi + sigma * (*xi - bi);
                    }
                    *fx = eval(x);
                    evals += 1;
                }
            }
        }
    }
    simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    simplex.swap_remove(0)
}

/// Empirical-Bayes fit: multi-start Nelder–Mead on −(log marginal
/// likelihood + log prior), clamped to the stability box. Returns the best
/// theta found (always at least the default).
pub fn fit_empirical_bayes(
    backend: &dyn SurrogateBackend,
    x: &[Vec<f64>],
    y: &[f64],
    d: usize,
    restarts: usize,
    rng: &mut Rng,
) -> Theta {
    let objective = |packed: &[f64]| -> Option<f64> {
        let mut p = packed.to_vec();
        Theta::clamp_packed(&mut p, d);
        let theta = Theta::unpack(&p, d);
        nll(backend, x, y, &theta).map(|v| v - theta.log_prior())
    };

    let mut best_x = Theta::default_for_dim(d).pack();
    let mut best_f = objective(&best_x).unwrap_or(f64::INFINITY);

    let bounds = Theta::bounds(d);
    for r in 0..restarts.max(1) {
        let start: Vec<f64> = if r == 0 {
            Theta::default_for_dim(d).pack()
        } else {
            bounds
                .iter()
                .map(|(lo, hi)| rng.uniform_range(*lo * 0.5 + *hi * 0.5 - 1.0, *lo * 0.5 + *hi * 0.5 + 1.0))
                .collect()
        };
        let (xr, fr) = nelder_mead(objective, &start, &NmOptions::default());
        if fr < best_f {
            best_f = fr;
            best_x = xr;
        }
    }
    Theta::clamp_packed(&mut best_x, d);
    Theta::unpack(&best_x, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{normalization, NativeBackend};

    #[test]
    fn nelder_mead_minimizes_quadratic() {
        let f = |x: &[f64]| Some((x[0] - 2.0).powi(2) + (x[1] + 1.0).powi(2) + 3.0);
        let (x, fx) = nelder_mead(f, &[0.0, 0.0], &NmOptions::default());
        assert!((x[0] - 2.0).abs() < 1e-3, "{x:?}");
        assert!((x[1] + 1.0).abs() < 1e-3, "{x:?}");
        assert!((fx - 3.0).abs() < 1e-5);
    }

    #[test]
    fn nelder_mead_handles_infeasible_regions() {
        // f undefined left of 1.0
        let f = |x: &[f64]| (x[0] > 1.0).then(|| (x[0] - 3.0).powi(2));
        let (x, _) = nelder_mead(f, &[4.0], &NmOptions::default());
        assert!((x[0] - 3.0).abs() < 1e-2);
    }

    #[test]
    fn rosenbrock_2d_reasonable() {
        let f =
            |x: &[f64]| Some((1.0 - x[0]).powi(2) + 100.0 * (x[1] - x[0] * x[0]).powi(2));
        let (x, fx) =
            nelder_mead(f, &[-1.0, 1.0], &NmOptions { max_evals: 2000, ..Default::default() });
        assert!(fx < 1e-3, "fx={fx} at {x:?}");
    }

    #[test]
    fn eb_fit_improves_over_default() {
        let mut rng = Rng::new(1);
        let x: Vec<Vec<f64>> =
            (0..25).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
        let y_raw: Vec<f64> =
            x.iter().map(|p| (5.0 * p[0]).sin() * 2.0 + 0.01 * rng.normal()).collect();
        let (m, s) = normalization(&y_raw);
        let y: Vec<f64> = y_raw.iter().map(|v| (v - m) / s).collect();

        let fitted = fit_empirical_bayes(&NativeBackend, &x, &y, 2, 2, &mut rng);
        let default = Theta::default_for_dim(2);
        let nll_fit = nll(&NativeBackend, &x, &y, &fitted).unwrap();
        let nll_def = nll(&NativeBackend, &x, &y, &default).unwrap();
        assert!(
            nll_fit <= nll_def + 1e-9,
            "fitted {nll_fit} should beat default {nll_def}"
        );
    }

    #[test]
    fn eb_fit_stays_in_bounds() {
        let mut rng = Rng::new(2);
        let x: Vec<Vec<f64>> = (0..8).map(|_| vec![rng.uniform()]).collect();
        let y: Vec<f64> = x.iter().map(|p| p[0]).collect();
        let t = fit_empirical_bayes(&NativeBackend, &x, &y, 1, 1, &mut rng);
        for (v, (lo, hi)) in t.pack().iter().zip(Theta::bounds(1)) {
            assert!(*v >= lo - 1e-9 && *v <= hi + 1e-9);
        }
    }
}
