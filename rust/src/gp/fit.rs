//! Empirical-Bayes GPHP fitting (§4.2's "traditional way"): maximize the
//! log marginal likelihood (plus the weak log prior, which regularizes the
//! few-observation regime the paper warns about) with a bounded
//! Nelder–Mead simplex over the packed log-space θ.
//!
//! Also home of the general-purpose [`nelder_mead`] optimizer, reused by
//! the acquisition module to locally optimize EI from Sobol anchors (§4.3).

use super::dataset::{Dataset, GramScratch};
use super::theta::Theta;
use super::{nll_scratch, SurrogateBackend};
use crate::rng::Rng;

/// Nelder–Mead options.
#[derive(Clone, Copy, Debug)]
pub struct NmOptions {
    /// Maximum function evaluations.
    pub max_evals: usize,
    /// Initial simplex scale (per coordinate).
    pub init_step: f64,
    /// Convergence: simplex f-spread below this stops.
    pub f_tol: f64,
}

impl Default for NmOptions {
    fn default() -> Self {
        NmOptions { max_evals: 400, init_step: 0.4, f_tol: 1e-8 }
    }
}

/// Derivative-free Nelder–Mead minimization of `f` from `x0`.
/// Returns (argmin, min). `f` may return `None` ⇒ treated as +∞.
///
/// `f` is `FnMut` so objectives can carry reusable workspaces (the
/// empirical-Bayes NLL threads a [`GramScratch`] through every evaluation).
pub fn nelder_mead<F>(mut f: F, x0: &[f64], opts: &NmOptions) -> (Vec<f64>, f64)
where
    F: FnMut(&[f64]) -> Option<f64>,
{
    let n = x0.len();
    let mut eval = |x: &[f64]| f(x).unwrap_or(f64::INFINITY);
    // initial simplex: x0 plus per-coordinate steps
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    let f0 = eval(x0);
    simplex.push((x0.to_vec(), f0));
    for i in 0..n {
        let mut xi = x0.to_vec();
        xi[i] += opts.init_step;
        let fx = eval(&xi);
        simplex.push((xi, fx));
    }
    let mut evals = n + 1;

    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);
    while evals < opts.max_evals {
        simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let spread = simplex[n].1 - simplex[0].1;
        if spread.abs() < opts.f_tol {
            break;
        }
        // centroid of all but worst
        let mut c = vec![0.0; n];
        for (x, _) in &simplex[..n] {
            for (ci, xi) in c.iter_mut().zip(x) {
                *ci += xi / n as f64;
            }
        }
        let worst = simplex[n].clone();
        let refl: Vec<f64> =
            c.iter().zip(&worst.0).map(|(ci, wi)| ci + alpha * (ci - wi)).collect();
        let f_refl = eval(&refl);
        evals += 1;

        if f_refl < simplex[0].1 {
            // expansion
            let exp: Vec<f64> =
                c.iter().zip(&refl).map(|(ci, ri)| ci + gamma * (ri - ci)).collect();
            let f_exp = eval(&exp);
            evals += 1;
            simplex[n] = if f_exp < f_refl { (exp, f_exp) } else { (refl, f_refl) };
        } else if f_refl < simplex[n - 1].1 {
            simplex[n] = (refl, f_refl);
        } else {
            // contraction
            let con: Vec<f64> =
                c.iter().zip(&worst.0).map(|(ci, wi)| ci + rho * (wi - ci)).collect();
            let f_con = eval(&con);
            evals += 1;
            if f_con < worst.1 {
                simplex[n] = (con, f_con);
            } else {
                // shrink towards best
                let best = simplex[0].0.clone();
                for (x, fx) in simplex.iter_mut().skip(1) {
                    for (xi, bi) in x.iter_mut().zip(&best) {
                        *xi = bi + sigma * (*xi - bi);
                    }
                    *fx = eval(x);
                    evals += 1;
                }
            }
        }
    }
    simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    simplex.swap_remove(0)
}

/// Empirical-Bayes fit: multi-start Nelder–Mead on −(log marginal
/// likelihood + log prior), clamped to the stability box. Returns the best
/// theta found (always at least the default).
///
/// Restarts after the first are seeded *uniformly within*
/// [`Theta::bounds`] — wide dimensions get the same relative coverage as
/// narrow ones (the old seeding sampled midpoint ± 1.0 regardless of
/// bound width, so most of a wide box was never explored).
pub fn fit_empirical_bayes(
    backend: &dyn SurrogateBackend,
    x: &Dataset,
    y: &[f64],
    d: usize,
    restarts: usize,
    rng: &mut Rng,
) -> Theta {
    let mut scratch = GramScratch::new();
    let mut theta_buf = Theta::default_for_dim(d);
    let mut clamped = vec![0.0; Theta::packed_len(d)];
    let mut objective = |packed: &[f64]| -> Option<f64> {
        clamped.copy_from_slice(packed);
        Theta::clamp_packed(&mut clamped, d);
        theta_buf.unpack_into(&clamped, d);
        nll_scratch(backend, x, y, &theta_buf, &mut scratch)
            .map(|v| v - theta_buf.log_prior())
    };

    let mut best_x = Theta::default_for_dim(d).pack();
    let mut best_f = objective(&best_x).unwrap_or(f64::INFINITY);

    let bounds = Theta::bounds(d);
    for r in 0..restarts.max(1) {
        let start: Vec<f64> = if r == 0 {
            Theta::default_for_dim(d).pack()
        } else {
            bounds.iter().map(|(lo, hi)| rng.uniform_range(*lo, *hi)).collect()
        };
        let (xr, fr) = nelder_mead(&mut objective, &start, &NmOptions::default());
        if fr < best_f {
            best_f = fr;
            best_x = xr;
        }
    }
    Theta::clamp_packed(&mut best_x, d);
    Theta::unpack(&best_x, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{normalization, NativeBackend};

    #[test]
    fn nelder_mead_minimizes_quadratic() {
        let f = |x: &[f64]| Some((x[0] - 2.0).powi(2) + (x[1] + 1.0).powi(2) + 3.0);
        let (x, fx) = nelder_mead(f, &[0.0, 0.0], &NmOptions::default());
        assert!((x[0] - 2.0).abs() < 1e-3, "{x:?}");
        assert!((x[1] + 1.0).abs() < 1e-3, "{x:?}");
        assert!((fx - 3.0).abs() < 1e-5);
    }

    #[test]
    fn nelder_mead_handles_infeasible_regions() {
        // f undefined left of 1.0
        let f = |x: &[f64]| (x[0] > 1.0).then(|| (x[0] - 3.0).powi(2));
        let (x, _) = nelder_mead(f, &[4.0], &NmOptions::default());
        assert!((x[0] - 3.0).abs() < 1e-2);
    }

    #[test]
    fn nelder_mead_accepts_stateful_objectives() {
        // FnMut: objectives may mutate captured workspaces between calls
        let mut calls = 0usize;
        let f = |x: &[f64]| {
            calls += 1;
            Some(x[0] * x[0])
        };
        let (x, _) = nelder_mead(f, &[2.0], &NmOptions::default());
        assert!(x[0].abs() < 1e-2);
        assert!(calls > 2);
    }

    #[test]
    fn rosenbrock_2d_reasonable() {
        let f =
            |x: &[f64]| Some((1.0 - x[0]).powi(2) + 100.0 * (x[1] - x[0] * x[0]).powi(2));
        let (x, fx) =
            nelder_mead(f, &[-1.0, 1.0], &NmOptions { max_evals: 2000, ..Default::default() });
        assert!(fx < 1e-3, "fx={fx} at {x:?}");
    }

    #[test]
    fn eb_fit_improves_over_default() {
        let mut rng = Rng::new(1);
        let mut x = Dataset::new(2);
        for _ in 0..25 {
            x.push_row(&[rng.uniform(), rng.uniform()]);
        }
        let y_raw: Vec<f64> =
            x.rows().map(|p| (5.0 * p[0]).sin() * 2.0 + 0.01 * rng.normal()).collect();
        let (m, s) = normalization(&y_raw);
        let y: Vec<f64> = y_raw.iter().map(|v| (v - m) / s).collect();

        let fitted = fit_empirical_bayes(&NativeBackend, &x, &y, 2, 2, &mut rng);
        let default = Theta::default_for_dim(2);
        let nll_fit = crate::gp::nll(&NativeBackend, &x, &y, &fitted).unwrap();
        let nll_def = crate::gp::nll(&NativeBackend, &x, &y, &default).unwrap();
        assert!(
            nll_fit <= nll_def + 1e-9,
            "fitted {nll_fit} should beat default {nll_def}"
        );
    }

    #[test]
    fn eb_fit_stays_in_bounds() {
        let mut rng = Rng::new(2);
        let mut x = Dataset::new(1);
        for _ in 0..8 {
            x.push_row(&[rng.uniform()]);
        }
        let y: Vec<f64> = x.rows().map(|p| p[0]).collect();
        let t = fit_empirical_bayes(&NativeBackend, &x, &y, 1, 1, &mut rng);
        for (v, (lo, hi)) in t.pack().iter().zip(Theta::bounds(1)) {
            assert!(*v >= lo - 1e-9 && *v <= hi + 1e-9);
        }
    }

    #[test]
    fn eb_restart_seeds_cover_the_full_box() {
        // regression for the midpoint ± 1.0 seeding bug: with many
        // restarts, seeds must land outside the old ±1 band around the
        // midpoint for the wide amplitude dimension (width ~13.8 in log
        // space). We reproduce the seeding draw exactly as the fitter
        // makes it and check its spread.
        let bounds = Theta::bounds(1);
        let (lo, hi) = bounds[0]; // log amp: ln(1e-3)..ln(1e3)
        let mid = 0.5 * (lo + hi);
        let mut rng = Rng::new(3);
        let mut outside_old_band = 0;
        for _ in 0..200 {
            let draw: Vec<f64> =
                bounds.iter().map(|(lo, hi)| rng.uniform_range(*lo, *hi)).collect();
            assert!(draw[0] >= lo && draw[0] <= hi);
            if (draw[0] - mid).abs() > 1.0 {
                outside_old_band += 1;
            }
        }
        assert!(
            outside_old_band > 100,
            "restart seeding still hugs the midpoint: {outside_old_band}/200"
        );
    }
}
