//! Native (pure-Rust, f64) Matérn-5/2 ARD kernel with Kumaraswamy input
//! warping — the same math as the L1 Pallas kernel, used as the
//! cross-check oracle for the HLO artifacts and as the fallback surrogate
//! when artifacts are unavailable (e.g. encoded dimension > the compiled
//! D).
//!
//! All entry points operate on the contiguous row-major [`Dataset`]
//! layout; the hot path ([`gram_into`]) streams warped points through a
//! caller-owned [`GramScratch`] so repeated likelihood queries allocate
//! nothing (DESIGN.md §3).

use super::dataset::{Dataset, GramScratch};
use super::theta::Theta;
use crate::linalg::Matrix;

/// Numerical guards, identical to `python/compile/kernels/matern.py`.
pub const EPS: f64 = 1e-6;
/// Diagonal jitter added to Gram matrices (matches `model.JITTER`).
pub const JITTER: f64 = 1e-6;
const SQRT5: f64 = 2.236067977499789696;

/// Kumaraswamy CDF w(x) = 1 − (1 − xᵃ)ᵇ on [0, 1], clipped like the kernel.
pub fn kumaraswamy(x: f64, a: f64, b: f64) -> f64 {
    let xc = x.clamp(EPS, 1.0 - EPS);
    1.0 - (1.0 - xc.powf(a)).powf(b)
}

/// Matérn-5/2 value from squared scaled distance.
pub fn matern52(r2: f64, amp: f64) -> f64 {
    let r = r2.max(0.0).sqrt();
    amp * (1.0 + SQRT5 * r + (5.0 / 3.0) * r2) * (-SQRT5 * r).exp()
}

/// Fill the per-dimension warp/scale parameters of `theta` into flat
/// buffers (no allocation; buffers must have length d).
fn theta_params_into(theta: &Theta, wa: &mut [f64], wb: &mut [f64], inv_ls: &mut [f64]) {
    for j in 0..wa.len() {
        wa[j] = theta.log_wa[j].exp();
        wb[j] = theta.log_wb[j].exp();
        inv_ls[j] = 1.0 / theta.log_ls[j].exp();
    }
}

/// Warp and inverse-lengthscale-scale `x` (n × d row-major) into `out`.
fn warp_scale_into(x: &[f64], d: usize, wa: &[f64], wb: &[f64], inv_ls: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), out.len());
    for (src, dst) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        for j in 0..d {
            dst[j] = kumaraswamy(src[j], wa[j], wb[j]) * inv_ls[j];
        }
    }
}

/// Squared Euclidean distance between two scaled points.
#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(u, v)| (u - v) * (u - v)).sum()
}

/// Pairwise cross covariance K[i][j] = k(xa_i, xb_j).
pub fn cross(xa: &Dataset, xb: &Dataset, theta: &Theta) -> Matrix {
    let d = theta.dim();
    debug_assert_eq!(xa.dim(), d);
    debug_assert_eq!(xb.dim(), d);
    let amp = theta.amp();
    let mut wa = vec![0.0; d];
    let mut wb = vec![0.0; d];
    let mut inv_ls = vec![0.0; d];
    theta_params_into(theta, &mut wa, &mut wb, &mut inv_ls);
    let mut a_scaled = vec![0.0; xa.len() * d];
    let mut b_scaled = vec![0.0; xb.len() * d];
    warp_scale_into(xa.flat(), d, &wa, &wb, &inv_ls, &mut a_scaled);
    warp_scale_into(xb.flat(), d, &wa, &wb, &inv_ls, &mut b_scaled);
    let mut k = Matrix::zeros(xa.len(), xb.len());
    for (i, ai) in a_scaled.chunks_exact(d).enumerate() {
        let out_row = &mut k.data[i * xb.len()..(i + 1) * xb.len()];
        for (o, bj) in out_row.iter_mut().zip(b_scaled.chunks_exact(d)) {
            *o = matern52(dist2(ai, bj), amp);
        }
    }
    k
}

/// One kernel column k(x_row, xb) without building a one-row dataset —
/// used by the rank-1 Cholesky append path.
pub fn cross_row(x_row: &[f64], xb: &Dataset, theta: &Theta) -> Vec<f64> {
    let d = theta.dim();
    debug_assert_eq!(x_row.len(), d);
    let amp = theta.amp();
    let mut wa = vec![0.0; d];
    let mut wb = vec![0.0; d];
    let mut inv_ls = vec![0.0; d];
    theta_params_into(theta, &mut wa, &mut wb, &mut inv_ls);
    let mut a = vec![0.0; d];
    warp_scale_into(x_row, d, &wa, &wb, &inv_ls, &mut a);
    let mut b_scaled = vec![0.0; xb.len() * d];
    warp_scale_into(xb.flat(), d, &wa, &wb, &inv_ls, &mut b_scaled);
    b_scaled
        .chunks_exact(d)
        .map(|bj| matern52(dist2(&a, bj), amp))
        .collect()
}

/// Regularized Gram matrix K(X, X) + (noise + jitter) I (allocating form).
pub fn gram(x: &Dataset, theta: &Theta) -> Matrix {
    let mut scratch = GramScratch::new();
    gram_into(x, theta, &mut scratch);
    scratch.k
}

/// Regularized Gram matrix into a reusable workspace: `scratch.k` holds
/// K(X, X) + (noise + jitter) I on return, and no heap allocation happens
/// once the scratch has warmed up at this (n, d).
///
/// Perf (§Perf iteration 6): computes only the upper triangle and mirrors —
/// the Matérn `exp` calls dominate this kernel, and symmetry halves them.
/// This is the innermost cost of every slice-sampling likelihood query
/// (~600 Gram+Cholesky evaluations per BO proposal at the paper's MCMC
/// settings), so the 2× here is a direct ~1.5× on GP fitting.
pub fn gram_into(x: &Dataset, theta: &Theta, scratch: &mut GramScratch) {
    let n = x.len();
    let d = x.dim();
    debug_assert_eq!(theta.dim(), d);
    scratch.ensure(n, d);
    let GramScratch { scaled, wa, wb, inv_ls, k, .. } = scratch;
    theta_params_into(theta, wa, wb, inv_ls);
    warp_scale_into(x.flat(), d, wa, wb, inv_ls, scaled);
    let amp = theta.amp();
    let reg = theta.noise() + JITTER;
    for i in 0..n {
        k.data[i * n + i] = amp + reg;
        let si = &scaled[i * d..(i + 1) * d];
        for j in 0..i {
            let v = matern52(dist2(si, &scaled[j * d..(j + 1) * d]), amp);
            k.data[i * n + j] = v;
            k.data[j * n + i] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;
    use crate::rng::Rng;

    fn rand_x(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        Dataset::from_fn(n, d, |_, _| rng.uniform())
    }

    #[test]
    fn gram_diag_is_amp_plus_reg() {
        let theta = Theta::default_for_dim(3);
        let x = rand_x(10, 3, 1);
        let k = gram(&x, &theta);
        for i in 0..10 {
            let want = theta.amp() + theta.noise() + JITTER;
            assert!((k[(i, i)] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_is_pd() {
        let theta = Theta::default_for_dim(4);
        let x = rand_x(40, 4, 2);
        assert!(cholesky(&gram(&x, &theta)).is_ok());
    }

    #[test]
    fn gram_into_reuses_scratch_without_allocating() {
        let theta = Theta::default_for_dim(5);
        let x = rand_x(30, 5, 9);
        let mut scratch = GramScratch::new();
        gram_into(&x, &theta, &mut scratch);
        let warmup = scratch.reallocs();
        let first = scratch.k.clone();
        for _ in 0..50 {
            gram_into(&x, &theta, &mut scratch);
        }
        assert_eq!(scratch.reallocs(), warmup, "warm gram_into must not allocate");
        assert_eq!(scratch.k, first, "repeated evaluation must be bit-identical");
        assert_eq!(first, gram(&x, &theta));
    }

    #[test]
    fn kernel_decays_monotonically() {
        let theta = Theta::default_for_dim(1);
        let a = Dataset::from_row(&[0.1]);
        let pts = Dataset::from_rows(&[vec![0.1], vec![0.3], vec![0.6], vec![0.95]]);
        let k = cross(&a, &pts, &theta);
        assert!(k[(0, 0)] > k[(0, 1)]);
        assert!(k[(0, 1)] > k[(0, 2)]);
        assert!(k[(0, 2)] > k[(0, 3)]);
    }

    #[test]
    fn warping_changes_geometry() {
        let mut theta = Theta::default_for_dim(1);
        let a = Dataset::from_row(&[0.05]);
        let b = Dataset::from_row(&[0.15]);
        let plain = cross(&a, &b, &theta)[(0, 0)];
        theta.log_wa = vec![(3.0f64).ln()];
        theta.log_wb = vec![(0.5f64).ln()];
        let warped = cross(&a, &b, &theta)[(0, 0)];
        assert!((plain - warped).abs() > 1e-4);
    }

    #[test]
    fn identity_warp_matches_unwarped_distance() {
        // a = b = 1 ⇒ w(x) = x (within clipping) ⇒ same as plain matern
        let theta = Theta::default_for_dim(2);
        let xa = rand_x(5, 2, 3);
        let xb = rand_x(6, 2, 4);
        let k = cross(&xa, &xb, &theta);
        let ils: Vec<f64> = theta.lengthscales().iter().map(|l| 1.0 / l).collect();
        for i in 0..5 {
            for j in 0..6 {
                let r2: f64 = xa
                    .row(i)
                    .iter()
                    .zip(xb.row(j))
                    .zip(&ils)
                    .map(|((u, v), il)| ((u - v) * il).powi(2))
                    .sum();
                assert!((k[(i, j)] - matern52(r2, theta.amp())).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn cross_row_matches_cross() {
        let theta = Theta::default_for_dim(3);
        let xa = rand_x(1, 3, 11);
        let xb = rand_x(7, 3, 12);
        let full = cross(&xa, &xb, &theta);
        let row = cross_row(xa.row(0), &xb, &theta);
        assert_eq!(full.row(0), &row[..]);
    }
}
