//! Native (pure-Rust, f64) Matérn-5/2 ARD kernel with Kumaraswamy input
//! warping — the same math as the L1 Pallas kernel, used as the
//! cross-check oracle for the HLO artifacts and as the fallback surrogate
//! when artifacts are unavailable (e.g. encoded dimension > the compiled
//! D).

use super::theta::Theta;
use crate::linalg::Matrix;

/// Numerical guards, identical to `python/compile/kernels/matern.py`.
pub const EPS: f64 = 1e-6;
/// Diagonal jitter added to Gram matrices (matches `model.JITTER`).
pub const JITTER: f64 = 1e-6;
const SQRT5: f64 = 2.236067977499789696;

/// Kumaraswamy CDF w(x) = 1 − (1 − xᵃ)ᵇ on [0, 1], clipped like the kernel.
pub fn kumaraswamy(x: f64, a: f64, b: f64) -> f64 {
    let xc = x.clamp(EPS, 1.0 - EPS);
    1.0 - (1.0 - xc.powf(a)).powf(b)
}

/// Matérn-5/2 value from squared scaled distance.
pub fn matern52(r2: f64, amp: f64) -> f64 {
    let r = r2.max(0.0).sqrt();
    amp * (1.0 + SQRT5 * r + (5.0 / 3.0) * r2) * (-SQRT5 * r).exp()
}

/// Warp and inverse-lengthscale-scale one encoded point.
fn warp_scale(x: &[f64], wa: &[f64], wb: &[f64], inv_ls: &[f64]) -> Vec<f64> {
    x.iter()
        .zip(wa)
        .zip(wb)
        .zip(inv_ls)
        .map(|(((&x, &a), &b), &il)| kumaraswamy(x, a, b) * il)
        .collect()
}

/// Pairwise cross covariance K[i][j] = k(xa_i, xb_j).
pub fn cross(xa: &[Vec<f64>], xb: &[Vec<f64>], theta: &Theta) -> Matrix {
    let amp = theta.amp();
    let wa = theta.warp_a();
    let wb = theta.warp_b();
    let inv_ls: Vec<f64> = theta.lengthscales().iter().map(|l| 1.0 / l).collect();
    let a_scaled: Vec<Vec<f64>> =
        xa.iter().map(|x| warp_scale(x, &wa, &wb, &inv_ls)).collect();
    let b_scaled: Vec<Vec<f64>> =
        xb.iter().map(|x| warp_scale(x, &wa, &wb, &inv_ls)).collect();
    let mut k = Matrix::zeros(xa.len(), xb.len());
    for (i, ai) in a_scaled.iter().enumerate() {
        for (j, bj) in b_scaled.iter().enumerate() {
            let r2: f64 = ai.iter().zip(bj).map(|(u, v)| (u - v) * (u - v)).sum();
            k[(i, j)] = matern52(r2, amp);
        }
    }
    k
}

/// Regularized Gram matrix K(X, X) + (noise + jitter) I.
///
/// Perf (§Perf iteration 6): computes only the upper triangle and mirrors —
/// the Matérn `exp` calls dominate this kernel, and symmetry halves them.
/// This is the innermost cost of every slice-sampling likelihood query
/// (~600 Gram+Cholesky evaluations per BO proposal at the paper's MCMC
/// settings), so the 2× here is a direct ~1.5× on GP fitting.
pub fn gram(x: &[Vec<f64>], theta: &Theta) -> Matrix {
    let n = x.len();
    let amp = theta.amp();
    let wa = theta.warp_a();
    let wb = theta.warp_b();
    let inv_ls: Vec<f64> = theta.lengthscales().iter().map(|l| 1.0 / l).collect();
    let scaled: Vec<Vec<f64>> =
        x.iter().map(|p| warp_scale(p, &wa, &wb, &inv_ls)).collect();
    let reg = theta.noise() + JITTER;
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        k[(i, i)] = amp + reg;
        let si = &scaled[i];
        for j in 0..i {
            let r2: f64 =
                si.iter().zip(&scaled[j]).map(|(u, v)| (u - v) * (u - v)).sum();
            let v = matern52(r2, amp);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;
    use crate::rng::Rng;

    fn rand_x(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect()
    }

    #[test]
    fn gram_diag_is_amp_plus_reg() {
        let theta = Theta::default_for_dim(3);
        let x = rand_x(10, 3, 1);
        let k = gram(&x, &theta);
        for i in 0..10 {
            let want = theta.amp() + theta.noise() + JITTER;
            assert!((k[(i, i)] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_is_pd() {
        let theta = Theta::default_for_dim(4);
        let x = rand_x(40, 4, 2);
        assert!(cholesky(&gram(&x, &theta)).is_ok());
    }

    #[test]
    fn kernel_decays_monotonically() {
        let theta = Theta::default_for_dim(1);
        let a = vec![vec![0.1]];
        let pts: Vec<Vec<f64>> = vec![vec![0.1], vec![0.3], vec![0.6], vec![0.95]];
        let k = cross(&a, &pts, &theta);
        assert!(k[(0, 0)] > k[(0, 1)]);
        assert!(k[(0, 1)] > k[(0, 2)]);
        assert!(k[(0, 2)] > k[(0, 3)]);
    }

    #[test]
    fn warping_changes_geometry() {
        let mut theta = Theta::default_for_dim(1);
        let a = vec![vec![0.05]];
        let b = vec![vec![0.15]];
        let plain = cross(&a, &b, &theta)[(0, 0)];
        theta.log_wa = vec![(3.0f64).ln()];
        theta.log_wb = vec![(0.5f64).ln()];
        let warped = cross(&a, &b, &theta)[(0, 0)];
        assert!((plain - warped).abs() > 1e-4);
    }

    #[test]
    fn identity_warp_matches_unwarped_distance() {
        // a = b = 1 ⇒ w(x) = x (within clipping) ⇒ same as plain matern
        let theta = Theta::default_for_dim(2);
        let xa = rand_x(5, 2, 3);
        let xb = rand_x(6, 2, 4);
        let k = cross(&xa, &xb, &theta);
        let ils: Vec<f64> = theta.lengthscales().iter().map(|l| 1.0 / l).collect();
        for i in 0..5 {
            for j in 0..6 {
                let r2: f64 = xa[i]
                    .iter()
                    .zip(&xb[j])
                    .zip(&ils)
                    .map(|((u, v), il)| ((u - v) * il).powi(2))
                    .sum();
                assert!((k[(i, j)] - matern52(r2, theta.amp())).abs() < 1e-9);
            }
        }
    }
}
