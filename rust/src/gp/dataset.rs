//! Contiguous training-set storage and the reusable Gram workspace.
//!
//! The BO hot path (slice-sampling likelihood queries, anchor scoring)
//! used to thread `&[Vec<f64>]` through every layer: one heap allocation
//! per row, pointer-chasing on every kernel evaluation, and fresh
//! warp/scale buffers on each of the ~600 likelihood queries per proposal.
//! [`Dataset`] replaces that with a single row-major `Vec<f64>` (n × d),
//! so kernels stream over contiguous memory and the PJRT backend can pad
//! straight out of the flat buffer (DESIGN.md §2).
//!
//! [`GramScratch`] is the companion workspace: warp parameters, scaled
//! points, the Gram/Cholesky matrix and a triangular-solve vector, all
//! reused across likelihood evaluations so the slice sampler's inner loop
//! performs zero heap allocations after warm-up (DESIGN.md §3).

use crate::linalg::Matrix;

/// Row-major, contiguous set of encoded configurations (n rows × d dims).
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    n: usize,
    d: usize,
    data: Vec<f64>,
}

impl Dataset {
    /// Empty dataset over a `d`-dimensional encoded space.
    pub fn new(d: usize) -> Dataset {
        Dataset { n: 0, d, data: Vec::new() }
    }

    /// Empty dataset with room for `rows` rows.
    pub fn with_capacity(d: usize, rows: usize) -> Dataset {
        Dataset { n: 0, d, data: Vec::with_capacity(rows * d) }
    }

    /// Build from per-row slices (all rows must share one length).
    pub fn from_rows(rows: &[Vec<f64>]) -> Dataset {
        let d = rows.first().map(Vec::len).unwrap_or(0);
        let mut ds = Dataset::with_capacity(d, rows.len());
        for r in rows {
            ds.push_row(r);
        }
        ds
    }

    /// Single-row dataset (posterior queries at one candidate).
    pub fn from_row(row: &[f64]) -> Dataset {
        Dataset { n: 1, d: row.len(), data: row.to_vec() }
    }

    /// Build an n × d dataset by evaluating `f(row, col)` in row-major
    /// order (the order matters for seeded RNG fills).
    pub fn from_fn(n: usize, d: usize, mut f: impl FnMut(usize, usize) -> f64) -> Dataset {
        let mut data = Vec::with_capacity(n * d);
        for i in 0..n {
            for j in 0..d {
                data.push(f(i, j));
            }
        }
        Dataset { n, d, data }
    }

    /// Build from an already-flat row-major buffer.
    pub fn from_flat(n: usize, d: usize, data: Vec<f64>) -> Dataset {
        assert_eq!(data.len(), n * d, "flat buffer length mismatch");
        Dataset { n, d, data }
    }

    /// Append one encoded row.
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.d, "row dimension mismatch");
        self.data.extend_from_slice(row);
        self.n += 1;
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the dataset holds no rows.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Encoded dimensionality d.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// One row as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Iterate over rows.
    pub fn rows(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks_exact(self.d.max(1))
    }

    /// The whole row-major buffer (ships to PJRT without re-marshalling).
    pub fn flat(&self) -> &[f64] {
        &self.data
    }

    /// Copy of the rows in `range` as an owned dataset (anchor blocks).
    pub fn slice(&self, range: std::ops::Range<usize>) -> Dataset {
        Dataset {
            n: range.len(),
            d: self.d,
            data: self.data[range.start * self.d..range.end * self.d].to_vec(),
        }
    }

    /// Split into owned blocks of at most `block` rows (last may be short).
    pub fn blocks(&self, block: usize) -> Vec<Dataset> {
        assert!(block > 0);
        (0..self.n)
            .step_by(block)
            .map(|s| self.slice(s..(s + block).min(self.n)))
            .collect()
    }
}

/// Reusable workspace for Gram construction and likelihood evaluation.
///
/// All buffers grow monotonically and are reused across calls; after the
/// first evaluation at a given (n, d) no further heap allocation happens
/// (asserted by the scratch-reuse tests via [`GramScratch::reallocs`]).
#[derive(Debug, Default)]
pub struct GramScratch {
    /// Warped + inverse-lengthscale-scaled points (n × d, row-major).
    pub(crate) scaled: Vec<f64>,
    /// Per-dimension Kumaraswamy `a` parameters.
    pub(crate) wa: Vec<f64>,
    /// Per-dimension Kumaraswamy `b` parameters.
    pub(crate) wb: Vec<f64>,
    /// Per-dimension inverse lengthscales.
    pub(crate) inv_ls: Vec<f64>,
    /// Gram matrix; the NLL path factorizes it in place (k becomes L).
    pub k: Matrix,
    /// Triangular-solve workspace (length n).
    pub v: Vec<f64>,
    /// How many times any buffer had to grow (should stabilize after the
    /// first call at a given size — the zero-alloc invariant).
    reallocs: u64,
}

impl GramScratch {
    /// Fresh, empty workspace.
    pub fn new() -> GramScratch {
        GramScratch::default()
    }

    /// Size all buffers for an (n, d) problem, reusing capacity.
    pub(crate) fn ensure(&mut self, n: usize, d: usize) {
        let caps = (
            self.scaled.capacity(),
            self.wa.capacity(),
            self.k.data.capacity(),
            self.v.capacity(),
        );
        self.scaled.resize(n * d, 0.0);
        self.wa.resize(d, 0.0);
        self.wb.resize(d, 0.0);
        self.inv_ls.resize(d, 0.0);
        self.k.data.resize(n * n, 0.0);
        self.k.rows = n;
        self.k.cols = n;
        self.v.resize(n, 0.0);
        let grown = caps
            != (
                self.scaled.capacity(),
                self.wa.capacity(),
                self.k.data.capacity(),
                self.v.capacity(),
            );
        if grown {
            self.reallocs += 1;
        }
    }

    /// Allocation-growth counter (see struct docs).
    pub fn reallocs(&self) -> u64 {
        self.reallocs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_roundtrips_rows() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let ds = Dataset::from_rows(&rows);
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        assert_eq!(ds.flat(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let back: Vec<Vec<f64>> = ds.rows().map(|r| r.to_vec()).collect();
        assert_eq!(back, rows);
    }

    #[test]
    fn dataset_push_and_slice() {
        let mut ds = Dataset::new(3);
        assert!(ds.is_empty());
        for i in 0..5 {
            ds.push_row(&[i as f64, 0.0, 1.0]);
        }
        let mid = ds.slice(1..3);
        assert_eq!(mid.len(), 2);
        assert_eq!(mid.row(0), ds.row(1));
        assert_eq!(mid.row(1), ds.row(2));
    }

    #[test]
    fn dataset_blocks_cover_everything_in_order() {
        let mut ds = Dataset::new(1);
        for i in 0..10 {
            ds.push_row(&[i as f64]);
        }
        let blocks = ds.blocks(4);
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks.iter().map(Dataset::len).sum::<usize>(), 10);
        let rejoined: Vec<f64> =
            blocks.iter().flat_map(|b| b.flat().iter().copied()).collect();
        assert_eq!(rejoined, ds.flat());
    }

    #[test]
    fn scratch_reuse_stops_allocating() {
        let mut s = GramScratch::new();
        s.ensure(20, 4);
        let after_first = s.reallocs();
        assert!(after_first >= 1);
        for _ in 0..100 {
            s.ensure(20, 4);
        }
        assert_eq!(s.reallocs(), after_first, "steady-state ensure() must not allocate");
        // shrinking reuses capacity too
        s.ensure(10, 4);
        assert_eq!(s.reallocs(), after_first);
    }

    #[test]
    fn from_fn_fills_row_major() {
        let ds = Dataset::from_fn(3, 2, |i, j| (i * 10 + j) as f64);
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.flat(), &[0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
    }

    #[test]
    fn from_flat_matches_from_rows() {
        let rows = vec![vec![0.5, 0.25], vec![0.75, 1.0]];
        let a = Dataset::from_rows(&rows);
        let b = Dataset::from_flat(2, 2, vec![0.5, 0.25, 0.75, 1.0]);
        assert_eq!(a, b);
    }
}
