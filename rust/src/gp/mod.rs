//! Gaussian-process surrogate model (§4.2): zero-mean GP over encoded
//! configurations with a warped Matérn-5/2 ARD kernel, Gaussian observation
//! noise, and GPHPs treated either by empirical Bayes ([`fit`]) or slice
//! sampling ([`slice`]).
//!
//! The O(N³) factorization work happens here in Rust ([`crate::linalg`]);
//! the O(N²) kernel construction and O(M·N²) acquisition scoring are
//! delegated to a [`SurrogateBackend`] — either [`NativeBackend`] (pure
//! Rust, any dimension) or the PJRT-executed AOT artifacts
//! ([`crate::runtime::HloBackend`]), which run the L1 Pallas kernel.
//!
//! Training inputs flow through the whole stack as a contiguous row-major
//! [`Dataset`]; likelihood queries reuse a [`GramScratch`] workspace; and
//! both GPHP fitting and posterior-sample scoring fan out over
//! [`crate::parallel`] with order-stable reduction, so results are
//! bit-identical to the sequential path (DESIGN.md §2–§5).

pub mod dataset;
pub mod fit;
pub mod kernel;
pub mod slice;
pub mod theta;

pub use dataset::{Dataset, GramScratch};
pub use theta::Theta;

use crate::linalg::{
    cho_inverse, cho_logdet, cho_solve, cholesky, cholesky_in_place, dot, solve_lower_in_place,
    Matrix,
};
use crate::parallel;

/// Below this many training rows, per-theta fitting stays sequential
/// (thread spawn would cost more than the factorization).
const PAR_MIN_FIT_N: usize = 64;
/// Below this many candidates, scoring stays sequential (the local EI
/// refinement scores one point per call).
const PAR_MIN_SCORE_M: usize = 32;

/// Acquisition-relevant posterior summary at one candidate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Score {
    /// Expected improvement (minimization, normalized-y units).
    pub ei: f64,
    /// Posterior mean.
    pub mu: f64,
    /// Posterior variance of the latent function.
    pub var: f64,
}

/// Fitted per-theta posterior state: everything the acquisition graphs need.
#[derive(Clone, Debug)]
pub struct PosteriorState {
    /// Encoded training inputs (live rows only, contiguous row-major).
    pub x: Dataset,
    /// GP hyperparameters of this sample.
    pub theta: Theta,
    /// Cholesky factor of the regularized Gram matrix.
    pub l: Matrix,
    /// K⁻¹ (used by the blocked native scorer and shipped to the AOT
    /// posterior/EI graph).
    pub k_inv: Matrix,
    /// K⁻¹ y (normalized targets).
    pub alpha: Vec<f64>,
}

/// Kernel/acquisition compute backend.
pub trait SurrogateBackend: Send + Sync {
    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str;
    /// Regularized Gram matrix K(X, X) + (noise + jitter) I.
    fn gram(&self, x: &Dataset, theta: &Theta) -> Matrix;
    /// Gram matrix into a reusable workspace (`scratch.k`). The native
    /// backend overrides this with a zero-allocation fill; the default
    /// delegates to [`SurrogateBackend::gram`].
    fn gram_into(&self, x: &Dataset, theta: &Theta, scratch: &mut GramScratch) {
        scratch.k = self.gram(x, theta);
    }
    /// (EI, mu, var) at each candidate given a fitted posterior and the
    /// incumbent `y_best` (normalized units, minimization).
    fn posterior_scores(
        &self,
        post: &PosteriorState,
        x_cand: &Dataset,
        y_best: f64,
    ) -> Vec<Score>;
}

/// Pure-Rust backend (f64; reference implementation).
pub struct NativeBackend;

impl SurrogateBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn gram(&self, x: &Dataset, theta: &Theta) -> Matrix {
        kernel::gram(x, theta)
    }

    fn gram_into(&self, x: &Dataset, theta: &Theta, scratch: &mut GramScratch) {
        kernel::gram_into(x, theta, scratch);
    }

    /// Blocked scorer: one Kx cross-covariance build, one blocked
    /// Kx · K⁻¹ matmul (ikj order, streaming K⁻¹ rows), then a contiguous
    /// per-candidate dot for the quadratic form — instead of the old
    /// per-candidate loop that gathered K⁻¹ rows M times with strided
    /// access (DESIGN.md §4).
    fn posterior_scores(
        &self,
        post: &PosteriorState,
        x_cand: &Dataset,
        y_best: f64,
    ) -> Vec<Score> {
        let kx = kernel::cross(x_cand, &post.x, &post.theta);
        let q = kx.matmul(&post.k_inv);
        let amp = post.theta.amp();
        let mut out = Vec::with_capacity(x_cand.len());
        for i in 0..x_cand.len() {
            let row = kx.row(i);
            let mu = dot(row, &post.alpha);
            // var = amp − rowᵀ K⁻¹ row (same formula the HLO graph uses)
            let quad = dot(q.row(i), row);
            let var = (amp - quad).max(1e-12);
            out.push(Score { ei: expected_improvement(mu, var, y_best), mu, var });
        }
        out
    }
}

/// Closed-form expected improvement for minimization.
pub fn expected_improvement(mu: f64, var: f64, y_best: f64) -> f64 {
    let sigma = var.max(1e-12).sqrt();
    let z = (y_best - mu) / sigma;
    sigma * (z * norm_cdf(z) + norm_pdf(z))
}

/// Standard normal pdf.
pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal cdf via erf (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7).
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Error function approximation (A&S 7.1.26).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Negative log marginal likelihood of normalized targets under `theta`
/// (allocating convenience wrapper over [`nll_scratch`]).
///
/// Returns `None` when the Gram matrix is numerically non-PD (the caller —
/// slice sampler or optimizer — treats that as an infinitely bad point).
pub fn nll(backend: &dyn SurrogateBackend, x: &Dataset, y: &[f64], theta: &Theta) -> Option<f64> {
    let mut scratch = GramScratch::new();
    nll_scratch(backend, x, y, theta, &mut scratch)
}

/// Negative log marginal likelihood through a reusable workspace: Gram
/// build, in-place Cholesky and forward solve all land in `scratch`, so a
/// warmed-up scratch makes this evaluation allocation-free — the slice
/// sampler calls it ~600 times per proposal.
pub fn nll_scratch(
    backend: &dyn SurrogateBackend,
    x: &Dataset,
    y: &[f64],
    theta: &Theta,
    scratch: &mut GramScratch,
) -> Option<f64> {
    backend.gram_into(x, theta, scratch);
    cholesky_in_place(&mut scratch.k).ok()?;
    scratch.v.resize(y.len(), 0.0);
    scratch.v.copy_from_slice(y);
    solve_lower_in_place(&scratch.k, &mut scratch.v);
    let quad: f64 = scratch.v.iter().map(|v| v * v).sum();
    let val = 0.5 * quad
        + 0.5 * cho_logdet(&scratch.k)
        + 0.5 * x.len() as f64 * (2.0 * std::f64::consts::PI).ln();
    val.is_finite().then_some(val)
}

/// A fitted GP surrogate: one posterior per GPHP sample, plus the target
/// normalization (observations are normalized to zero mean / unit variance,
/// §4.2 "observations y collected from f are normalized").
pub struct GpModel {
    /// One fitted posterior per theta (MCMC) or a single one (EB).
    pub posteriors: Vec<PosteriorState>,
    /// Normalization: y_norm = (y − mean) / std.
    pub y_mean: f64,
    /// Normalization scale.
    pub y_std: f64,
    /// Best (lowest) normalized observation — EI incumbent.
    pub y_best_norm: f64,
}

impl GpModel {
    /// Fit posteriors for a set of theta samples over raw observations.
    /// Thetas whose Gram matrix fails to factorize are dropped; returns
    /// `None` if none survive or the dataset is empty.
    ///
    /// Per-theta factorizations are independent, so they run through
    /// [`parallel::par_map`] when the dataset is large enough to pay for
    /// the threads; the surviving posteriors keep theta order either way.
    pub fn fit(
        backend: &dyn SurrogateBackend,
        x: &Dataset,
        y_raw: &[f64],
        thetas: Vec<Theta>,
    ) -> Option<GpModel> {
        if x.is_empty() || x.len() != y_raw.len() {
            return None;
        }
        let (y_mean, y_std) = normalization(y_raw);
        let y: Vec<f64> = y_raw.iter().map(|v| (v - y_mean) / y_std).collect();
        let y_best_norm = y.iter().cloned().fold(f64::INFINITY, f64::min);
        let fit_one = |theta: &Theta| -> Option<PosteriorState> {
            let k = backend.gram(x, theta);
            let l = cholesky(&k).ok()?;
            let alpha = cho_solve(&l, &y);
            let k_inv = cho_inverse(&l);
            Some(PosteriorState { x: x.clone(), theta: theta.clone(), l, k_inv, alpha })
        };
        let fitted: Vec<Option<PosteriorState>> =
            if thetas.len() > 1 && x.len() >= PAR_MIN_FIT_N && parallel::max_threads() > 1 {
                parallel::par_map(&thetas, fit_one)
            } else {
                thetas.iter().map(fit_one).collect()
            };
        let posteriors: Vec<PosteriorState> = fitted.into_iter().flatten().collect();
        (!posteriors.is_empty()).then_some(GpModel { posteriors, y_mean, y_std, y_best_norm })
    }

    /// Fit a single posterior from an already-computed Cholesky factor of
    /// the regularized Gram matrix (the rank-1 empirical-Bayes refit path:
    /// the factor was extended in O(N²) by
    /// [`crate::linalg::chol_append_row`], so no O(N³) refactorization
    /// happens here).
    ///
    /// The factor depends **only on X and theta, never on y** — `alpha` is
    /// recomputed from the passed observations on every call. The
    /// speculative proposal pipeline (DESIGN.md §17) leans on this: a
    /// factor extended by a constant-liar *fantasy* row stays exactly
    /// valid when the real outcome lands at the same configuration with a
    /// different value, so a committed speculation needs zero Cholesky
    /// recompute on the slice.
    pub fn fit_from_factor(
        x: &Dataset,
        y_raw: &[f64],
        theta: Theta,
        l: Matrix,
    ) -> Option<GpModel> {
        if x.is_empty() || x.len() != y_raw.len() || l.rows != x.len() {
            return None;
        }
        let (y_mean, y_std) = normalization(y_raw);
        let y: Vec<f64> = y_raw.iter().map(|v| (v - y_mean) / y_std).collect();
        let y_best_norm = y.iter().cloned().fold(f64::INFINITY, f64::min);
        let alpha = cho_solve(&l, &y);
        let k_inv = cho_inverse(&l);
        let posteriors = vec![PosteriorState { x: x.clone(), theta, l, k_inv, alpha }];
        Some(GpModel { posteriors, y_mean, y_std, y_best_norm })
    }

    /// Acquisition scores averaged over the GPHP posterior samples
    /// (normalized-y units). Fans out over posterior samples when the
    /// batch is large enough; reduction is in posterior order, so the
    /// result is bit-identical to [`GpModel::score_sequential`].
    pub fn score(&self, backend: &dyn SurrogateBackend, x_cand: &Dataset) -> Vec<Score> {
        let go_parallel = self.posteriors.len() > 1
            && x_cand.len() >= PAR_MIN_SCORE_M
            && parallel::max_threads() > 1;
        let per: Vec<Vec<Score>> = if go_parallel {
            parallel::par_map(&self.posteriors, |p| {
                backend.posterior_scores(p, x_cand, self.y_best_norm)
            })
        } else {
            self.posteriors
                .iter()
                .map(|p| backend.posterior_scores(p, x_cand, self.y_best_norm))
                .collect()
        };
        average_scores(per, x_cand.len())
    }

    /// Strictly sequential scoring (determinism cross-checks and benches).
    pub fn score_sequential(
        &self,
        backend: &dyn SurrogateBackend,
        x_cand: &Dataset,
    ) -> Vec<Score> {
        let per: Vec<Vec<Score>> = self
            .posteriors
            .iter()
            .map(|p| backend.posterior_scores(p, x_cand, self.y_best_norm))
            .collect();
        average_scores(per, x_cand.len())
    }

    /// Posterior mean in raw-objective units at one point.
    pub fn predict_raw(&self, backend: &dyn SurrogateBackend, x: &[f64]) -> (f64, f64) {
        let s = self.score(backend, &Dataset::from_row(x));
        (self.y_mean + self.y_std * s[0].mu, self.y_std * self.y_std * s[0].var)
    }
}

/// Order-stable average of per-posterior score vectors.
fn average_scores(per: Vec<Vec<Score>>, m: usize) -> Vec<Score> {
    let mut acc: Vec<Score> = vec![Score { ei: 0.0, mu: 0.0, var: 0.0 }; m];
    for s in &per {
        for (a, b) in acc.iter_mut().zip(s) {
            a.ei += b.ei;
            a.mu += b.mu;
            a.var += b.var;
        }
    }
    let k = per.len() as f64;
    for a in &mut acc {
        a.ei /= k;
        a.mu /= k;
        a.var /= k;
    }
    acc
}

/// Mean/std normalization constants (std floored for degenerate data).
pub fn normalization(y: &[f64]) -> (f64, f64) {
    let n = y.len() as f64;
    let mean = y.iter().sum::<f64>() / n;
    let var = y.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
    (mean, var.sqrt().max(1e-9))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn toy_data(n: usize, d: usize, seed: u64) -> (Dataset, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = Dataset::from_fn(n, d, |_, _| rng.uniform());
        // smooth function + small noise
        let y: Vec<f64> = x
            .rows()
            .map(|p| {
                (3.0 * p[0]).sin() + p.iter().skip(1).sum::<f64>() * 0.3 + 0.01 * rng.normal()
            })
            .collect();
        (x, y)
    }

    #[test]
    fn erf_and_cdf_reference_values() {
        assert!((erf(0.0)).abs() < 1e-6); // A&S 7.1.26: |err| < 1.5e-7
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((norm_cdf(1.96) - 0.9750021).abs() < 1e-5);
        assert!((norm_cdf(-1.96) - 0.0249979).abs() < 1e-5);
    }

    #[test]
    fn ei_closed_form_sanity() {
        // mu = y_best, sigma = 1 ⇒ EI = phi(0) ≈ 0.39894
        let ei = expected_improvement(0.0, 1.0, 0.0);
        assert!((ei - 0.3989422804).abs() < 1e-6);
        // far worse mean with tiny sigma ⇒ ~0
        assert!(expected_improvement(10.0, 1e-6, 0.0) < 1e-12);
        // improvement certain ⇒ EI ≈ y_best − mu
        let ei = expected_improvement(-5.0, 1e-6, 0.0);
        assert!((ei - 5.0).abs() < 1e-6);
    }

    #[test]
    fn nll_finite_and_better_for_true_noise() {
        let (x, y) = toy_data(30, 2, 1);
        let (m, s) = normalization(&y);
        let yn: Vec<f64> = y.iter().map(|v| (v - m) / s).collect();
        let good = Theta::default_for_dim(2);
        let mut bad = good.clone();
        bad.log_noise = 0.0; // variance 1: way too noisy for this data
        let nll_good = nll(&NativeBackend, &x, &yn, &good).unwrap();
        let nll_bad = nll(&NativeBackend, &x, &yn, &bad).unwrap();
        assert!(nll_good < nll_bad, "{nll_good} vs {nll_bad}");
    }

    #[test]
    fn nll_scratch_reuse_is_stable_and_allocation_free() {
        let (x, y) = toy_data(25, 3, 8);
        let (m, s) = normalization(&y);
        let yn: Vec<f64> = y.iter().map(|v| (v - m) / s).collect();
        let theta = Theta::default_for_dim(3);
        let mut scratch = GramScratch::new();
        let first = nll_scratch(&NativeBackend, &x, &yn, &theta, &mut scratch).unwrap();
        let warm = scratch.reallocs();
        for _ in 0..200 {
            let again = nll_scratch(&NativeBackend, &x, &yn, &theta, &mut scratch).unwrap();
            assert_eq!(first.to_bits(), again.to_bits());
        }
        assert_eq!(
            scratch.reallocs(),
            warm,
            "NLL inner loop must not allocate once the scratch is warm"
        );
        // and the scratch path agrees with the one-shot wrapper
        let one_shot = nll(&NativeBackend, &x, &yn, &theta).unwrap();
        assert_eq!(first.to_bits(), one_shot.to_bits());
    }

    #[test]
    fn posterior_interpolates_training_data() {
        let (x, y) = toy_data(25, 2, 2);
        let model =
            GpModel::fit(&NativeBackend, &x, &y, vec![Theta::default_for_dim(2)]).unwrap();
        for i in 0..5 {
            let (mu, var) = model.predict_raw(&NativeBackend, x.row(i));
            assert!((mu - y[i]).abs() < 0.15, "mu={mu} yi={}", y[i]);
            assert!(var < 0.1);
        }
    }

    #[test]
    fn posterior_uncertainty_grows_away_from_data() {
        let x = Dataset::from_row(&[0.5, 0.5]);
        let y = vec![0.0];
        let model =
            GpModel::fit(&NativeBackend, &x, &y, vec![Theta::default_for_dim(2)]).unwrap();
        let (_, var_near) = model.predict_raw(&NativeBackend, &[0.5, 0.5]);
        let (_, var_far) = model.predict_raw(&NativeBackend, &[0.0, 0.0]);
        assert!(var_far > 10.0 * var_near, "{var_far} vs {var_near}");
    }

    #[test]
    fn score_averages_over_theta_samples() {
        let (x, y) = toy_data(12, 2, 3);
        let mut t2 = Theta::default_for_dim(2);
        t2.log_ls = vec![(0.2f64).ln(); 2];
        let model =
            GpModel::fit(&NativeBackend, &x, &y, vec![Theta::default_for_dim(2), t2.clone()])
                .unwrap();
        let cand = Dataset::from_row(&[0.3, 0.7]);
        let avg = model.score(&NativeBackend, &cand)[0];
        let m1 = GpModel::fit(&NativeBackend, &x, &y, vec![Theta::default_for_dim(2)]).unwrap();
        let m2 = GpModel::fit(&NativeBackend, &x, &y, vec![t2]).unwrap();
        let s1 = m1.score(&NativeBackend, &cand)[0];
        let s2 = m2.score(&NativeBackend, &cand)[0];
        assert!((avg.mu - 0.5 * (s1.mu + s2.mu)).abs() < 1e-9);
        assert!((avg.ei - 0.5 * (s1.ei + s2.ei)).abs() < 1e-9);
    }

    #[test]
    fn parallel_score_is_bit_identical_to_sequential() {
        let (x, y) = toy_data(80, 3, 4);
        let mut thetas = Vec::new();
        for i in 0..6 {
            let mut t = Theta::default_for_dim(3);
            t.log_ls = vec![(0.2 + 0.1 * i as f64).ln(); 3];
            thetas.push(t);
        }
        let model = GpModel::fit(&NativeBackend, &x, &y, thetas).unwrap();
        let (cand, _) = toy_data(100, 3, 5);
        let par = model.score(&NativeBackend, &cand);
        let seq = model.score_sequential(&NativeBackend, &cand);
        assert_eq!(par.len(), seq.len());
        for (a, b) in par.iter().zip(&seq) {
            assert_eq!(a.ei.to_bits(), b.ei.to_bits());
            assert_eq!(a.mu.to_bits(), b.mu.to_bits());
            assert_eq!(a.var.to_bits(), b.var.to_bits());
        }
    }

    #[test]
    fn fit_from_factor_matches_direct_fit() {
        let (x, y) = toy_data(20, 2, 6);
        let theta = Theta::default_for_dim(2);
        let direct = GpModel::fit(&NativeBackend, &x, &y, vec![theta.clone()]).unwrap();
        let l = direct.posteriors[0].l.clone();
        let via_factor = GpModel::fit_from_factor(&x, &y, theta, l).unwrap();
        let (cand, _) = toy_data(10, 2, 7);
        let a = direct.score(&NativeBackend, &cand);
        let b = via_factor.score(&NativeBackend, &cand);
        for (u, v) in a.iter().zip(&b) {
            assert!((u.mu - v.mu).abs() < 1e-12);
            assert!((u.var - v.var).abs() < 1e-12);
        }
    }

    /// The fantasy append/rollback invariant the speculative pipeline
    /// rides (DESIGN.md §17): a factor extended by a row for a *fantasy*
    /// observation is bit-identical to one extended for the *real*
    /// observation at the same x, because the factor never sees y. Only
    /// alpha changes between the fantasy fit and the commit-time fit.
    #[test]
    fn factor_is_y_independent_so_fantasy_rows_commit_exactly() {
        let (x, y_fantasy) = toy_data(16, 2, 8);
        let mut y_real = y_fantasy.clone();
        *y_real.last_mut().unwrap() += 3.5; // the fantasy missed badly
        let theta = Theta::default_for_dim(2);
        let via_fantasy = GpModel::fit(&NativeBackend, &x, &y_fantasy, vec![theta.clone()])
            .unwrap();
        let via_real = GpModel::fit(&NativeBackend, &x, &y_real, vec![theta.clone()]).unwrap();
        // identical factors bit-for-bit…
        assert_eq!(via_fantasy.posteriors[0].l.data.len(), via_real.posteriors[0].l.data.len());
        for (a, b) in via_fantasy.posteriors[0].l.data.iter().zip(&via_real.posteriors[0].l.data)
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // …so re-solving with the real ys through the fantasy's factor is
        // the same model the synchronous path would have fitted
        let committed = GpModel::fit_from_factor(
            &x,
            &y_real,
            theta,
            via_fantasy.posteriors[0].l.clone(),
        )
        .unwrap();
        let (cand, _) = toy_data(8, 2, 9);
        let a = committed.score(&NativeBackend, &cand);
        let b = via_real.score(&NativeBackend, &cand);
        for (u, v) in a.iter().zip(&b) {
            assert_eq!(u.mu.to_bits(), v.mu.to_bits());
            assert_eq!(u.var.to_bits(), v.var.to_bits());
            assert_eq!(u.ei.to_bits(), v.ei.to_bits());
        }
    }

    #[test]
    fn fit_drops_non_finite_thetas() {
        let x = Dataset::from_rows(&[vec![0.1], vec![0.9]]);
        let y = vec![0.0, 1.0];
        let mut degenerate = Theta::default_for_dim(1);
        degenerate.log_amp = 710.0; // exp overflows ⇒ non-finite Gram ⇒ dropped
        let ok = Theta::default_for_dim(1);
        let model = GpModel::fit(&NativeBackend, &x, &y, vec![degenerate, ok]).unwrap();
        assert_eq!(model.posteriors.len(), 1);
    }

    #[test]
    fn normalization_handles_constant_targets() {
        let (m, s) = normalization(&[2.0, 2.0, 2.0]);
        assert_eq!(m, 2.0);
        assert!(s > 0.0);
    }
}
