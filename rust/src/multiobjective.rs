//! Multi-objective extension (§8 Conclusion): "In the future, AMT could be
//! extended to optimize multiple objectives simultaneously, automatically
//! suggesting hyperparameter configurations that are optimal along several
//! criteria and search for the Pareto frontier of the multiple objectives."
//!
//! This module implements that extension on top of the existing BO engine:
//!
//! * [`pareto_front`] — non-dominated filtering (minimization on all axes);
//! * [`hypervolume_2d`] — the standard front-quality indicator;
//! * [`ParEgoOptimizer`] — ParEGO-style random augmented-Chebyshev
//!   scalarization: each proposal draws a weight vector, scalarizes the
//!   (normalized) multi-objective history, and delegates to the single-
//!   objective GP/EI machinery — so warping, MCMC GPHPs and the
//!   asynchronous pending handling all carry over unchanged.

use std::sync::Arc;

use crate::gp::SurrogateBackend;
use crate::rng::Rng;
use crate::space::{Config, SearchSpace};
use crate::strategies::{BayesianOptimization, BoConfig, Observation};

/// One evaluation under several objectives (all minimized).
#[derive(Clone, Debug)]
pub struct MultiObservation {
    /// Evaluated configuration.
    pub config: Config,
    /// One value per objective.
    pub values: Vec<f64>,
}

/// True iff `a` dominates `b` (no worse on all axes, better on one).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the non-dominated observations (the Pareto front).
pub fn pareto_front(observations: &[MultiObservation]) -> Vec<usize> {
    (0..observations.len())
        .filter(|&i| {
            !observations
                .iter()
                .enumerate()
                .any(|(j, o)| j != i && dominates(&o.values, &observations[i].values))
        })
        .collect()
}

/// Dominated hypervolume of a 2-d front w.r.t. `reference` (both axes
/// minimized; points outside the reference box contribute nothing).
pub fn hypervolume_2d(front: &[(f64, f64)], reference: (f64, f64)) -> f64 {
    let mut pts: Vec<(f64, f64)> = front
        .iter()
        .copied()
        .filter(|p| p.0 < reference.0 && p.1 < reference.1)
        .collect();
    pts.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut hv = 0.0;
    let mut prev_y = reference.1;
    for (x, y) in pts {
        if y < prev_y {
            hv += (reference.0 - x) * (prev_y - y);
            prev_y = y;
        }
    }
    hv
}

/// ParEGO-style multi-objective BO: random augmented-Chebyshev
/// scalarization per proposal over the shared GP engine.
pub struct ParEgoOptimizer {
    bo: BayesianOptimization,
    num_objectives: usize,
    rng: Rng,
    /// Chebyshev augmentation coefficient (ParEGO default 0.05).
    pub rho: f64,
}

impl ParEgoOptimizer {
    /// Build over a search space and surrogate backend.
    pub fn new(
        space: SearchSpace,
        backend: Arc<dyn SurrogateBackend>,
        config: BoConfig,
        num_objectives: usize,
        seed: u64,
    ) -> Self {
        assert!(num_objectives >= 2, "use BayesianOptimization for 1 objective");
        ParEgoOptimizer {
            bo: BayesianOptimization::new(space, backend, config, seed),
            num_objectives,
            rng: Rng::new(seed ^ 0x9A9A),
            rho: 0.05,
        }
    }

    /// Scalarize the history with a random weight vector (normalized per
    /// objective to [0, 1] so weights are comparable).
    fn scalarize(&mut self, history: &[MultiObservation]) -> Vec<Observation> {
        // per-objective min/max
        let k = self.num_objectives;
        let mut lo = vec![f64::INFINITY; k];
        let mut hi = vec![f64::NEG_INFINITY; k];
        for o in history {
            for (j, v) in o.values.iter().enumerate() {
                lo[j] = lo[j].min(*v);
                hi[j] = hi[j].max(*v);
            }
        }
        // random simplex weights
        let raw: Vec<f64> = (0..k).map(|_| -self.rng.uniform().max(1e-12).ln()).collect();
        let sum: f64 = raw.iter().sum();
        let w: Vec<f64> = raw.iter().map(|v| v / sum).collect();

        history
            .iter()
            .map(|o| {
                let normed: Vec<f64> = o
                    .values
                    .iter()
                    .enumerate()
                    .map(|(j, v)| {
                        if hi[j] > lo[j] {
                            (v - lo[j]) / (hi[j] - lo[j])
                        } else {
                            0.0
                        }
                    })
                    .collect();
                let cheb = normed
                    .iter()
                    .zip(&w)
                    .map(|(v, wi)| v * wi)
                    .fold(f64::NEG_INFINITY, f64::max);
                let aug: f64 = normed.iter().zip(&w).map(|(v, wi)| v * wi).sum();
                Observation { config: o.config.clone(), value: cheb + self.rho * aug }
            })
            .collect()
    }

    /// Propose the next configuration for the multi-objective problem.
    pub fn next_config(
        &mut self,
        history: &[MultiObservation],
        pending: &[Config],
    ) -> Config {
        use crate::strategies::Strategy;
        let scalar = self.scalarize(history);
        self.bo.next_config(&scalar, pending)
    }

    /// Current Pareto front of the history.
    pub fn front<'a>(&self, history: &'a [MultiObservation]) -> Vec<&'a MultiObservation> {
        pareto_front(history).into_iter().map(|i| &history[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acquisition::AcquisitionConfig;
    use crate::gp::NativeBackend;
    use crate::space::{continuous, Scaling, Value};
    use crate::strategies::GphpMode;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal
    }

    fn mo(vals: &[f64]) -> MultiObservation {
        MultiObservation { config: Config::new(), values: vals.to_vec() }
    }

    #[test]
    fn pareto_front_filters_dominated() {
        let obs = vec![
            mo(&[1.0, 5.0]),
            mo(&[2.0, 2.0]),
            mo(&[5.0, 1.0]),
            mo(&[3.0, 3.0]), // dominated by (2,2)
            mo(&[2.0, 6.0]), // dominated by (1,5)? (1<=2, 5<=6, strict) yes
        ];
        let front = pareto_front(&obs);
        assert_eq!(front, vec![0, 1, 2]);
    }

    #[test]
    fn hypervolume_known_values() {
        // single point (0,0) with ref (1,1) ⇒ area 1
        assert!((hypervolume_2d(&[(0.0, 0.0)], (1.0, 1.0)) - 1.0).abs() < 1e-12);
        // staircase {(0, .5), (.5, 0)} ref (1,1): 1*0.5 + 0.5*0.5 = 0.75
        let hv = hypervolume_2d(&[(0.0, 0.5), (0.5, 0.0)], (1.0, 1.0));
        assert!((hv - 0.75).abs() < 1e-12, "{hv}");
        // points outside the reference contribute nothing
        assert_eq!(hypervolume_2d(&[(2.0, 2.0)], (1.0, 1.0)), 0.0);
        // dominated point adds nothing
        let hv2 = hypervolume_2d(&[(0.0, 0.5), (0.5, 0.0), (0.6, 0.6)], (1.0, 1.0));
        assert!((hv2 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn parego_approaches_biobjective_front() {
        // f1 = x², f2 = (x−1)²: Pareto set is x ∈ [0, 1]
        let space =
            SearchSpace::new(vec![continuous("x", -2.0, 3.0, Scaling::Linear)]).unwrap();
        let mut opt = ParEgoOptimizer::new(
            space,
            Arc::new(NativeBackend),
            BoConfig {
                init_random: 4,
                gphp: GphpMode::EmpiricalBayes { restarts: 1 },
                acq: AcquisitionConfig { num_anchors: 128, ..Default::default() },
                ..Default::default()
            },
            2,
            3,
        );
        let mut history: Vec<MultiObservation> = Vec::new();
        for _ in 0..20 {
            let c = opt.next_config(&history, &[]);
            let x = c.get("x").unwrap().as_f64().unwrap();
            history.push(MultiObservation {
                config: c,
                values: vec![x * x, (x - 1.0) * (x - 1.0)],
            });
        }
        let front = opt.front(&history);
        assert!(front.len() >= 3, "front too small: {}", front.len());
        // most front points should lie in the Pareto set [0, 1] (±slack)
        let inside = front
            .iter()
            .filter(|o| {
                let x = o.config.get("x").unwrap().as_f64().unwrap();
                (-0.2..=1.2).contains(&x)
            })
            .count();
        assert!(
            inside * 2 >= front.len(),
            "front not concentrated on the Pareto set"
        );
        // hypervolume should beat a naive two-endpoint baseline
        let pts: Vec<(f64, f64)> =
            front.iter().map(|o| (o.values[0], o.values[1])).collect();
        let hv = hypervolume_2d(&pts, (4.0, 4.0));
        assert!(hv > hypervolume_2d(&[(0.0, 1.0), (1.0, 0.0)], (4.0, 4.0)) * 0.9);
    }

    #[test]
    fn scalarization_preserves_config_identity() {
        let space =
            SearchSpace::new(vec![continuous("x", 0.0, 1.0, Scaling::Linear)]).unwrap();
        let mut opt = ParEgoOptimizer::new(
            space,
            Arc::new(NativeBackend),
            BoConfig::default(),
            2,
            1,
        );
        let mut cfg = Config::new();
        cfg.insert("x".into(), Value::Float(0.5));
        let hist = vec![MultiObservation { config: cfg.clone(), values: vec![1.0, 2.0] }];
        let scalar = opt.scalarize(&hist);
        assert_eq!(scalar.len(), 1);
        assert_eq!(scalar[0].config, cfg);
        assert!(scalar[0].value.is_finite());
    }
}
