//! Real trainable model for the end-to-end example: a small MLP binary
//! classifier whose train/eval steps are the `mlp_train_h*` / `mlp_eval_h*`
//! AOT artifacts. The Rust coordinator owns the parameters and the training
//! loop; every SGD epoch and every evaluation is an HLO execution — no
//! Python anywhere at run time.
//!
//! [`MlpObjective`] adapts the trainer to the [`crate::objectives::Objective`]
//! interface so the *entire AMT stack* (API → workflow → platform →
//! selection service → early stopper) can tune a genuinely trained model.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::objectives::Objective;
use crate::rng::Rng;
use crate::space::{categorical, continuous, Config, Scaling, SearchSpace, Value};

use super::{literal_matrix, literal_to_f64, literal_vec, HloRuntime};

/// A synthetic-but-real binary classification dataset (two noisy linear
/// class boundaries with interactions), fixed at generation seed.
pub struct MlpDataset {
    /// Train inputs, row-major (train_rows × features).
    pub x_train: Vec<f64>,
    /// Train labels.
    pub y_train: Vec<f64>,
    /// Validation inputs.
    pub x_val: Vec<f64>,
    /// Validation labels.
    pub y_val: Vec<f64>,
    /// Feature count.
    pub features: usize,
    /// Train rows.
    pub train_rows: usize,
    /// Validation rows.
    pub val_rows: usize,
}

impl MlpDataset {
    /// Generate the dataset matching the artifact shapes.
    pub fn generate(runtime: &HloRuntime, seed: u64) -> MlpDataset {
        let f = runtime.manifest.mlp_features;
        let tr = runtime.manifest.mlp_train_rows;
        let vr = runtime.manifest.mlp_val_rows;
        let mut rng = Rng::new(seed);
        let w: Vec<f64> = (0..f).map(|_| rng.normal()).collect();
        let mut make = |rows: usize| {
            let mut x = Vec::with_capacity(rows * f);
            let mut y = Vec::with_capacity(rows);
            for _ in 0..rows {
                let xi: Vec<f64> = (0..f).map(|_| rng.normal()).collect();
                // nonlinear boundary: linear part + pairwise interaction
                let score: f64 = xi.iter().zip(&w).map(|(a, b)| a * b).sum::<f64>()
                    + 0.8 * xi[0] * xi[1]
                    + 0.3 * rng.normal();
                x.extend_from_slice(&xi);
                y.push(if score > 0.0 { 1.0 } else { 0.0 });
            }
            (x, y)
        };
        let (x_train, y_train) = make(tr);
        let (x_val, y_val) = make(vr);
        MlpDataset { x_train, y_train, x_val, y_val, features: f, train_rows: tr, val_rows: vr }
    }
}

/// MLP parameters + the executable pair for one hidden width.
pub struct MlpTrainer {
    runtime: Arc<HloRuntime>,
    hidden: usize,
    features: usize,
    w1: Vec<f64>,
    b1: Vec<f64>,
    w2: Vec<f64>,
    b2: Vec<f64>,
}

impl MlpTrainer {
    /// Initialize parameters for hidden width `hidden` (must be one of the
    /// compiled artifact widths).
    pub fn new(runtime: Arc<HloRuntime>, hidden: usize, seed: u64) -> Result<MlpTrainer> {
        if !runtime.manifest.mlp_widths.contains(&hidden) {
            return Err(anyhow!(
                "no mlp artifact for hidden width {hidden} (have {:?})",
                runtime.manifest.mlp_widths
            ));
        }
        let f = runtime.manifest.mlp_features;
        let mut rng = Rng::new(seed ^ 0x3117);
        let scale = (2.0 / f as f64).sqrt();
        Ok(MlpTrainer {
            features: f,
            w1: (0..f * hidden).map(|_| rng.normal() * scale).collect(),
            b1: vec![0.0; hidden],
            w2: (0..hidden).map(|_| rng.normal() * (2.0 / hidden as f64).sqrt()).collect(),
            b2: vec![0.0; 1],
            hidden,
            runtime,
        })
    }

    /// One SGD epoch over the dataset through the `mlp_train_h*` artifact;
    /// returns the mean training loss.
    pub fn train_epoch(&mut self, data: &MlpDataset, lr: f64, l2: f64) -> Result<f64> {
        let out = self.runtime.run(
            &format!("mlp_train_h{}", self.hidden),
            &[
                &literal_matrix(&self.w1, self.features, self.hidden)?,
                &literal_vec(&self.b1),
                &literal_vec(&self.w2),
                &literal_vec(&self.b2),
                &literal_matrix(&data.x_train, data.train_rows, data.features)?,
                &literal_vec(&data.y_train),
                &literal_vec(&[lr]),
                &literal_vec(&[l2]),
            ],
        )?;
        self.w1 = literal_to_f64(&out[0])?;
        self.b1 = literal_to_f64(&out[1])?;
        self.w2 = literal_to_f64(&out[2])?;
        self.b2 = literal_to_f64(&out[3])?;
        Ok(literal_to_f64(&out[4])?[0])
    }

    /// Validation (loss, accuracy) through the `mlp_eval_h*` artifact.
    pub fn evaluate(&self, data: &MlpDataset) -> Result<(f64, f64)> {
        let out = self.runtime.run(
            &format!("mlp_eval_h{}", self.hidden),
            &[
                &literal_matrix(&self.w1, self.features, self.hidden)?,
                &literal_vec(&self.b1),
                &literal_vec(&self.w2),
                &literal_vec(&self.b2),
                &literal_matrix(&data.x_val, data.val_rows, data.features)?,
                &literal_vec(&data.y_val),
            ],
        )?;
        Ok((literal_to_f64(&out[0])?[0], literal_to_f64(&out[1])?[0]))
    }
}

/// The end-to-end workload: tune (learning_rate, l2, hidden_width) of the
/// real HLO-trained MLP. Metric = validation loss per epoch (minimized).
pub struct MlpObjective {
    runtime: Arc<HloRuntime>,
    dataset: Arc<MlpDataset>,
    epochs: u32,
}

impl MlpObjective {
    /// Build the workload (dataset fixed by `data_seed`).
    pub fn new(runtime: Arc<HloRuntime>, data_seed: u64, epochs: u32) -> MlpObjective {
        let dataset = Arc::new(MlpDataset::generate(&runtime, data_seed));
        MlpObjective { runtime, dataset, epochs }
    }

    /// Validation accuracy of a fully trained configuration (reporting).
    pub fn final_accuracy(&self, config: &Config, seed: u64) -> f64 {
        let (mut trainer, lr, l2) = self.make_trainer(config, seed);
        for _ in 0..self.epochs {
            let _ = trainer.train_epoch(&self.dataset, lr, l2);
        }
        trainer.evaluate(&self.dataset).map(|(_, acc)| acc).unwrap_or(0.0)
    }

    fn make_trainer(&self, config: &Config, seed: u64) -> (MlpTrainer, f64, f64) {
        let lr = config.get("learning_rate").and_then(Value::as_f64).unwrap_or(0.1);
        let l2 = config.get("l2").and_then(Value::as_f64).unwrap_or(1e-4);
        let hidden: usize = config
            .get("hidden_width")
            .and_then(Value::as_str)
            .and_then(|s| s.parse().ok())
            .unwrap_or(32);
        let trainer = MlpTrainer::new(Arc::clone(&self.runtime), hidden, seed)
            .expect("hidden width validated by the space");
        (trainer, lr, l2)
    }
}

impl Objective for MlpObjective {
    fn name(&self) -> &str {
        "mlp_real"
    }

    fn space(&self) -> SearchSpace {
        let widths: Vec<String> =
            self.runtime.manifest.mlp_widths.iter().map(|w| w.to_string()).collect();
        let width_refs: Vec<&str> = widths.iter().map(String::as_str).collect();
        SearchSpace::new(vec![
            continuous("learning_rate", 1e-3, 1.0, Scaling::Logarithmic),
            continuous("l2", 1e-7, 1e-1, Scaling::Logarithmic),
            categorical("hidden_width", &width_refs),
        ])
        .unwrap()
    }

    fn max_epochs(&self) -> u32 {
        self.epochs
    }

    fn curve(&self, config: &Config, seed: u64) -> Vec<f64> {
        let (mut trainer, lr, l2) = self.make_trainer(config, seed);
        let mut curve = Vec::with_capacity(self.epochs as usize);
        for _ in 0..self.epochs {
            if trainer.train_epoch(&self.dataset, lr, l2).is_err() {
                curve.push(f64::INFINITY);
                continue;
            }
            let (val_loss, _) = trainer.evaluate(&self.dataset).unwrap_or((f64::INFINITY, 0.0));
            curve.push(val_loss);
        }
        curve
    }

    fn epoch_seconds(&self, config: &Config) -> f64 {
        // bigger hidden layer ⇒ slower simulated epochs
        let hidden: f64 = config
            .get("hidden_width")
            .and_then(Value::as_str)
            .and_then(|s| s.parse().ok())
            .unwrap_or(32.0);
        8.0 + hidden * 0.25
    }
}
