//! [`SurrogateBackend`] implementation over the AOT HLO artifacts: the
//! production GP compute path (Pallas Matérn kernel inside the compiled
//! graphs), with transparent fallback to the native backend for shapes the
//! artifact family does not cover (encoded dim > D or train set > the
//! largest bucket).
//!
//! Inputs arrive as the contiguous row-major [`Dataset`], so bucket
//! padding is a straight row-by-row `copy_from_slice` out of the flat
//! buffer — no per-row pointer chasing or re-marshalling.

use std::sync::Arc;

use crate::gp::{Dataset, NativeBackend, PosteriorState, Score, SurrogateBackend, Theta};
use crate::linalg::Matrix;

use super::{literal_matrix, literal_to_f64, literal_vec, HloRuntime};

/// GP backend executing the `kernel_matrix_n*` / `posterior_ei_n*` HLO
/// artifacts through PJRT.
pub struct HloBackend {
    runtime: Arc<HloRuntime>,
    /// §Perf iteration 7 (hybrid routing): serve `gram` from the native
    /// path and keep the artifacts for the batched posterior/EI scoring.
    /// The slice sampler issues ~600 small Gram+Cholesky queries per
    /// proposal, where per-call PJRT overhead dominates on this CPU
    /// testbed (measured: proposal p50 1.5 s → ~40 ms at n = 50); the
    /// acquisition batch (M = 256 candidates per execution) amortizes that
    /// overhead and stays on the compiled Pallas path. Set to `false` to
    /// run everything through the artifacts (numeric cross-checks do).
    pub hybrid_gram: bool,
    /// Count of calls that fell back to the native path.
    pub native_fallbacks: std::sync::atomic::AtomicU64,
}

impl HloBackend {
    /// Wrap an opened runtime (hybrid Gram routing on — see field docs).
    pub fn new(runtime: Arc<HloRuntime>) -> Self {
        HloBackend {
            runtime,
            hybrid_gram: true,
            native_fallbacks: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// All compute through the artifacts (used by the numeric cross-checks
    /// and the kernel benches).
    pub fn artifacts_only(runtime: Arc<HloRuntime>) -> Self {
        HloBackend {
            runtime,
            hybrid_gram: false,
            native_fallbacks: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// The artifact runtime (for perf counters).
    pub fn runtime(&self) -> &HloRuntime {
        &self.runtime
    }

    fn fits(&self, d: usize, n: usize) -> bool {
        d <= self.runtime.manifest.encoded_dim && self.runtime.manifest.bucket_for(n).is_some()
    }

    fn note_fallback(&self) {
        self.native_fallbacks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Pad encoded points (n × d) into a bucket-sized row-major f64 buffer
    /// (b × D) — padded entries are zeros, which the masked graphs ignore.
    /// Rows stream straight out of the dataset's flat buffer.
    fn pad_points(&self, x: &Dataset, b: usize) -> Vec<f64> {
        let dd = self.runtime.manifest.encoded_dim;
        let d = x.dim();
        let mut out = vec![0.0; b * dd];
        for (i, row) in x.rows().enumerate() {
            out[i * dd..i * dd + d].copy_from_slice(row);
        }
        out
    }

    /// Pack a d-dimensional theta into the artifact's D-dimensional layout.
    fn pad_theta(&self, theta: &Theta) -> Vec<f64> {
        let dd = self.runtime.manifest.encoded_dim;
        let d = theta.dim();
        let mut v = Vec::with_capacity(2 + 3 * dd);
        v.push(theta.log_amp);
        v.push(theta.log_noise);
        for block in [&theta.log_ls, &theta.log_wa, &theta.log_wb] {
            v.extend_from_slice(block);
            v.extend(std::iter::repeat(0.0).take(dd - d));
        }
        v
    }
}

impl SurrogateBackend for HloBackend {
    fn name(&self) -> &'static str {
        "hlo"
    }

    fn gram(&self, x: &Dataset, theta: &Theta) -> Matrix {
        let n = x.len();
        let d = x.dim();
        if self.hybrid_gram {
            // deliberate routing, not a fallback — see field docs
            return NativeBackend.gram(x, theta);
        }
        if !self.fits(d, n) {
            self.note_fallback();
            return NativeBackend.gram(x, theta);
        }
        let b = self.runtime.manifest.bucket_for(n).unwrap();
        let dd = self.runtime.manifest.encoded_dim;
        let go = || -> anyhow::Result<Matrix> {
            let x_lit = literal_matrix(&self.pad_points(x, b), b, dd)?;
            let mut mask = vec![1.0; n];
            mask.resize(b, 0.0);
            let mask_lit = literal_vec(&mask);
            let theta_lit = literal_vec(&self.pad_theta(theta));
            let out = self.runtime.run(
                &format!("kernel_matrix_n{b}"),
                &[&x_lit, &mask_lit, &theta_lit],
            )?;
            let k = literal_to_f64(&out[0])?;
            // trim the padded (b × b) result to (n × n)
            let mut m = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    m[(i, j)] = k[i * b + j];
                }
            }
            // enforce exact symmetry (f32 round-trip)
            for i in 0..n {
                for j in 0..i {
                    let v = 0.5 * (m[(i, j)] + m[(j, i)]);
                    m[(i, j)] = v;
                    m[(j, i)] = v;
                }
            }
            Ok(m)
        };
        match go() {
            Ok(m) => m,
            Err(e) => {
                // artifact missing/corrupt ⇒ stay correct on the native path
                eprintln!("hlo backend gram fallback: {e}");
                self.note_fallback();
                NativeBackend.gram(x, theta)
            }
        }
    }

    fn posterior_scores(
        &self,
        post: &PosteriorState,
        x_cand: &Dataset,
        y_best: f64,
    ) -> Vec<Score> {
        let n = post.x.len();
        let d = post.x.dim();
        // §Perf iteration 8: the local EI refinement scores ONE candidate
        // per call (sequential Nelder–Mead); padding it to the M = 256
        // artifact batch wastes 99.6% of the execution and PJRT call
        // overhead dominates (measured ~1.3 s of a 1.5 s proposal). Tiny
        // batches run natively; the Sobol anchor grid still goes through
        // the compiled Pallas path where the batch amortizes the call.
        if self.hybrid_gram && x_cand.len() <= 32 {
            return NativeBackend.posterior_scores(post, x_cand, y_best);
        }
        if !self.fits(d, n) {
            self.note_fallback();
            return NativeBackend.posterior_scores(post, x_cand, y_best);
        }
        let b = self.runtime.manifest.bucket_for(n).unwrap();
        let dd = self.runtime.manifest.encoded_dim;
        let m_batch = self.runtime.manifest.cand_batch;

        let go = || -> anyhow::Result<Vec<Score>> {
            // bucket-padded training-side inputs (shared across chunks)
            let x_lit = literal_matrix(&self.pad_points(&post.x, b), b, dd)?;
            let mut mask = vec![1.0; n];
            mask.resize(b, 0.0);
            let mask_lit = literal_vec(&mask);
            let theta_lit = literal_vec(&self.pad_theta(&post.theta));
            let mut kinv_pad = vec![0.0; b * b];
            for i in 0..n {
                kinv_pad[i * b..i * b + n]
                    .copy_from_slice(&post.k_inv.data[i * n..(i + 1) * n]);
            }
            let kinv_lit = literal_matrix(&kinv_pad, b, b)?;
            let mut alpha_pad = post.alpha.clone();
            alpha_pad.resize(b, 0.0);
            let alpha_lit = literal_vec(&alpha_pad);
            let ybest_lit = literal_vec(&[y_best]);

            let mut scores = Vec::with_capacity(x_cand.len());
            let mut start = 0;
            while start < x_cand.len() {
                let end = (start + m_batch).min(x_cand.len());
                let chunk = x_cand.slice(start..end);
                let cand_lit =
                    literal_matrix(&self.pad_points(&chunk, m_batch), m_batch, dd)?;
                let out = self.runtime.run(
                    &format!("posterior_ei_n{b}"),
                    &[
                        &x_lit, &mask_lit, &theta_lit, &kinv_lit, &alpha_lit, &cand_lit,
                        &ybest_lit,
                    ],
                )?;
                let ei = literal_to_f64(&out[0])?;
                let mu = literal_to_f64(&out[1])?;
                let var = literal_to_f64(&out[2])?;
                for i in 0..chunk.len() {
                    scores.push(Score { ei: ei[i], mu: mu[i], var: var[i] });
                }
                start = end;
            }
            Ok(scores)
        };
        match go() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("hlo backend posterior fallback: {e}");
                self.note_fallback();
                NativeBackend.posterior_scores(post, x_cand, y_best)
            }
        }
    }
}
