//! PJRT runtime: loads and executes the AOT-compiled HLO artifacts.
//!
//! This is the L3 ↔ L2 bridge: `make artifacts` lowers the JAX/Pallas
//! graphs to HLO *text* (jax ≥ 0.5 emits serialized protos with 64-bit ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids), and
//! this module compiles them once on the PJRT CPU client and executes them
//! from the BO hot path. Python never runs at request time.
//!
//! Layout contract with `python/compile/aot.py` is carried by
//! `artifacts/manifest.json` (buckets, encoded dim, candidate batch, theta
//! packing).

pub mod backend;
pub mod mlp;

pub use backend::HloBackend;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::json::{self, Json};

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Train-set-size buckets with compiled artifacts.
    pub buckets: Vec<usize>,
    /// Encoded configuration dimension D of the compiled graphs.
    pub encoded_dim: usize,
    /// Candidate batch size M of the posterior/EI graph.
    pub cand_batch: usize,
    /// Packed theta length (must equal 2 + 3 D).
    pub theta_dim: usize,
    /// MLP artifact family (end-to-end example).
    pub mlp_widths: Vec<usize>,
    /// MLP input features.
    pub mlp_features: usize,
    /// MLP train batch rows.
    pub mlp_train_rows: usize,
    /// MLP validation rows.
    pub mlp_val_rows: usize,
}

impl Manifest {
    /// Parse from JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arr_usize = |k: &str| -> Result<Vec<usize>> {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Json::as_i64)
                        .map(|v| v as usize)
                        .collect()
                })
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let num =
            |v: Option<&Json>, k: &str| v.and_then(Json::as_i64).ok_or_else(|| anyhow!("manifest missing {k}"));
        let mlp = j.get("mlp").ok_or_else(|| anyhow!("manifest missing mlp"))?;
        Ok(Manifest {
            buckets: arr_usize("buckets")?,
            encoded_dim: num(j.get("encoded_dim"), "encoded_dim")? as usize,
            cand_batch: num(j.get("cand_batch"), "cand_batch")? as usize,
            theta_dim: num(j.get("theta_dim"), "theta_dim")? as usize,
            mlp_widths: mlp
                .get("widths")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_i64).map(|v| v as usize).collect())
                .ok_or_else(|| anyhow!("manifest missing mlp.widths"))?,
            mlp_features: num(mlp.get("features"), "mlp.features")? as usize,
            mlp_train_rows: num(mlp.get("train_rows"), "mlp.train_rows")? as usize,
            mlp_val_rows: num(mlp.get("val_rows"), "mlp.val_rows")? as usize,
        })
    }

    /// Smallest bucket that fits `n` live rows.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }
}

/// `PjRtLoadedExecutable` wrapper asserting thread-safety.
///
/// SAFETY: the PJRT CPU client is thread-safe per the PJRT C API contract;
/// the crate merely omits the auto-markers because it holds raw pointers.
/// All executions additionally serialize through [`HloRuntime::run`]'s
/// mutex, so cross-thread use is conservative.
struct SendExecutable(xla::PjRtLoadedExecutable);
unsafe impl Send for SendExecutable {}
unsafe impl Sync for SendExecutable {}

/// Compiled-artifact cache over one PJRT CPU client.
pub struct HloRuntime {
    dir: PathBuf,
    /// Manifest describing the artifact family.
    pub manifest: Manifest,
    client: Mutex<xla::PjRtClient>,
    executables: Mutex<HashMap<String, Arc<SendExecutable>>>,
    /// Total artifact executions (perf accounting).
    pub executions: std::sync::atomic::AtomicU64,
}

unsafe impl Send for HloRuntime {}
unsafe impl Sync for HloRuntime {}

impl HloRuntime {
    /// Open the artifact directory (expects `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Arc<HloRuntime>> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?;
        let manifest = Manifest::parse(&manifest_text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Arc::new(HloRuntime {
            dir,
            manifest,
            client: Mutex::new(client),
            executables: Mutex::new(HashMap::new()),
            executions: std::sync::atomic::AtomicU64::new(0),
        }))
    }

    /// Default artifact location (repo-root `artifacts/`).
    pub fn open_default() -> Result<Arc<HloRuntime>> {
        HloRuntime::open("artifacts")
    }

    /// Compile (or fetch from cache) the named artifact.
    fn executable(&self, name: &str) -> Result<Arc<SendExecutable>> {
        {
            let cache = self.executables.lock().unwrap();
            if let Some(e) = cache.get(name) {
                return Ok(Arc::clone(e));
            }
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = {
            let client = self.client.lock().unwrap();
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?
        };
        let exe = Arc::new(SendExecutable(exe));
        self.executables.lock().unwrap().insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact with literal inputs; returns the output tuple's
    /// elements (graphs are lowered with `return_tuple=True`).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        self.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // serialize executions (single CPU device; keeps FFI use conservative)
        let _guard = self.client.lock().unwrap();
        let result = exe
            .0
            .execute(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        out.to_tuple().map_err(|e| anyhow!("untupling {name} output: {e:?}"))
    }

    /// Names of compiled-and-cached artifacts (for diagnostics).
    pub fn cached(&self) -> Vec<String> {
        self.executables.lock().unwrap().keys().cloned().collect()
    }
}

/// f32 row-major literal from f64 data with shape (rows, cols).
pub fn literal_matrix(data: &[f64], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    let f32s: Vec<f32> = data.iter().map(|&v| v as f32).collect();
    xla::Literal::vec1(&f32s)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// f32 vector literal from f64 data.
pub fn literal_vec(data: &[f64]) -> xla::Literal {
    let f32s: Vec<f32> = data.iter().map(|&v| v as f32).collect();
    xla::Literal::vec1(&f32s)
}

/// Read an f32 literal back as f64s.
pub fn literal_to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    Ok(v.into_iter().map(|x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
            "buckets": [16, 32], "encoded_dim": 8, "cand_batch": 256,
            "theta_dim": 26, "jitter": 1e-6,
            "mlp": {"widths": [8], "features": 10, "train_rows": 512,
                     "val_rows": 256, "num_batches": 8}
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.buckets, vec![16, 32]);
        assert_eq!(m.encoded_dim, 8);
        assert_eq!(m.theta_dim, 26);
        assert_eq!(m.bucket_for(10), Some(16));
        assert_eq!(m.bucket_for(17), Some(32));
        assert_eq!(m.bucket_for(33), None);
    }

    #[test]
    fn manifest_rejects_incomplete() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let data = vec![1.5, -2.0, 3.25, 0.0, 7.0, -1.0];
        let lit = literal_matrix(&data, 2, 3).unwrap();
        let back = literal_to_f64(&lit).unwrap();
        assert_eq!(back, data);
    }
}
