//! Benchmark & figure-harness utilities.
//!
//! The offline environment pins a vendored crate set without criterion, so
//! `cargo bench` targets use this self-contained harness: warmup + timed
//! iterations, robust summary statistics, and aligned table printing shared
//! by the figure-reproduction examples.
//!
//! Perf benches additionally emit machine-readable `BENCH_<name>.json`
//! reports (see [`BenchReport`]) so the latency trajectory is tracked
//! across PRs; `scripts/bench.sh` diffs a fresh run against the committed
//! baselines.

use std::time::Instant;

use crate::json::Json;

/// Summary statistics over timed iterations (seconds).
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    /// Iterations measured.
    pub iters: usize,
    /// Mean seconds/iter.
    pub mean: f64,
    /// Median seconds/iter.
    pub p50: f64,
    /// 95th percentile seconds/iter.
    pub p95: f64,
    /// Minimum seconds/iter.
    pub min: f64,
}

impl BenchStats {
    /// From raw per-iteration durations.
    pub fn from_samples(mut secs: Vec<f64>) -> BenchStats {
        assert!(!secs.is_empty());
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = secs.len();
        let mean = secs.iter().sum::<f64>() / n as f64;
        BenchStats {
            iters: n,
            mean,
            p50: secs[n / 2],
            p95: secs[((n - 1) as f64 * 0.95) as usize],
            min: secs[0],
        }
    }

    /// Human format with auto units.
    pub fn human(&self) -> String {
        format!(
            "mean {:>10} p50 {:>10} p95 {:>10} min {:>10} ({} iters)",
            fmt_secs(self.mean),
            fmt_secs(self.p50),
            fmt_secs(self.p95),
            fmt_secs(self.min),
            self.iters
        )
    }
}

/// Format seconds with appropriate unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let stats = BenchStats::from_samples(samples);
    println!("{name:<44} {}", stats.human());
    stats
}

/// Machine-readable benchmark report, one entry per measured case.
///
/// Serialized as `BENCH_<name>.json` next to the working directory of the
/// bench run (repo root under `cargo bench`), or under `AMT_BENCH_DIR`
/// when set. Schema:
///
/// ```json
/// { "bench": "propose", "schema": 1,
///   "entries": [ { "label": "...", "params": {...}, "iters": 3,
///                  "mean_s": 0.01, "p50_s": 0.01, "p95_s": 0.02,
///                  "min_s": 0.009 } ] }
/// ```
pub struct BenchReport {
    name: String,
    entries: Vec<Json>,
}

impl BenchReport {
    /// New empty report named `name` (file becomes `BENCH_<name>.json`).
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), entries: Vec::new() }
    }

    /// Record one measured case with free-form string parameters.
    pub fn push(&mut self, label: &str, params: &[(&str, String)], stats: &BenchStats) {
        let p = Json::Obj(
            params.iter().map(|(k, v)| (k.to_string(), Json::Str(v.clone()))).collect(),
        );
        self.entries.push(Json::obj(vec![
            ("label", Json::Str(label.to_string())),
            ("params", p),
            ("iters", Json::Num(stats.iters as f64)),
            ("mean_s", Json::Num(stats.mean)),
            ("p50_s", Json::Num(stats.p50)),
            ("p95_s", Json::Num(stats.p95)),
            ("min_s", Json::Num(stats.min)),
        ]));
    }

    /// Record a per-operation latency histogram (telemetry plane,
    /// DESIGN.md §15) as a report entry. Maps the µs summary onto the
    /// seconds-based schema (`p50_s` keyed so `scripts/bench.sh` can diff
    /// it like any timed case; the p95 slot carries p99 — the closest tail
    /// the log-bucketed histogram exports) and carries the full tail in
    /// `params` (`p50_us`/`p99_us`/`p999_us`/`max_us`/`count`).
    pub fn push_histogram(
        &mut self,
        label: &str,
        params: &[(&str, String)],
        h: &crate::telemetry::HistSummary,
    ) {
        let stats = BenchStats {
            iters: h.count as usize,
            mean: h.mean_us() / 1e6,
            p50: h.p50 as f64 / 1e6,
            p95: h.p99 as f64 / 1e6,
            min: h.min as f64 / 1e6,
        };
        let mut extended: Vec<(&str, String)> = params.to_vec();
        extended.push(("p50_us", h.p50.to_string()));
        extended.push(("p99_us", h.p99.to_string()));
        extended.push(("p999_us", h.p999.to_string()));
        extended.push(("max_us", h.max.to_string()));
        extended.push(("count", h.count.to_string()));
        self.push(label, &extended, &stats);
    }

    /// The report as a JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("schema", Json::Num(1.0)),
            ("entries", Json::Arr(self.entries.clone())),
        ])
    }

    /// Write `BENCH_<name>.json` (respecting `AMT_BENCH_DIR`) and return
    /// the path written.
    pub fn write(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("AMT_BENCH_DIR").unwrap_or_else(|_| ".".into());
        let path = std::path::Path::new(&dir).join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_pretty() + "\n")?;
        Ok(path)
    }
}

/// Print an aligned table: header + rows of equal arity.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    let fmt_row = |cells: Vec<String>| {
        cells
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(header.iter().map(|s| s.to_string()).collect()));
    println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
    for row in rows {
        println!("{}", fmt_row(row.clone()));
    }
}

/// Mean and population standard deviation.
pub fn mean_std(values: &[f64]) -> (f64, f64) {
    let n = values.len().max(1) as f64;
    let mean = values.iter().sum::<f64>() / n;
    let var = values.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Interpolate a step series (time, best-so-far) onto a fixed time grid —
/// used to average best-over-time curves across replications.
pub fn step_interpolate(series: &[(f64, f64)], grid: &[f64], default: f64) -> Vec<f64> {
    grid.iter()
        .map(|&t| {
            let mut last = default;
            for &(st, sv) in series {
                if st <= t {
                    last = sv;
                } else {
                    break;
                }
            }
            last
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_computed_correctly() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-5).ends_with("µs"));
        assert!(fmt_secs(2.5e-2).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }

    #[test]
    fn step_interpolation() {
        let series = vec![(1.0, 10.0), (3.0, 5.0)];
        let grid = vec![0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(
            step_interpolate(&series, &grid, f64::NAN)
                .iter()
                .skip(1)
                .cloned()
                .collect::<Vec<_>>(),
            vec![10.0, 10.0, 5.0, 5.0]
        );
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0]);
        assert_eq!(m, 3.0);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn bench_report_serializes_and_parses_back() {
        let stats = BenchStats::from_samples(vec![0.01, 0.02, 0.03]);
        let mut report = BenchReport::new("propose");
        report.push("propose native n=50", &[("n", "50".into()), ("backend", "native".into())], &stats);
        let j = report.to_json();
        let text = j.to_pretty();
        let parsed = crate::json::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("propose"));
        assert_eq!(parsed.get("schema").unwrap().as_i64(), Some(1));
        let entries = parsed.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("iters").unwrap().as_i64(), Some(3));
        assert_eq!(entries[0].get("p50_s").unwrap().as_f64(), Some(stats.p50));
        assert_eq!(
            entries[0].get("params").unwrap().get("backend").unwrap().as_str(),
            Some("native")
        );
    }
}
