//! Slice-lifecycle tracing (DESIGN.md §15): per-job trace ids minted at
//! submission and carried through the `Assign`/`SliceResult` wire
//! frames, with cheap structured events in a bounded in-memory ring.
//!
//! The trace sink is process-global (unlike metric registries):
//! [`crate::distributed::worker::WorkerRuntime`] has no service handle,
//! and in loopback tests the leader and worker share one process, so a
//! global sink is the only sink both sides can reach. Consumers filter
//! by job name ([`for_job`]) — job names are unique per test/service —
//! or drain everything ([`drain`], the `AmtService::drain_traces`
//! backing).
//!
//! Phase vocabulary (one complete distributed slice lifecycle):
//! `propose` (job accepted, trace minted) → `dispatch` (leader sent the
//! poll burst) → `worker_poll` (the `SliceResult` echoed our trace id —
//! recorded by the *leader*, so the wire field is load-bearing) →
//! `delta_apply` (slice records applied to store/metrics) →
//! `group_commit` (WAL commit covering the slice) → `outcome` (terminal
//! verdict published). Every phase except `propose`/`outcome` repeats
//! per slice.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Ring capacity: old events are dropped past this, and every overwrite
/// increments the `telemetry.trace_dropped` counter surfaced by
/// [`crate::api::AmtService::telemetry_snapshot`] — overflow is never
/// silent. Public so overflow tests can size their fill loops.
pub const RING_CAP: usize = 65_536;

/// One structured trace event. `t_us` is microseconds on the process
/// clock ([`super::now_us`]).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub trace_id: u64,
    pub job: String,
    pub phase: &'static str,
    pub t_us: u64,
}

struct Sink {
    ring: Mutex<VecDeque<TraceEvent>>,
    jobs: Mutex<HashMap<String, u64>>,
    next_id: AtomicU64,
    dropped: AtomicU64,
    /// Sample 1-in-N jobs (by name hash); 1 = trace every job.
    sample_every: AtomicU64,
}

fn sink() -> &'static Sink {
    static SINK: OnceLock<Sink> = OnceLock::new();
    SINK.get_or_init(|| Sink {
        ring: Mutex::new(VecDeque::with_capacity(1024)),
        jobs: Mutex::new(HashMap::new()),
        next_id: AtomicU64::new(1),
        dropped: AtomicU64::new(0),
        sample_every: AtomicU64::new(1),
    })
}

/// FNV-1a — the store's shard hash, reused so sampling is a pure
/// deterministic function of the job name.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Trace 1-in-`n` jobs (deterministic by job-name hash). `n = 1`
/// (default) traces every job; `n = 0` is clamped to 1.
pub fn set_sampling(n: u64) {
    sink().sample_every.store(n.max(1), Ordering::Relaxed);
}

/// Mint (or look up) the trace id for `job`, recording the `propose`
/// event on first mint. Returns `None` when telemetry is disabled or
/// the job is sampled out — callers just skip tracing then.
pub fn ensure_trace(job: &str) -> Option<u64> {
    if super::disabled() {
        return None;
    }
    let s = sink();
    if let Some(&id) = s.jobs.lock().unwrap().get(job) {
        return Some(id);
    }
    let every = s.sample_every.load(Ordering::Relaxed);
    if every > 1 && fnv1a(job) % every != 0 {
        return None;
    }
    let id = {
        let mut jobs = s.jobs.lock().unwrap();
        // double-checked under the lock: a concurrent submit of the
        // same name must not mint two ids
        if let Some(&id) = jobs.get(job) {
            return Some(id);
        }
        let id = s.next_id.fetch_add(1, Ordering::Relaxed);
        jobs.insert(job.to_string(), id);
        id
    };
    event(id, job, "propose");
    Some(id)
}

/// The already-minted trace id for `job`, if any (and telemetry is on).
pub fn trace_id(job: &str) -> Option<u64> {
    if super::disabled() {
        return None;
    }
    sink().jobs.lock().unwrap().get(job).copied()
}

/// Record one event into the bounded ring. No-op when disabled.
pub fn event(trace_id: u64, job: &str, phase: &'static str) {
    if super::disabled() {
        return;
    }
    let ev = TraceEvent { trace_id, job: job.to_string(), phase, t_us: super::now_us() };
    let s = sink();
    let mut ring = s.ring.lock().unwrap();
    if ring.len() >= RING_CAP {
        ring.pop_front();
        s.dropped.fetch_add(1, Ordering::Relaxed);
    }
    ring.push_back(ev);
}

/// Record `phase` for `job` if it has a minted trace id — the common
/// call shape on the leader's hot path.
#[inline]
pub fn event_for(job: &str, phase: &'static str) {
    if super::disabled() {
        return;
    }
    if let Some(id) = trace_id(job) {
        event(id, job, phase);
    }
}

/// Drain the whole ring (oldest first). Destructive and process-global
/// — prefer [`for_job`] inside tests that share the process.
pub fn drain() -> Vec<TraceEvent> {
    sink().ring.lock().unwrap().drain(..).collect()
}

/// Non-destructive view of one job's events, oldest first.
pub fn for_job(job: &str) -> Vec<TraceEvent> {
    sink().ring.lock().unwrap().iter().filter(|e| e.job == job).cloned().collect()
}

/// Forget a finished job's name→id binding (the ring keeps its events
/// until they age out). Bounds the map under job churn.
pub fn forget(job: &str) {
    sink().jobs.lock().unwrap().remove(job);
}

/// Total trace ids minted since process start.
pub fn minted() -> u64 {
    sink().next_id.load(Ordering::Relaxed) - 1
}

/// Events dropped to the ring bound since process start.
pub fn dropped() -> u64 {
    sink().dropped.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_is_idempotent_and_records_propose_once() {
        let job = "trace-unit-mint";
        let a = ensure_trace(job).expect("telemetry defaults on");
        let b = ensure_trace(job).unwrap();
        assert_eq!(a, b, "same job must keep one trace id");
        assert_eq!(trace_id(job), Some(a));
        let proposes =
            for_job(job).iter().filter(|e| e.phase == "propose").count();
        assert_eq!(proposes, 1);
        forget(job);
        assert_eq!(trace_id(job), None);
        // events survive forget(): the ring is the record of what ran
        assert!(!for_job(job).is_empty());
    }

    #[test]
    fn events_are_ordered_and_filtered_per_job() {
        let job = "trace-unit-order";
        let id = ensure_trace(job).unwrap();
        for phase in ["dispatch", "worker_poll", "delta_apply", "group_commit", "outcome"] {
            event(id, job, phase);
        }
        let events = for_job(job);
        let phases: Vec<&str> = events.iter().map(|e| e.phase).collect();
        assert_eq!(
            phases,
            vec!["propose", "dispatch", "worker_poll", "delta_apply", "group_commit", "outcome"]
        );
        assert!(events.windows(2).all(|w| w[0].t_us <= w[1].t_us));
        assert!(events.iter().all(|e| e.trace_id == id));
        forget(job);
    }

    // NOTE: sampling and the enabled flag are process-global, and lib
    // unit tests run in parallel threads of one binary — toggling them
    // here would race other tests' ensure_trace calls. Their behavior
    // is covered in `rust/tests/telemetry.rs`, which serializes the
    // toggles inside a single #[test].
}
