//! Telemetry plane (DESIGN.md §15): zero-dependency metrics registry,
//! latency histograms, and slice-lifecycle tracing for the whole
//! service — scheduler → WAL → wire → worker.
//!
//! The paper's AMT is operable at scale because it is observable: job
//! health, progress, and tuning decisions surface through described
//! jobs and emitted metrics (§3.2, §6.5). This module is the
//! reproduction's instrumentation substrate:
//!
//! * [`metrics`] — lock-free [`Counter`]s, [`Gauge`]s and log-bucketed
//!   latency [`Histogram`]s (p50/p99/p999 + exact min/max/count,
//!   mergeable across shards and threads, bit-deterministic bucket
//!   boundaries) behind a hierarchically-named [`Registry`]
//!   (`scheduler.poll_slice_us`, `wal.commit_us`, `leader.rtt_us`,
//!   `store.put_batch_us`, …);
//! * [`trace`] — cheap structured [`trace::TraceEvent`]s with a per-job
//!   trace id minted at submission and carried through the
//!   `Assign`/`SliceResult` wire frames, so one job's propose →
//!   dispatch → worker poll → delta apply → group commit → outcome
//!   path is reconstructible from a bounded in-memory ring buffer;
//! * export surfaces — [`TelemetrySnapshot`] (typed, JSON-serializable,
//!   renders the `amt stats` human table), drained per-job traces for
//!   `amt trace <job>`, and histogram emission into
//!   [`crate::harness::BenchReport`].
//!
//! Registries are **per component instance** (each scheduler, store,
//! WAL, and worker pool owns its own), never process-global: `cargo
//! test` runs many services concurrently in one process and asserts
//! exact counter values, so metrics must not bleed across instances.
//! Only the trace sink is process-global (workers have no service
//! handle); trace consumers filter by job name.
//!
//! Overhead budget: the kill switch [`disabled()`] is a single relaxed
//! atomic load; with telemetry on, the hot path is one relaxed
//! fetch-add per counter and five relaxed atomic RMWs per histogram
//! sample — no locks, no allocation after the handle is created.

pub mod metrics;
pub mod trace;

pub use metrics::{Counter, Gauge, HistSummary, Histogram};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::json::Json;

/// Process-wide kill switch. Latency *timing* and trace recording honor
/// it; plain counters keep counting regardless (existing tests assert
/// exact counts, and a relaxed fetch-add costs less than the branch
/// would save).
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is telemetry recording on? Single relaxed load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The compiled-in fast path: one relaxed load, nothing else.
#[inline]
pub fn disabled() -> bool {
    !ENABLED.load(Ordering::Relaxed)
}

/// Turn latency timing and trace recording on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Microseconds since the first telemetry observation in this process —
/// the common clock for trace events. Monotonic, never wraps in
/// practice (u64 µs ≈ 585k years).
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(Instant::now);
    Instant::now().saturating_duration_since(epoch).as_micros() as u64
}

/// A point-in-time value of one named metric.
#[derive(Clone, Debug)]
pub enum MetricValue {
    Counter(u64),
    Gauge(i64),
    Histogram(HistSummary),
}

/// One named metric in a [`TelemetrySnapshot`].
#[derive(Clone, Debug)]
pub struct MetricSnapshot {
    pub name: String,
    pub value: MetricValue,
}

/// Get-or-create registry of named metrics for ONE component instance.
/// Handle creation takes a mutex (cold path, at component construction);
/// the returned `Arc` handles are lock-free thereafter.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the counter named `name` (hierarchical dotted
    /// names by convention: `"wal.commits"`).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new())))
    }

    /// Get or create the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new())))
    }

    /// Get or create the histogram named `name` (values in µs by
    /// convention: `"wal.commit_us"`).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())))
    }

    /// Point-in-time snapshot of every metric in this registry,
    /// name-sorted (BTreeMap order) within each kind.
    pub fn snapshot(&self) -> Vec<MetricSnapshot> {
        let mut out = Vec::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push(MetricSnapshot {
                name: name.clone(),
                value: MetricValue::Counter(c.get()),
            });
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push(MetricSnapshot { name: name.clone(), value: MetricValue::Gauge(g.get()) });
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push(MetricSnapshot {
                name: name.clone(),
                value: MetricValue::Histogram(h.summary()),
            });
        }
        out
    }
}

/// One typed, JSON-serializable view of every metric a service exports
/// — the payload of [`crate::api::AmtService::telemetry_snapshot`] and
/// of `amt stats`.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySnapshot {
    /// Name-sorted metrics merged from every component registry.
    pub metrics: Vec<MetricSnapshot>,
}

impl TelemetrySnapshot {
    /// Merge component snapshots into one name-sorted view.
    pub fn from_parts(parts: Vec<Vec<MetricSnapshot>>) -> Self {
        let mut metrics: Vec<MetricSnapshot> = parts.into_iter().flatten().collect();
        metrics.sort_by(|a, b| a.name.cmp(&b.name));
        TelemetrySnapshot { metrics }
    }

    /// Look up a counter value by name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.metrics.iter().find(|m| m.name == name).and_then(|m| match &m.value {
            MetricValue::Counter(v) => Some(*v),
            _ => None,
        })
    }

    /// Look up a gauge value by name.
    pub fn gauge(&self, name: &str) -> Option<i64> {
        self.metrics.iter().find(|m| m.name == name).and_then(|m| match &m.value {
            MetricValue::Gauge(v) => Some(*v),
            _ => None,
        })
    }

    /// Look up a histogram summary by name.
    pub fn histogram(&self, name: &str) -> Option<&HistSummary> {
        self.metrics.iter().find(|m| m.name == name).and_then(|m| match &m.value {
            MetricValue::Histogram(h) => Some(h),
            _ => None,
        })
    }

    /// JSON export (`amt stats --json`): an object keyed by metric name;
    /// counters/gauges as numbers, histograms as objects with
    /// count/min/max/mean and p50/p99/p999 (all µs).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for m in &self.metrics {
            let value = match &m.value {
                MetricValue::Counter(v) => Json::Num(*v as f64),
                MetricValue::Gauge(v) => Json::Num(*v as f64),
                MetricValue::Histogram(h) => Json::obj(vec![
                    ("count", Json::Num(h.count as f64)),
                    ("min_us", Json::Num(h.min as f64)),
                    ("max_us", Json::Num(h.max as f64)),
                    ("mean_us", Json::Num(h.mean_us())),
                    ("p50_us", Json::Num(h.p50 as f64)),
                    ("p99_us", Json::Num(h.p99 as f64)),
                    ("p999_us", Json::Num(h.p999 as f64)),
                ]),
            };
            obj.insert(m.name.clone(), value);
        }
        Json::Obj(obj)
    }

    /// Human-readable table (`amt stats` default output).
    pub fn render_table(&self) -> String {
        let mut rows: Vec<(String, String)> = Vec::new();
        for m in &self.metrics {
            let value = match &m.value {
                MetricValue::Counter(v) => v.to_string(),
                MetricValue::Gauge(v) => v.to_string(),
                MetricValue::Histogram(h) if h.count == 0 => "n=0".to_string(),
                MetricValue::Histogram(h) => format!(
                    "n={} p50={}µs p99={}µs p999={}µs min={}µs max={}µs",
                    h.count, h.p50, h.p99, h.p999, h.min, h.max
                ),
            };
            rows.push((m.name.clone(), value));
        }
        let width = rows.iter().map(|(n, _)| n.len()).max().unwrap_or(6).max(6);
        let mut out = String::new();
        out.push_str(&format!("{:<width$}  value\n", "metric", width = width));
        out.push_str(&format!("{:-<width$}  -----\n", "", width = width));
        for (name, value) in rows {
            out.push_str(&format!("{name:<width$}  {value}\n", width = width));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_handles_are_shared_and_snapshot_is_sorted() {
        let reg = Registry::new();
        let a = reg.counter("z.last");
        let b = reg.counter("z.last");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "both handles must hit the same counter");
        reg.gauge("a.first").set(-5);
        reg.histogram("m.mid_us").record(10);
        let snap = TelemetrySnapshot::from_parts(vec![reg.snapshot()]);
        let names: Vec<&str> = snap.metrics.iter().map(|m| m.name.as_str()).collect();
        assert_eq!(names, vec!["a.first", "m.mid_us", "z.last"]);
        assert_eq!(snap.counter("z.last"), Some(3));
        assert_eq!(snap.gauge("a.first"), Some(-5));
        assert_eq!(snap.histogram("m.mid_us").unwrap().count, 1);
    }

    #[test]
    fn snapshot_json_roundtrips_through_the_crate_parser() {
        let reg = Registry::new();
        reg.counter("x.count").add(7);
        let h = reg.histogram("x.lat_us");
        for v in [1u64, 100, 10_000] {
            h.record(v);
        }
        let snap = TelemetrySnapshot::from_parts(vec![reg.snapshot()]);
        let text = snap.to_json().to_string();
        let parsed = crate::json::parse(&text).expect("snapshot JSON must parse");
        assert_eq!(parsed.get("x.count").and_then(Json::as_f64), Some(7.0));
        let hist = parsed.get("x.lat_us").expect("histogram entry");
        assert_eq!(hist.get("count").and_then(Json::as_f64), Some(3.0));
        assert!(hist.get("p999_us").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn telemetry_defaults_on() {
        // the flag itself is process-global, so the off-state behavior
        // is exercised in `rust/tests/telemetry.rs` (own binary) — here
        // only the default and the accessor pairing are checked
        assert!(enabled());
        assert_eq!(disabled(), !enabled());
    }
}
