//! Lock-free metric primitives: [`Counter`], [`Gauge`], and the
//! log-linear latency [`Histogram`] (DESIGN.md §15).
//!
//! Histogram bucket math (HdrHistogram-style log-linear, integer-only
//! so boundaries are bit-deterministic on every platform): values are
//! unsigned integers (µs by convention). Values `0..=7` get exact
//! unit-width buckets `0..=7`. A value `v ≥ 8` with `b = floor(log2 v)`
//! lands in bucket `8 + (b-3)*4 + ((v >> (b-2)) & 3)` — each power-of-2
//! range is split into 4 linear sub-buckets, so the relative bucket
//! width is ≤ 1/4 everywhere (quantiles report the bucket's lower
//! bound, which is within 25% below the true value). 256 bucket slots
//! cover all of `u64` (the largest index, at `v = u64::MAX`, is 251).
//!
//! Buckets are plain relaxed `AtomicU64`s: recording is 5 relaxed RMWs
//! (count, sum, min, max, bucket), merging is commutative addition —
//! per-shard or per-thread histograms merged in ANY order report
//! identical quantiles (property-tested in `rust/tests/telemetry.rs`).

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Monotonic event counter. Always counts (not gated on
/// [`super::enabled`]): a relaxed fetch-add is cheaper than a
/// mispredicted branch, and test suites assert exact counts.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// The raw atomic behind the counter — for pre-registry interfaces
    /// that take `&AtomicU64` (e.g.
    /// [`crate::durability::commit_with_retry`]'s failure counter).
    #[inline]
    pub fn as_atomic(&self) -> &AtomicU64 {
        &self.value
    }
}

/// Last-write-wins signed gauge (fleet sizes, parked-job counts).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, delta: i64) {
        self.value.fetch_add(delta, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Number of histogram bucket slots (covers all of `u64`; see module
/// docs for the index formula — max used index is 251).
pub const BUCKETS: usize = 256;

/// Log-linear latency histogram: lock-free, mergeable, with exact
/// min/max/count/sum alongside the bucketed distribution.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Deterministic bucket index for `v` (see module docs).
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        if v < 8 {
            v as usize
        } else {
            let b = 63 - v.leading_zeros() as usize; // floor(log2 v), ≥ 3
            8 + (b - 3) * 4 + ((v >> (b - 2)) & 3) as usize
        }
    }

    /// Inclusive lower bound of bucket `idx` — the value quantiles
    /// report for samples that landed there.
    #[inline]
    pub fn bucket_lower(idx: usize) -> u64 {
        if idx < 8 {
            idx as u64
        } else {
            let b = (idx - 8) / 4 + 3;
            let sub = ((idx - 8) % 4) as u64;
            (1u64 << b) + sub * (1u64 << (b - 2))
        }
    }

    /// Record one sample. 5 relaxed atomic RMWs, no locks, no
    /// allocation.
    #[inline]
    pub fn record(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a [`std::time::Duration`] as whole microseconds.
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_micros() as u64);
    }

    /// Fold another histogram into this one. Pure addition (plus
    /// min/max folds), so merging N shards is commutative and
    /// associative — any merge order yields identical quantiles.
    pub fn merge_from(&self, other: &Histogram) {
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min.fetch_min(other.min.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// The value at quantile `q` (0.0–1.0): the lower bound of the
    /// bucket containing the rank-`ceil(q·n)` sample, clamped into
    /// `[min, max]` so degenerate low-count reads stay sane. 0 when
    /// empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        let mut value = 0u64;
        for (idx, bucket) in self.buckets.iter().enumerate() {
            cum += bucket.load(Ordering::Relaxed);
            if cum >= rank {
                value = Self::bucket_lower(idx);
                break;
            }
        }
        let min = self.min.load(Ordering::Relaxed);
        let max = self.max.load(Ordering::Relaxed);
        value.clamp(min.min(max), max)
    }

    /// Point-in-time summary (the exported form).
    pub fn summary(&self) -> HistSummary {
        let count = self.count.load(Ordering::Relaxed);
        HistSummary {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { self.min.load(Ordering::Relaxed) },
            max: self.max.load(Ordering::Relaxed),
            p50: self.quantile(0.50),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Histogram({:?})", self.summary())
    }
}

/// Exported summary of a [`Histogram`]: exact count/sum/min/max plus
/// bucketed p50/p99/p999. All values in the histogram's unit (µs by
/// convention).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistSummary {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub p50: u64,
    pub p99: u64,
    pub p999: u64,
}

impl HistSummary {
    /// Mean in the histogram's unit (µs by convention); 0.0 when empty.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_and_monotone() {
        // unit-width linear region
        for v in 0u64..8 {
            assert_eq!(Histogram::bucket_index(v), v as usize);
            assert_eq!(Histogram::bucket_lower(v as usize), v);
        }
        // every bucket's lower bound maps back to that bucket, and
        // lower bounds strictly increase
        let top = Histogram::bucket_index(u64::MAX);
        assert!(top < BUCKETS, "u64::MAX index {top} must fit");
        let mut prev = 0u64;
        for idx in 1..=top {
            let lower = Histogram::bucket_lower(idx);
            assert_eq!(
                Histogram::bucket_index(lower),
                idx,
                "lower bound {lower} must land in its own bucket {idx}"
            );
            assert!(lower > prev, "bucket lowers must be strictly increasing at {idx}");
            prev = lower;
        }
        // one past a lower bound stays in the same bucket; the next
        // lower bound starts the next bucket
        assert_eq!(Histogram::bucket_index(8), Histogram::bucket_index(9));
        assert_ne!(Histogram::bucket_index(8), Histogram::bucket_index(10));
    }

    #[test]
    fn quantiles_track_exact_values_in_the_linear_region() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4, 5, 6, 7] {
            h.record(v);
        }
        let s = h.summary();
        assert_eq!(s.count, 7);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 7);
        assert_eq!(s.p50, 4); // rank ceil(0.5·7)=4 → value 4, exact
        assert_eq!(s.p99, 7);
        assert_eq!(s.p999, 7);
        assert_eq!(s.sum, 28);
    }

    #[test]
    fn single_sample_summary_is_that_sample_in_every_percentile() {
        let h = Histogram::new();
        h.record(123_456);
        let s = h.summary();
        assert_eq!((s.count, s.min, s.max), (1, 123_456, 123_456));
        // bucketed percentiles clamp into [min, max] = the exact value
        assert_eq!(s.p50, 123_456);
        assert_eq!(s.p999, 123_456);
    }

    #[test]
    fn merge_is_equivalent_to_recording_into_one() {
        let a = Histogram::new();
        let b = Histogram::new();
        let one = Histogram::new();
        for v in 0..1000u64 {
            let x = v * v % 7919;
            if v % 2 == 0 { a.record(x) } else { b.record(x) }
            one.record(x);
        }
        let merged = Histogram::new();
        merged.merge_from(&b);
        merged.merge_from(&a);
        assert_eq!(merged.summary(), one.summary());
    }

    #[test]
    fn empty_histogram_summarizes_to_zeros() {
        let s = Histogram::new().summary();
        assert_eq!(s, HistSummary::default());
    }
}
