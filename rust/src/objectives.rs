//! Objective/workload suite: everything the paper's evaluation tunes.
//!
//! The paper runs AMT against real SageMaker training jobs (XGBoost on the
//! UCI direct-marketing set, linear learner on Gdelt, the built-in image
//! classifier on Caltech-256, an SVM capacity sweep). We do not have those
//! proprietary workloads, so each is substituted with a calibrated surrogate
//! that preserves the properties the corresponding experiment measures (see
//! DESIGN.md §4 for the substitution table): response-surface shape,
//! learning-curve family, noise level, and evaluation-time structure.
//!
//! Every objective exposes a *learning curve* (metric value after each
//! training epoch), which is what the platform simulator streams to the
//! metrics service and what the median-rule early stopper consumes.

use crate::rng::Rng;
use crate::space::{categorical, continuous, integer, Config, Scaling, SearchSpace, Value};

/// A tunable workload: deterministic given (config, seed).
pub trait Objective: Send + Sync {
    /// Short identifier (used by the CLI and benches).
    fn name(&self) -> &str;
    /// The hyperparameter search space of this workload.
    fn space(&self) -> SearchSpace;
    /// Number of training epochs of a full (non-stopped) run.
    fn max_epochs(&self) -> u32;
    /// Whether lower metric values are better.
    fn minimize(&self) -> bool {
        true
    }
    /// Full learning curve: metric after epochs 1..=max_epochs.
    fn curve(&self, config: &Config, seed: u64) -> Vec<f64>;
    /// Simulated wall-clock seconds per training epoch for this config.
    fn epoch_seconds(&self, _config: &Config) -> f64 {
        10.0
    }

    /// Final metric of a complete run.
    fn final_value(&self, config: &Config, seed: u64) -> f64 {
        *self
            .curve(config, seed)
            .last()
            .expect("curve must be non-empty")
    }
}

fn get_f(config: &Config, key: &str) -> f64 {
    config
        .get(key)
        .and_then(Value::as_f64)
        .unwrap_or_else(|| panic!("missing numeric hyperparameter {key}"))
}

/// Standard converging learning curve: exponential decay from `init` to
/// `asymptote` with time constant `tau` epochs plus iid noise.
pub fn converging_curve(
    epochs: u32,
    init: f64,
    asymptote: f64,
    tau: f64,
    noise_sd: f64,
    rng: &mut Rng,
) -> Vec<f64> {
    (1..=epochs)
        .map(|r| {
            asymptote
                + (init - asymptote) * (-(r as f64) / tau).exp()
                + noise_sd * rng.normal()
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Analytic test functions (BO correctness and regression tests)
// ---------------------------------------------------------------------------

/// Wraps an analytic ℝᵈ→ℝ function as a trainable workload with a
/// converging curve towards the true value.
pub struct Analytic {
    name: &'static str,
    space: SearchSpace,
    f: fn(&[f64]) -> f64,
    noise_sd: f64,
    epochs: u32,
}

impl Analytic {
    /// Branin (2-d, three global minima, value ≈ 0.397887).
    pub fn branin() -> Self {
        Analytic {
            name: "branin",
            space: SearchSpace::new(vec![
                continuous("x1", -5.0, 10.0, Scaling::Linear),
                continuous("x2", 0.0, 15.0, Scaling::Linear),
            ])
            .unwrap(),
            f: |x| {
                let (x1, x2) = (x[0], x[1]);
                let a = 1.0;
                let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
                let c = 5.0 / std::f64::consts::PI;
                let r = 6.0;
                let s = 10.0;
                let t = 1.0 / (8.0 * std::f64::consts::PI);
                a * (x2 - b * x1 * x1 + c * x1 - r).powi(2) + s * (1.0 - t) * x1.cos() + s
            },
            noise_sd: 0.05,
            epochs: 5,
        }
    }

    /// Hartmann-6 (6-d, global minimum ≈ -3.32237).
    pub fn hartmann6() -> Self {
        Analytic {
            name: "hartmann6",
            space: SearchSpace::new(
                (1..=6)
                    .map(|i| continuous(&format!("x{i}"), 0.0, 1.0, Scaling::Linear))
                    .collect(),
            )
            .unwrap(),
            f: |x| {
                const ALPHA: [f64; 4] = [1.0, 1.2, 3.0, 3.2];
                const A: [[f64; 6]; 4] = [
                    [10.0, 3.0, 17.0, 3.5, 1.7, 8.0],
                    [0.05, 10.0, 17.0, 0.1, 8.0, 14.0],
                    [3.0, 3.5, 1.7, 10.0, 17.0, 8.0],
                    [17.0, 8.0, 0.05, 10.0, 0.1, 14.0],
                ];
                const P: [[f64; 6]; 4] = [
                    [0.1312, 0.1696, 0.5569, 0.0124, 0.8283, 0.5886],
                    [0.2329, 0.4135, 0.8307, 0.3736, 0.1004, 0.9991],
                    [0.2348, 0.1451, 0.3522, 0.2883, 0.3047, 0.6650],
                    [0.4047, 0.8828, 0.8732, 0.5743, 0.1091, 0.0381],
                ];
                -(0..4)
                    .map(|i| {
                        let inner: f64 = (0..6)
                            .map(|j| A[i][j] * (x[j] - P[i][j]).powi(2))
                            .sum();
                        ALPHA[i] * (-inner).exp()
                    })
                    .sum::<f64>()
            },
            noise_sd: 0.01,
            epochs: 5,
        }
    }

    /// Rastrigin in `d` dimensions (highly multimodal; global minimum 0).
    pub fn rastrigin(d: usize) -> Self {
        assert!((1..=8).contains(&d));
        Analytic {
            name: "rastrigin",
            space: SearchSpace::new(
                (1..=d)
                    .map(|i| continuous(&format!("x{i}"), -5.12, 5.12, Scaling::Linear))
                    .collect(),
            )
            .unwrap(),
            f: |x| {
                10.0 * x.len() as f64
                    + x.iter()
                        .map(|&v| v * v - 10.0 * (2.0 * std::f64::consts::PI * v).cos())
                        .sum::<f64>()
            },
            noise_sd: 0.1,
            epochs: 5,
        }
    }

    /// Evaluate the underlying analytic function at an encoded-order vector
    /// of raw values (test helper).
    pub fn raw(&self, x: &[f64]) -> f64 {
        (self.f)(x)
    }
}

impl Objective for Analytic {
    fn name(&self) -> &str {
        self.name
    }
    fn space(&self) -> SearchSpace {
        self.space.clone()
    }
    fn max_epochs(&self) -> u32 {
        self.epochs
    }
    fn curve(&self, config: &Config, seed: u64) -> Vec<f64> {
        let x: Vec<f64> = self
            .space
            .parameters
            .iter()
            .map(|p| get_f(config, p.name()))
            .collect();
        let fx = (self.f)(&x);
        let mut rng = Rng::new(seed ^ 0xA11A);
        converging_curve(self.epochs, fx + 2.0, fx, 1.2, self.noise_sd, &mut rng)
    }
    fn epoch_seconds(&self, _config: &Config) -> f64 {
        30.0
    }
}

// ---------------------------------------------------------------------------
// Figure 2: SVM capacity sweep
// ---------------------------------------------------------------------------

/// Validation score of an SVM as a function of its capacity parameter C over
/// the paper's range [1e-9, 1e9] (Fig 2): flat underfit plateau, a rise over
/// a few decades, a broad optimum, and a mild overfitting decline.
pub struct SvmCapacity;

impl SvmCapacity {
    /// Noise-free validation accuracy at capacity `c`.
    pub fn accuracy(c: f64) -> f64 {
        let lc = c.log10();
        let rise = 1.0 / (1.0 + (-(lc + 1.0) / 0.9).exp());
        let overfit = 1.0 / (1.0 + (-(lc - 5.0) / 1.4).exp());
        0.52 + 0.40 * rise - 0.10 * overfit
    }
}

impl Objective for SvmCapacity {
    fn name(&self) -> &str {
        "svm_capacity"
    }
    fn minimize(&self) -> bool {
        false
    }
    fn space(&self) -> SearchSpace {
        SearchSpace::new(vec![continuous("C", 1e-9, 1e9, Scaling::Logarithmic)]).unwrap()
    }
    fn max_epochs(&self) -> u32 {
        10
    }
    fn curve(&self, config: &Config, seed: u64) -> Vec<f64> {
        let acc = Self::accuracy(get_f(config, "C"));
        let mut rng = Rng::new(seed ^ 0x57);
        converging_curve(10, acc * 0.6, acc, 2.5, 0.004, &mut rng)
    }
    fn epoch_seconds(&self, config: &Config) -> f64 {
        // larger capacity ⇒ slower training (the cost asymmetry §5.1 notes)
        20.0 * (1.0 + get_f(config, "C").log10().max(0.0))
    }
}

// ---------------------------------------------------------------------------
// Figure 3: XGBoost on UCI direct marketing (alpha, lambda regularizers)
// ---------------------------------------------------------------------------

/// Response surface for tuning XGBoost `alpha` / `lambda` on the UCI
/// direct-marketing task (Fig 3). Score is an error-style metric (paper:
/// "lower is better"): best at small `alpha` (the region log scaling
/// surfaces), weakly curved in `lambda`, with evaluation noise.
pub struct XgboostDirectMarketing;

impl XgboostDirectMarketing {
    /// Noise-free validation score (≈ 1 − AUC) at (alpha, lambda).
    pub fn score(alpha: f64, lambda: f64) -> f64 {
        let la = alpha.log10(); // range [-6, 2]
        let ll = lambda.log10();
        // alpha: flat optimum below ~1e-2, steep degradation above 1
        let alpha_pen = 0.055 / (1.0 + (-(la - 0.3) / 0.55).exp());
        // lambda: shallow parabola with optimum near 10
        let lambda_pen = 0.006 * (ll - 1.0).powi(2);
        // mild interaction: heavy L1 + heavy L2 over-regularizes
        let inter = 0.004 * ((la + 1.0).max(0.0)) * ((ll + 1.0).max(0.0));
        0.072 + alpha_pen + lambda_pen + inter
    }
}

impl Objective for XgboostDirectMarketing {
    fn name(&self) -> &str {
        "xgboost_dm"
    }
    fn space(&self) -> SearchSpace {
        SearchSpace::new(vec![
            continuous("alpha", 1e-6, 100.0, Scaling::Logarithmic),
            continuous("lambda", 1e-6, 100.0, Scaling::Logarithmic),
        ])
        .unwrap()
    }
    /// Variant with linear scaling (the log-scaling ablation in Fig 3).
    fn max_epochs(&self) -> u32 {
        20
    }
    fn curve(&self, config: &Config, seed: u64) -> Vec<f64> {
        let s = Self::score(get_f(config, "alpha"), get_f(config, "lambda"));
        let mut rng = Rng::new(seed ^ 0x9B00);
        converging_curve(20, s + 0.15, s, 4.0, 0.0025, &mut rng)
    }
    fn epoch_seconds(&self, _config: &Config) -> f64 {
        8.0
    }
}

/// The same XGBoost workload with *linear* parameter scaling — the
/// without-log-scaling arm of the §5.1/§6.2 comparison.
pub struct XgboostDirectMarketingLinear;

impl Objective for XgboostDirectMarketingLinear {
    fn name(&self) -> &str {
        "xgboost_dm_linear"
    }
    fn space(&self) -> SearchSpace {
        SearchSpace::new(vec![
            continuous("alpha", 1e-6, 100.0, Scaling::Linear),
            continuous("lambda", 1e-6, 100.0, Scaling::Linear),
        ])
        .unwrap()
    }
    fn max_epochs(&self) -> u32 {
        20
    }
    fn curve(&self, config: &Config, seed: u64) -> Vec<f64> {
        XgboostDirectMarketing.curve(config, seed)
    }
    fn epoch_seconds(&self, c: &Config) -> f64 {
        XgboostDirectMarketing.epoch_seconds(c)
    }
}

// ---------------------------------------------------------------------------
// Figure 4: linear learner on Gdelt (early-stopping experiment)
// ---------------------------------------------------------------------------

/// Linear-learner-on-Gdelt surrogate with full learning curves. The
/// `distributed` variant models the multi-year dataset on a cluster: longer
/// epochs, more of them, and noisier curves — the regime where early
/// stopping pays most (Fig 4 right).
pub struct GdeltLinearLearner {
    /// Multi-year data on a distributed cluster vs single instance.
    pub distributed: bool,
}

impl GdeltLinearLearner {
    fn quality(config: &Config) -> (f64, f64) {
        // asymptotic absolute loss and convergence time-constant
        let lr = get_f(config, "learning_rate");
        let wd = get_f(config, "wd");
        let llr = lr.log10(); // [-4, 0]
        let lwd = wd.log10(); // [-7, 0]
        // best lr around 3e-2, best wd around 1e-5
        let loss = 0.30
            + 0.12 * ((llr + 1.5) / 1.1).powi(2)
            + 0.025 * ((lwd + 5.0) / 2.0).powi(2);
        // small lr ⇒ slow convergence; large ⇒ fast but worse asymptote
        let tau = 2.0 + 14.0 * (1.0 / (1.0 + (-(-llr - 2.2) / 0.5).exp()));
        (loss, tau)
    }
}

impl Objective for GdeltLinearLearner {
    fn name(&self) -> &str {
        if self.distributed {
            "gdelt_distributed"
        } else {
            "gdelt_single"
        }
    }
    fn space(&self) -> SearchSpace {
        SearchSpace::new(vec![
            continuous("learning_rate", 1e-4, 1.0, Scaling::Logarithmic),
            continuous("wd", 1e-7, 1.0, Scaling::Logarithmic),
            integer("mini_batch_size", 100, 5000, Scaling::Logarithmic),
        ])
        .unwrap()
    }
    fn max_epochs(&self) -> u32 {
        if self.distributed {
            50
        } else {
            30
        }
    }
    fn curve(&self, config: &Config, seed: u64) -> Vec<f64> {
        let (loss, tau) = Self::quality(config);
        let noise = if self.distributed { 0.012 } else { 0.008 };
        let mut rng = Rng::new(seed ^ 0x6DE1);
        converging_curve(self.max_epochs(), 0.95, loss, tau, noise, &mut rng)
    }
    fn epoch_seconds(&self, config: &Config) -> f64 {
        let mbs = get_f(config, "mini_batch_size");
        let base = if self.distributed { 95.0 } else { 40.0 };
        // smaller minibatches ⇒ more updates per epoch ⇒ slower epochs
        base * (1.0 + 300.0 / mbs)
    }
}

// ---------------------------------------------------------------------------
// Figure 5: image classification on Caltech-256 (warm-start experiment)
// ---------------------------------------------------------------------------

/// Task variants of the Caltech-256 workload: reruns share the optimum, the
/// augmented dataset shifts it (correlated but not identical — the transfer
/// structure warm start exploits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaltechVariant {
    /// First tuning job, trained from scratch.
    Base,
    /// Second job: same algorithm and data (paper: best found 0.33 → 0.47).
    Rerun,
    /// Third job: augmented dataset (crop/color/affine), best → 0.52.
    Augmented,
}

/// Image-classifier surrogate with a shared, shifted optimum per variant.
pub struct Caltech256 {
    /// Which of the three sequential tuning tasks this is.
    pub variant: CaltechVariant,
}

impl Caltech256 {
    fn peak(&self) -> f64 {
        match self.variant {
            CaltechVariant::Base | CaltechVariant::Rerun => 0.48,
            CaltechVariant::Augmented => 0.54,
        }
    }
    fn optimum(&self) -> (f64, f64) {
        // (log10 lr*, log10 wd*) — augmented data likes slightly higher lr
        match self.variant {
            CaltechVariant::Base | CaltechVariant::Rerun => (-2.3, -4.0),
            CaltechVariant::Augmented => (-2.0, -4.4),
        }
    }
    /// Noise-free validation accuracy for a configuration.
    pub fn accuracy(&self, config: &Config) -> f64 {
        let llr = get_f(config, "learning_rate").log10();
        let lwd = get_f(config, "weight_decay").log10();
        let opt = config
            .get("optimizer")
            .and_then(Value::as_str)
            .unwrap_or("sgd");
        let (lr0, wd0) = self.optimum();
        let q = (-((llr - lr0) / 1.0).powi(2) - ((lwd - wd0) / 2.2).powi(2)).exp();
        let opt_bonus = if opt == "sgd" { 1.0 } else { 0.93 };
        (self.peak() * q * opt_bonus).max(0.004) // 1/256 floor
    }
}

impl Objective for Caltech256 {
    fn name(&self) -> &str {
        match self.variant {
            CaltechVariant::Base => "caltech_base",
            CaltechVariant::Rerun => "caltech_rerun",
            CaltechVariant::Augmented => "caltech_augmented",
        }
    }
    fn minimize(&self) -> bool {
        false
    }
    fn space(&self) -> SearchSpace {
        SearchSpace::new(vec![
            continuous("learning_rate", 1e-5, 0.5, Scaling::Logarithmic),
            continuous("weight_decay", 1e-7, 1e-2, Scaling::Logarithmic),
            categorical("optimizer", &["sgd", "adam"]),
        ])
        .unwrap()
    }
    fn max_epochs(&self) -> u32 {
        25
    }
    fn curve(&self, config: &Config, seed: u64) -> Vec<f64> {
        let acc = self.accuracy(config);
        let mut rng = Rng::new(seed ^ 0xCA17);
        converging_curve(25, 0.02, acc, 6.0, 0.006, &mut rng)
            .into_iter()
            .map(|v| v.clamp(0.0, 1.0))
            .collect()
    }
    fn epoch_seconds(&self, _config: &Config) -> f64 {
        match self.variant {
            CaltechVariant::Augmented => 260.0, // augmented data is bigger
            _ => 180.0,
        }
    }
}

/// Look up a built-in objective by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn Objective>> {
    Some(match name {
        "branin" => Box::new(Analytic::branin()),
        "hartmann6" => Box::new(Analytic::hartmann6()),
        "rastrigin" => Box::new(Analytic::rastrigin(4)),
        "svm_capacity" => Box::new(SvmCapacity),
        "xgboost_dm" => Box::new(XgboostDirectMarketing),
        "xgboost_dm_linear" => Box::new(XgboostDirectMarketingLinear),
        "gdelt_single" => Box::new(GdeltLinearLearner { distributed: false }),
        "gdelt_distributed" => Box::new(GdeltLinearLearner { distributed: true }),
        "caltech_base" => Box::new(Caltech256 { variant: CaltechVariant::Base }),
        "caltech_rerun" => Box::new(Caltech256 { variant: CaltechVariant::Rerun }),
        "caltech_augmented" => Box::new(Caltech256 { variant: CaltechVariant::Augmented }),
        _ => return None,
    })
}

/// Names of all built-in objectives.
pub fn all_names() -> &'static [&'static str] {
    &[
        "branin",
        "hartmann6",
        "rastrigin",
        "svm_capacity",
        "xgboost_dm",
        "xgboost_dm_linear",
        "gdelt_single",
        "gdelt_distributed",
        "caltech_base",
        "caltech_rerun",
        "caltech_augmented",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(pairs: &[(&str, Value)]) -> Config {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect()
    }

    #[test]
    fn branin_known_minimum() {
        let b = Analytic::branin();
        // (π, 2.275) is a global minimizer with value ≈ 0.397887
        assert!((b.raw(&[std::f64::consts::PI, 2.275]) - 0.397887).abs() < 1e-4);
    }

    #[test]
    fn hartmann6_known_minimum() {
        let h = Analytic::hartmann6();
        let xstar = [0.20169, 0.150011, 0.476874, 0.275332, 0.311652, 0.6573];
        assert!((h.raw(&xstar) - (-3.32237)).abs() < 1e-3);
    }

    #[test]
    fn curves_converge_to_final_value() {
        for name in all_names() {
            let obj = by_name(name).unwrap();
            let mut rng = Rng::new(1);
            let config = obj.space().sample(&mut rng);
            let curve = obj.curve(&config, 7);
            assert_eq!(curve.len(), obj.max_epochs() as usize, "{name}");
            // last value ≈ final_value with a fresh call (determinism)
            assert_eq!(obj.final_value(&config, 7), *curve.last().unwrap(), "{name}");
        }
    }

    #[test]
    fn curves_deterministic_in_seed() {
        let obj = by_name("gdelt_single").unwrap();
        let mut rng = Rng::new(3);
        let config = obj.space().sample(&mut rng);
        assert_eq!(obj.curve(&config, 5), obj.curve(&config, 5));
        assert_ne!(obj.curve(&config, 5), obj.curve(&config, 6));
    }

    #[test]
    fn svm_capacity_shape_matches_fig2() {
        // underfit plateau < peak, peak in mid decades, overfit decline
        let low = SvmCapacity::accuracy(1e-9);
        let mid = SvmCapacity::accuracy(1e3);
        let high = SvmCapacity::accuracy(1e9);
        assert!(low < mid && high < mid, "low={low} mid={mid} high={high}");
        assert!(mid > 0.85);
        assert!(low < 0.60);
    }

    #[test]
    fn xgboost_surface_prefers_small_alpha() {
        let good = XgboostDirectMarketing::score(1e-5, 10.0);
        let bad = XgboostDirectMarketing::score(50.0, 10.0);
        assert!(good + 0.02 < bad, "good={good} bad={bad}");
    }

    #[test]
    fn gdelt_quality_penalizes_extreme_lr() {
        let mk = |lr: f64| {
            cfg(&[
                ("learning_rate", Value::Float(lr)),
                ("wd", Value::Float(1e-5)),
                ("mini_batch_size", Value::Int(1000)),
            ])
        };
        let (good, _) = GdeltLinearLearner::quality(&mk(0.03));
        let (slow, _) = GdeltLinearLearner::quality(&mk(1e-4));
        let (hot, _) = GdeltLinearLearner::quality(&mk(1.0));
        assert!(good < slow && good < hot);
    }

    #[test]
    fn gdelt_small_lr_converges_slowly() {
        let mk = |lr: f64| {
            cfg(&[
                ("learning_rate", Value::Float(lr)),
                ("wd", Value::Float(1e-5)),
                ("mini_batch_size", Value::Int(1000)),
            ])
        };
        let (_, tau_small) = GdeltLinearLearner::quality(&mk(1e-4));
        let (_, tau_big) = GdeltLinearLearner::quality(&mk(0.3));
        assert!(tau_small > 2.0 * tau_big, "{tau_small} vs {tau_big}");
    }

    #[test]
    fn caltech_variants_are_correlated_but_shifted() {
        let base = Caltech256 { variant: CaltechVariant::Base };
        let aug = Caltech256 { variant: CaltechVariant::Augmented };
        let good = cfg(&[
            ("learning_rate", Value::Float(5e-3)),
            ("weight_decay", Value::Float(1e-4)),
            ("optimizer", Value::Cat("sgd".into())),
        ]);
        let bad = cfg(&[
            ("learning_rate", Value::Float(0.5)),
            ("weight_decay", Value::Float(1e-2)),
            ("optimizer", Value::Cat("adam".into())),
        ]);
        // a config good on base is also good on augmented (transferable)
        assert!(base.accuracy(&good) > base.accuracy(&bad));
        assert!(aug.accuracy(&good) > aug.accuracy(&bad));
        // augmented peak is higher (paper: 0.47 → 0.52)
        assert!(aug.peak() > base.peak());
    }

    #[test]
    fn registry_is_complete() {
        for name in all_names() {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("nope").is_none());
    }
}
