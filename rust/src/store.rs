//! Metadata store (§3.2): the DynamoDB stand-in.
//!
//! AMT keeps *only job metadata* here — never customer data (a §3.1
//! security requirement the store enforces by construction: values are
//! JSON job/state records produced by the service itself). Semantics
//! mirror what the backend needs from DynamoDB:
//!
//! * per-item version numbers with **conditional writes** (optimistic
//!   concurrency for the workflow engine's state transitions),
//! * prefix listing (List* APIs) with **pagination** ([`MetadataStore::scan_page`]),
//! * JSON snapshot persistence (durability stand-in).
//!
//! The store is **lock-striped into K shards** hashed by `(table, key)`
//! (DynamoDB's partitioning, scaled down): point operations lock exactly
//! one shard, so the scheduler's worker pool writing on behalf of many
//! concurrent tuning jobs does not serialize on one global mutex. Prefix
//! `scan`/`list_keys` visit the shards one at a time, range-bound each
//! shard's BTreeMap to the prefix instead of cloning whole tables, and
//! merge-sort the per-shard results — output order is identical to the
//! old single-lock store's. Like DynamoDB's Scan, cross-shard reads are
//! *not* point-in-time atomic with respect to concurrent writers (each
//! shard is read consistently, but a writer may land between shards);
//! [`MetadataStore::snapshot`] is the exception — it holds every shard
//! lock and is a true point-in-time capture.

use std::collections::BTreeMap;
use std::ops::Bound;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::durability::wal::{Wal, WalRecord};
use crate::json::{self, Json};

/// Version assigned to an item on each successful write.
pub type Version = u64;

/// Default shard count (lock stripes). Kept modest: each shard is a
/// BTreeMap behind its own mutex, and the workload is dozens-of-writers.
const DEFAULT_SHARDS: usize = 16;

/// Table holding cross-job evaluation-cache entries (DESIGN.md §17). A
/// plain store table, so entries ride the WAL, snapshots, and the
/// distributed capture plane exactly like job records.
pub const EVAL_CACHE_TABLE: &str = "eval_cache";

/// Conditional-write failure.
#[derive(Debug, PartialEq, Eq)]
pub enum StoreError {
    /// Expected version did not match the stored item.
    VersionConflict { expected: Version, actual: Version },
    /// Conditional update of a missing item.
    NotFound,
    /// Snapshot (de)serialization problem.
    Corrupt(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for StoreError {}

/// One lock stripe: its slice of every table, keyed `table → key → item`.
#[derive(Default)]
struct Shard {
    tables: BTreeMap<String, BTreeMap<String, (Version, Json)>>,
}

impl Shard {
    /// Collect `(key, version, value)` for keys with `prefix`, starting
    /// strictly after `start_after` (pagination cursor), at most `limit`
    /// entries. Range-bounded: never walks or clones the whole table.
    fn scan_prefix(
        &self,
        table: &str,
        prefix: &str,
        start_after: Option<&str>,
        limit: usize,
    ) -> Vec<(String, Version, Json)> {
        let Some(t) = self.tables.get(table) else { return Vec::new() };
        // keys sharing a prefix are contiguous in sorted order, so start at
        // max(prefix inclusive, cursor exclusive) and stop at the first
        // non-matching key
        let lower: Bound<&str> = match start_after {
            Some(sa) if sa >= prefix => Bound::Excluded(sa),
            _ => Bound::Included(prefix),
        };
        let mut out = Vec::new();
        for (k, (ver, v)) in t.range::<str, _>((lower, Bound::Unbounded)) {
            if !k.starts_with(prefix) {
                break;
            }
            out.push((k.clone(), *ver, v.clone()));
            if out.len() >= limit {
                break;
            }
        }
        out
    }

    /// Keys-only variant of [`Shard::scan_prefix`]: the paginated scan's
    /// first pass. Values are *not* cloned here — up to `shards × limit`
    /// candidate keys compete for a `limit`-sized page, and cloning the
    /// losers' values (full job records) was pure waste.
    fn scan_keys(
        &self,
        table: &str,
        prefix: &str,
        start_after: Option<&str>,
        limit: usize,
    ) -> Vec<String> {
        let Some(t) = self.tables.get(table) else { return Vec::new() };
        let lower: Bound<&str> = match start_after {
            Some(sa) if sa >= prefix => Bound::Excluded(sa),
            _ => Bound::Included(prefix),
        };
        let mut out = Vec::new();
        for (k, _) in t.range::<str, _>((lower, Bound::Unbounded)) {
            if !k.starts_with(prefix) {
                break;
            }
            out.push(k.clone());
            if out.len() >= limit {
                break;
            }
        }
        out
    }
}

/// One operation of a [`MetadataStore::put_batch`] call. Borrowed
/// fields: the batch path exists to cut per-record overhead, so callers
/// hand in references and only what actually lands in the store (or the
/// WAL) is cloned — exactly the clones the per-record path makes.
pub enum StoreBatchOp<'a> {
    /// Unconditional put — same semantics as [`MetadataStore::put`]
    /// (next version derived from the stored item, WAL-logged).
    Put {
        /// Target table.
        table: &'a str,
        /// Item key.
        key: &'a str,
        /// Value to store.
        value: &'a Json,
    },
    /// Version-preserving raw insert — the snapshot-restore / WAL-replay
    /// path (same semantics as the internal `insert_raw`: bypasses the
    /// WAL and the write counter; recovery must not re-log what it
    /// replays).
    PutRaw {
        /// Target table.
        table: &'a str,
        /// Item key.
        key: &'a str,
        /// Exact version to restore.
        version: Version,
        /// Value to store.
        value: &'a Json,
    },
    /// Delete — same semantics as [`MetadataStore::delete`] (logged only
    /// if the item existed).
    Delete {
        /// Target table.
        table: &'a str,
        /// Item key.
        key: &'a str,
    },
}

impl StoreBatchOp<'_> {
    fn table_key(&self) -> (&str, &str) {
        match self {
            StoreBatchOp::Put { table, key, .. }
            | StoreBatchOp::PutRaw { table, key, .. }
            | StoreBatchOp::Delete { table, key } => (table, key),
        }
    }
}

/// In-memory, thread-safe metadata store with DynamoDB-like semantics,
/// lock-striped into shards hashed by `(table, key)`.
pub struct MetadataStore {
    shards: Vec<Mutex<Shard>>,
    /// This store's metric registry (per-instance). Handles below are
    /// cached into it under `store.*` names.
    telemetry: crate::telemetry::Registry,
    /// Registry name: `store.writes`.
    writes: Arc<crate::telemetry::Counter>,
    /// Shard-guard acquisitions made by mutation paths (put/put_if/
    /// delete/raw inserts/batches). Observability for the throughput
    /// plane: batched application takes each distinct shard lock once
    /// per batch instead of once per record, and the soak bench asserts
    /// the reduction on this counter. Registry name:
    /// `store.shard_lock_acquisitions`.
    shard_locks: Arc<crate::telemetry::Counter>,
    /// Latency of one [`MetadataStore::put_batch`] call (µs). Registry
    /// name: `store.put_batch_us`.
    put_batch_us: Arc<crate::telemetry::Histogram>,
    /// Evaluation-cache lookups that found a recorded outcome (DESIGN.md
    /// §17). Registry name: `cache.hits`.
    cache_hits: Arc<crate::telemetry::Counter>,
    /// Evaluation-cache lookups that missed. Registry name:
    /// `cache.misses`.
    cache_misses: Arc<crate::telemetry::Counter>,
    /// Evaluations launched by jobs with the cache disabled (the lookup
    /// was never made). Registry name: `cache.bypass`.
    cache_bypass: Arc<crate::telemetry::Counter>,
    /// Optional write-ahead log: once attached, every successful mutation
    /// appends a record *inside* its shard critical section, so WAL order
    /// equals application order per key (DESIGN.md §10).
    wal: OnceLock<Arc<Wal>>,
}

impl Default for MetadataStore {
    fn default() -> Self {
        Self::with_shards(DEFAULT_SHARDS)
    }
}

/// FNV-1a over a sequence of byte slices — the shard-routing hash shared
/// by [`MetadataStore`] and [`crate::metrics::MetricsService`].
pub(crate) fn fnv1a(parts: &[&[u8]]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

impl MetadataStore {
    /// Empty store with the default shard count.
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty store with an explicit shard count (≥ 1). `with_shards(1)`
    /// is the old single-lock store — the reference the sharded scan
    /// property tests compare against.
    pub fn with_shards(shards: usize) -> Self {
        let shards = shards.max(1);
        let reg = crate::telemetry::Registry::new();
        MetadataStore {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            writes: reg.counter("store.writes"),
            shard_locks: reg.counter("store.shard_lock_acquisitions"),
            put_batch_us: reg.histogram("store.put_batch_us"),
            cache_hits: reg.counter("cache.hits"),
            cache_misses: reg.counter("cache.misses"),
            cache_bypass: reg.counter("cache.bypass"),
            telemetry: reg,
            wal: OnceLock::new(),
        }
    }

    /// Attach a write-ahead log. Mutations from this point on emit WAL
    /// records; at most one WAL can ever be attached (later calls no-op).
    pub fn attach_wal(&self, wal: Arc<Wal>) {
        let _ = self.wal.set(wal);
    }

    /// Number of lock stripes.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Deterministic FNV-1a shard index of `(table, key)`.
    fn shard_of(&self, table: &str, key: &str) -> usize {
        let h = fnv1a(&[table.as_bytes(), &[0], key.as_bytes()]);
        (h % self.shards.len() as u64) as usize
    }

    /// Acquire one shard guard on a mutation path, counting it in
    /// [`MetadataStore::shard_lock_acquisitions`].
    fn lock_shard(&self, idx: usize) -> MutexGuard<'_, Shard> {
        self.shard_locks.inc();
        self.shards[idx].lock().unwrap()
    }

    /// Shard-guard acquisitions made by mutation paths so far — the
    /// observable [`MetadataStore::put_batch`] reduces (one acquisition
    /// per distinct shard per batch instead of one per record). Shim
    /// over registry metric `store.shard_lock_acquisitions`; prefer
    /// [`MetadataStore::telemetry_metrics`].
    pub fn shard_lock_acquisitions(&self) -> u64 {
        self.shard_locks.get()
    }

    /// Point-in-time snapshot of this store's metric registry (names
    /// under `store.*`, including the `store.put_batch_us` latency
    /// histogram) — one part of
    /// [`crate::api::AmtService::telemetry_snapshot`].
    pub fn telemetry_metrics(&self) -> Vec<crate::telemetry::MetricSnapshot> {
        self.telemetry.snapshot()
    }

    /// Unconditional put; returns the new version.
    pub fn put(&self, table: &str, key: &str, value: Json) -> Version {
        let mut shard = self.lock_shard(self.shard_of(table, key));
        let t = shard.tables.entry(table.to_string()).or_default();
        let next = t.get(key).map(|(v, _)| v + 1).unwrap_or(1);
        if let Some(w) = self.wal.get() {
            w.append(&WalRecord::Put {
                table: table.to_string(),
                key: key.to_string(),
                version: next,
                value: value.clone(),
            });
        }
        t.insert(key.to_string(), (next, value));
        self.writes.inc();
        next
    }

    /// Raw insert with an explicit version: the snapshot-restore / WAL-replay
    /// path. Bypasses the WAL (recovery must not re-log what it replays)
    /// and the write counter.
    pub(crate) fn insert_raw(&self, table: &str, key: &str, version: Version, value: Json) {
        let mut shard = self.lock_shard(self.shard_of(table, key));
        shard
            .tables
            .entry(table.to_string())
            .or_default()
            .insert(key.to_string(), (version, value));
    }

    /// Apply a batch of mutations, locking each distinct shard **once**
    /// and appending all WAL records in one locked extend
    /// ([`Wal::append_batch`]) — observably identical to applying the
    /// ops one at a time in order (same versions, same values, same WAL
    /// bytes when single-threaded), but with one lock acquisition per
    /// shard and one WAL buffer operation per batch instead of one per
    /// record. Returns one version per op, aligned with the input
    /// (`Delete` yields 0).
    ///
    /// Guards are acquired in ascending shard-index order — a subset of
    /// the total order [`MetadataStore::snapshot`] and
    /// `capture_for_snapshot` use for their all-shards acquisition, so
    /// multi-guard holders can never deadlock each other; point ops only
    /// ever hold one guard. The WAL append happens while every touched
    /// shard guard is still held, preserving the invariant that WAL
    /// order equals application order per key.
    pub fn put_batch(&self, ops: &[StoreBatchOp<'_>]) -> Vec<Version> {
        if ops.is_empty() {
            return Vec::new();
        }
        let batch_t0 = crate::telemetry::enabled().then(std::time::Instant::now);
        let idxs: Vec<usize> = ops
            .iter()
            .map(|op| {
                let (table, key) = op.table_key();
                self.shard_of(table, key)
            })
            .collect();
        let mut unique = idxs.clone();
        unique.sort_unstable();
        unique.dedup();
        let mut guards: BTreeMap<usize, MutexGuard<'_, Shard>> =
            unique.iter().map(|&i| (i, self.lock_shard(i))).collect();
        let log = self.wal.get().is_some();
        let mut wal_recs: Vec<WalRecord> = Vec::new();
        let mut versions = Vec::with_capacity(ops.len());
        let mut writes = 0u64;
        for (op, idx) in ops.iter().zip(&idxs) {
            let shard = guards.get_mut(idx).unwrap();
            match op {
                StoreBatchOp::Put { table, key, value } => {
                    let t = shard.tables.entry((*table).to_string()).or_default();
                    let next = t.get(*key).map(|(v, _)| v + 1).unwrap_or(1);
                    if log {
                        wal_recs.push(WalRecord::Put {
                            table: (*table).to_string(),
                            key: (*key).to_string(),
                            version: next,
                            value: (*value).clone(),
                        });
                    }
                    t.insert((*key).to_string(), (next, (*value).clone()));
                    writes += 1;
                    versions.push(next);
                }
                StoreBatchOp::PutRaw { table, key, version, value } => {
                    shard
                        .tables
                        .entry((*table).to_string())
                        .or_default()
                        .insert((*key).to_string(), (*version, (*value).clone()));
                    versions.push(*version);
                }
                StoreBatchOp::Delete { table, key } => {
                    let removed = shard
                        .tables
                        .get_mut(*table)
                        .map(|t| t.remove(*key).is_some())
                        .unwrap_or(false);
                    if removed && log {
                        wal_recs.push(WalRecord::Delete {
                            table: (*table).to_string(),
                            key: (*key).to_string(),
                        });
                    }
                    versions.push(0);
                }
            }
        }
        if let Some(w) = self.wal.get() {
            w.append_batch(&wal_recs);
        }
        drop(guards);
        if writes > 0 {
            self.writes.add(writes);
        }
        if let Some(t0) = batch_t0 {
            self.put_batch_us.record_duration(t0.elapsed());
        }
        versions
    }

    /// Point-in-time capture for per-shard snapshots: clones every
    /// shard's tables while **all** shard guards are held, and reads the
    /// WAL high-water mark under the same guards — no writer can be
    /// inside a critical section at that instant, so the mark exactly
    /// separates contained from not-contained records (DESIGN.md §10).
    pub(crate) fn capture_for_snapshot(
        &self,
    ) -> (Vec<BTreeMap<String, BTreeMap<String, (Version, Json)>>>, u64) {
        let guards: Vec<_> = self.shards.iter().map(|s| s.lock().unwrap()).collect();
        let hwm = self.wal.get().map(|w| w.last_lsn()).unwrap_or(0);
        let data = guards.iter().map(|g| g.tables.clone()).collect();
        (data, hwm)
    }

    /// Conditional put: succeeds only if the stored version matches
    /// `expected` (`None` ⇒ item must not exist). The workflow engine uses
    /// this for exactly-once state transitions.
    pub fn put_if(
        &self,
        table: &str,
        key: &str,
        value: Json,
        expected: Option<Version>,
    ) -> Result<Version, StoreError> {
        let mut shard = self.lock_shard(self.shard_of(table, key));
        let t = shard.tables.entry(table.to_string()).or_default();
        let actual = t.get(key).map(|(v, _)| *v);
        match (expected, actual) {
            (None, None) => {}
            (Some(e), Some(a)) if e == a => {}
            (Some(e), Some(a)) => {
                return Err(StoreError::VersionConflict { expected: e, actual: a })
            }
            (Some(_), None) => return Err(StoreError::NotFound),
            (None, Some(a)) => {
                return Err(StoreError::VersionConflict { expected: 0, actual: a })
            }
        }
        let next = actual.map(|v| v + 1).unwrap_or(1);
        if let Some(w) = self.wal.get() {
            w.append(&WalRecord::Put {
                table: table.to_string(),
                key: key.to_string(),
                version: next,
                value: value.clone(),
            });
        }
        t.insert(key.to_string(), (next, value));
        self.writes.inc();
        Ok(next)
    }

    /// Read an item with its version.
    pub fn get(&self, table: &str, key: &str) -> Option<(Version, Json)> {
        let shard = self.shards[self.shard_of(table, key)].lock().unwrap();
        shard.tables.get(table)?.get(key).cloned()
    }

    /// Delete an item; true if it existed.
    pub fn delete(&self, table: &str, key: &str) -> bool {
        let mut shard = self.lock_shard(self.shard_of(table, key));
        let removed = shard
            .tables
            .get_mut(table)
            .map(|t| t.remove(key).is_some())
            .unwrap_or(false);
        if removed {
            if let Some(w) = self.wal.get() {
                w.append(&WalRecord::Delete {
                    table: table.to_string(),
                    key: key.to_string(),
                });
            }
        }
        removed
    }

    /// Keys with the given prefix (List* API support), in sorted order.
    pub fn list_keys(&self, table: &str, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = Vec::new();
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            keys.extend(shard.scan_keys(table, prefix, None, usize::MAX));
        }
        keys.sort();
        keys
    }

    /// All (key, value) pairs with the given prefix, key-sorted.
    pub fn scan(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        let mut items: Vec<(String, Json)> = Vec::new();
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            items.extend(
                shard
                    .scan_prefix(table, prefix, None, usize::MAX)
                    .into_iter()
                    .map(|(k, _, v)| (k, v)),
            );
        }
        items.sort_by(|a, b| a.0.cmp(&b.0));
        items
    }

    /// Paginated prefix scan: at most `limit` key-sorted (key, value)
    /// pairs with keys strictly greater than `start_after` (pass the last
    /// key of the previous page as the cursor; `None` starts at the
    /// beginning). An empty result means the scan is exhausted. Each shard
    /// lock is held only long enough to pull its own ≤ `limit` candidates.
    ///
    /// Two-pass: pass 1 collects candidate *keys* per shard and elects the
    /// page (sort + truncate); pass 2 re-locks only the shards that won a
    /// slot and clones just the page's values. The old single-pass scan
    /// cloned full values for up to `shards × limit` candidates and then
    /// threw most of them away — on wide tables (job records, metric
    /// streams) that was the dominant cost of every List* call. The scan
    /// is not atomic across passes (point reads never were across shards):
    /// a key deleted between passes is simply dropped from the page.
    pub fn scan_page(
        &self,
        table: &str,
        prefix: &str,
        start_after: Option<&str>,
        limit: usize,
    ) -> Vec<(String, Json)> {
        if limit == 0 {
            return Vec::new();
        }
        // Pass 1: keys only, remembering which shard each came from.
        let mut candidates: Vec<(String, usize)> = Vec::new();
        for (idx, shard) in self.shards.iter().enumerate() {
            let shard = shard.lock().unwrap();
            candidates.extend(
                shard
                    .scan_keys(table, prefix, start_after, limit)
                    .into_iter()
                    .map(|k| (k, idx)),
            );
        }
        candidates.sort_by(|a, b| a.0.cmp(&b.0));
        candidates.truncate(limit);
        // Pass 2: group the winners by shard so each winning shard is
        // locked exactly once, then reassemble in page order.
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (pos, (_, idx)) in candidates.iter().enumerate() {
            by_shard[*idx].push(pos);
        }
        let mut items: Vec<Option<(String, Json)>> = vec![None; candidates.len()];
        for (idx, positions) in by_shard.iter().enumerate() {
            if positions.is_empty() {
                continue;
            }
            let shard = self.shards[idx].lock().unwrap();
            for &pos in positions {
                let key = &candidates[pos].0;
                if let Some((_, v)) = shard.tables.get(table).and_then(|t| t.get(key)) {
                    items[pos] = Some((key.clone(), v.clone()));
                }
            }
        }
        items.into_iter().flatten().collect()
    }

    /// Total successful writes (availability accounting for §6.5). Shim
    /// over registry metric `store.writes`.
    pub fn write_count(&self) -> u64 {
        self.writes.get()
    }

    /// Cross-job evaluation-cache lookup (DESIGN.md §17). Keys are
    /// `"{objective}|{canonical typed-config JSON}"` — built by
    /// [`crate::coordinator::eval_cache_key`] — so one objective's entries
    /// form a contiguous prefix range. Counts `cache.hits`/`cache.misses`.
    pub fn eval_cache_get(&self, key: &str) -> Option<Json> {
        match self.get(EVAL_CACHE_TABLE, key) {
            Some((_, v)) => {
                self.cache_hits.inc();
                Some(v)
            }
            None => {
                self.cache_misses.inc();
                None
            }
        }
    }

    /// Record an evaluation outcome in the cache. Entries are immutable:
    /// the first writer wins (create-if-absent), so a hit is bit-identical
    /// to the *first* run of that config forever — concurrent jobs racing
    /// on the same config cannot flap the recorded series. Returns whether
    /// this call created the entry.
    pub fn eval_cache_put(&self, key: &str, value: Json) -> bool {
        self.put_if(EVAL_CACHE_TABLE, key, None, value).is_ok()
    }

    /// Count an evaluation that skipped the cache entirely (job ran with
    /// the cache disabled). Registry name: `cache.bypass`.
    pub fn eval_cache_bypass(&self) {
        self.cache_bypass.inc();
    }

    /// Cache-hit count so far. Shim over registry metric `cache.hits`.
    pub fn eval_cache_hits(&self) -> u64 {
        self.cache_hits.get()
    }

    /// Cache-miss count so far. Shim over registry metric `cache.misses`.
    pub fn eval_cache_misses(&self) -> u64 {
        self.cache_misses.get()
    }

    /// This store's per-instance metric registry — shared with the
    /// coordinator so strategy-level counters (`strategy.speculation_*`,
    /// `strategy.speculate_us`, `platform.trains`) land in the same
    /// snapshot the service merges into `amt stats`.
    pub(crate) fn registry(&self) -> &crate::telemetry::Registry {
        &self.telemetry
    }

    /// Serialize the whole store to pretty JSON. Shards are merged into
    /// one sorted `table → key` object, so the format is identical across
    /// shard counts (and to the pre-sharding store).
    ///
    /// Service persistence now goes through [`crate::durability`]
    /// (per-shard snapshot files + WAL replay); this merged blob remains
    /// for debugging dumps, state comparison in tests, and the legacy
    /// `restore()` path, which recovery still accepts.
    ///
    /// Unlike prefix scans, a snapshot is a **point-in-time** durability
    /// operation: all shard locks are held simultaneously (acquired in
    /// index order; point ops only ever hold one, so this cannot
    /// deadlock), so a restored snapshot is always a state that actually
    /// existed.
    pub fn snapshot(&self) -> String {
        let guards: Vec<_> = self.shards.iter().map(|s| s.lock().unwrap()).collect();
        let mut merged: BTreeMap<String, BTreeMap<String, (Version, Json)>> = BTreeMap::new();
        for shard in &guards {
            for (name, t) in shard.tables.iter() {
                let m = merged.entry(name.clone()).or_default();
                for (k, item) in t {
                    m.insert(k.clone(), item.clone());
                }
            }
        }
        drop(guards);
        let mut obj = BTreeMap::new();
        for (name, t) in merged {
            let mut items = BTreeMap::new();
            for (k, (ver, v)) in t {
                items.insert(
                    k,
                    Json::obj(vec![("version", Json::Num(ver as f64)), ("value", v)]),
                );
            }
            obj.insert(name, Json::Obj(items));
        }
        Json::Obj(obj).to_pretty()
    }

    /// Restore a snapshot produced by [`MetadataStore::snapshot`].
    pub fn restore(text: &str) -> Result<MetadataStore, StoreError> {
        let parsed = json::parse(text).map_err(|e| StoreError::Corrupt(e.to_string()))?;
        let obj = parsed
            .as_obj()
            .ok_or_else(|| StoreError::Corrupt("top level must be object".into()))?;
        let store = MetadataStore::new();
        for (name, items) in obj {
            let items = items
                .as_obj()
                .ok_or_else(|| StoreError::Corrupt("table must be object".into()))?;
            for (k, entry) in items {
                let ver = entry
                    .get("version")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| StoreError::Corrupt("missing version".into()))?;
                let value = entry
                    .get("value")
                    .cloned()
                    .ok_or_else(|| StoreError::Corrupt("missing value".into()))?;
                store.insert_raw(name, k, ver as Version, value);
            }
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip_and_versions() {
        let s = MetadataStore::new();
        let v1 = s.put("jobs", "a", Json::Num(1.0));
        let v2 = s.put("jobs", "a", Json::Num(2.0));
        assert_eq!((v1, v2), (1, 2));
        let (ver, val) = s.get("jobs", "a").unwrap();
        assert_eq!(ver, 2);
        assert_eq!(val, Json::Num(2.0));
        assert!(s.get("jobs", "b").is_none());
        assert!(s.get("other", "a").is_none());
    }

    #[test]
    fn conditional_writes_enforce_versions() {
        let s = MetadataStore::new();
        assert_eq!(s.put_if("t", "k", Json::Bool(true), None), Ok(1));
        // create-if-absent fails on existing
        assert!(matches!(
            s.put_if("t", "k", Json::Bool(false), None),
            Err(StoreError::VersionConflict { .. })
        ));
        // stale version fails
        s.put("t", "k", Json::Num(2.0));
        assert!(matches!(
            s.put_if("t", "k", Json::Num(3.0), Some(1)),
            Err(StoreError::VersionConflict { expected: 1, actual: 2 })
        ));
        // matching version succeeds
        assert_eq!(s.put_if("t", "k", Json::Num(3.0), Some(2)), Ok(3));
        // conditional update of missing item
        assert_eq!(
            s.put_if("t", "missing", Json::Null, Some(1)),
            Err(StoreError::NotFound)
        );
    }

    #[test]
    fn list_and_scan_by_prefix() {
        let s = MetadataStore::new();
        s.put("jobs", "tune-1", Json::Num(1.0));
        s.put("jobs", "tune-2", Json::Num(2.0));
        s.put("jobs", "train-1", Json::Num(3.0));
        assert_eq!(s.list_keys("jobs", "tune-"), vec!["tune-1", "tune-2"]);
        assert_eq!(s.scan("jobs", "train-").len(), 1);
        assert!(s.list_keys("nope", "").is_empty());
    }

    #[test]
    fn scan_page_paginates_in_key_order() {
        let s = MetadataStore::new();
        for i in 0..25 {
            s.put("jobs", &format!("run-{i:03}"), Json::Num(i as f64));
        }
        s.put("jobs", "other", Json::Null);
        let mut seen = Vec::new();
        let mut cursor: Option<String> = None;
        loop {
            let page = s.scan_page("jobs", "run-", cursor.as_deref(), 7);
            if page.is_empty() {
                break;
            }
            assert!(page.len() <= 7);
            cursor = Some(page.last().unwrap().0.clone());
            seen.extend(page.into_iter().map(|(k, _)| k));
        }
        let full: Vec<String> = s.scan("jobs", "run-").into_iter().map(|(k, _)| k).collect();
        assert_eq!(seen, full);
        assert_eq!(seen.len(), 25);
        // limit 0 and exhausted cursors return empty pages
        assert!(s.scan_page("jobs", "run-", None, 0).is_empty());
        assert!(s.scan_page("jobs", "run-", Some("run-999"), 5).is_empty());
        // missing tables scan empty
        assert!(s.scan_page("nope", "", None, 5).is_empty());
    }

    #[test]
    fn scan_page_matches_full_scan_across_shard_counts() {
        // The two-pass page (keys elected first, values cloned second)
        // must be observably identical to slicing the full scan.
        for shards in [1, 3, 16] {
            let s = MetadataStore::with_shards(shards);
            for i in 0..33 {
                s.put(
                    "jobs",
                    &format!("run-{i:03}"),
                    Json::obj(vec![("i", Json::Num(i as f64))]),
                );
            }
            let full = s.scan("jobs", "run-");
            assert_eq!(s.scan_page("jobs", "run-", None, 10), full[..10].to_vec());
            assert_eq!(
                s.scan_page("jobs", "run-", Some("run-009"), 10),
                full[10..20].to_vec()
            );
            assert_eq!(s.scan_page("jobs", "run-", None, 100), full);
        }
    }

    #[test]
    fn eval_cache_is_immutable_and_counts() {
        let s = MetadataStore::new();
        assert_eq!(s.eval_cache_get("obj|{\"x\":1}"), None);
        assert_eq!(s.eval_cache_misses(), 1);
        assert!(s.eval_cache_put("obj|{\"x\":1}", Json::Num(0.25)));
        // first writer wins: a second put with a different value no-ops
        assert!(!s.eval_cache_put("obj|{\"x\":1}", Json::Num(9.0)));
        assert_eq!(s.eval_cache_get("obj|{\"x\":1}"), Some(Json::Num(0.25)));
        assert_eq!(s.eval_cache_hits(), 1);
        s.eval_cache_bypass();
        let names: Vec<String> = s
            .telemetry_metrics()
            .into_iter()
            .map(|m| m.name)
            .collect();
        for n in ["cache.hits", "cache.misses", "cache.bypass"] {
            assert!(names.iter().any(|x| x == n), "missing metric {n}");
        }
        // entries live in a plain table ⇒ snapshot/restore carries them
        let r = MetadataStore::restore(&s.snapshot()).unwrap();
        assert_eq!(
            r.get(EVAL_CACHE_TABLE, "obj|{\"x\":1}").unwrap().1,
            Json::Num(0.25)
        );
    }

    #[test]
    fn shard_counts_do_not_change_observable_behavior() {
        for shards in [1, 3, 16] {
            let s = MetadataStore::with_shards(shards);
            assert_eq!(s.shard_count(), shards);
            for i in 0..40 {
                s.put("t", &format!("k-{i:02}"), Json::Num(i as f64));
            }
            s.put("u", "k-00", Json::Bool(true)); // same key, other table
            assert_eq!(s.list_keys("t", "k-").len(), 40);
            assert_eq!(s.scan("t", "k-1").len(), 10);
            assert_eq!(s.get("t", "k-07").unwrap().1, Json::Num(7.0));
            assert_eq!(s.get("u", "k-00").unwrap().1, Json::Bool(true));
            // sorted output regardless of shard layout
            let keys = s.list_keys("t", "");
            let mut sorted = keys.clone();
            sorted.sort();
            assert_eq!(keys, sorted);
        }
    }

    #[test]
    fn snapshot_identical_across_shard_counts() {
        let fill = |s: &MetadataStore| {
            for i in 0..30 {
                s.put("a", &format!("x{i}"), Json::Num(i as f64));
                s.put("b", &format!("y{i}"), Json::Str(format!("v{i}")));
            }
        };
        let one = MetadataStore::with_shards(1);
        let many = MetadataStore::with_shards(8);
        fill(&one);
        fill(&many);
        assert_eq!(one.snapshot(), many.snapshot());
    }

    #[test]
    fn delete_removes() {
        let s = MetadataStore::new();
        s.put("t", "k", Json::Null);
        assert!(s.delete("t", "k"));
        assert!(!s.delete("t", "k"));
        assert!(s.get("t", "k").is_none());
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let s = MetadataStore::new();
        s.put("jobs", "a", Json::obj(vec![("x", Json::Num(1.5))]));
        s.put("jobs", "b", Json::Str("hello \"world\"".into()));
        s.put("state", "a", Json::Arr(vec![Json::Bool(true), Json::Null]));
        s.put("jobs", "a", Json::obj(vec![("x", Json::Num(2.5))])); // bump version
        let snap = s.snapshot();
        let r = MetadataStore::restore(&snap).unwrap();
        assert_eq!(r.get("jobs", "a"), s.get("jobs", "a"));
        assert_eq!(r.get("jobs", "b"), s.get("jobs", "b"));
        assert_eq!(r.get("state", "a"), s.get("state", "a"));
        // versions preserved ⇒ conditional writes keep working post-restore
        assert_eq!(r.get("jobs", "a").unwrap().0, 2);
    }

    #[test]
    fn restore_rejects_garbage() {
        assert!(MetadataStore::restore("not json").is_err());
        assert!(MetadataStore::restore("[1,2]").is_err());
        assert!(MetadataStore::restore(r#"{"t": {"k": {"value": 1}}}"#).is_err());
    }

    /// Regression: a snapshot must be a state that actually existed. A
    /// single writer bumps key `alpha` then key `beta` (hashed to
    /// different shards with high probability); a snapshot that visited
    /// shards without holding all guards could observe `beta > alpha` or
    /// `alpha - beta > 1`, neither of which ever exists.
    #[test]
    fn snapshot_is_point_in_time_under_concurrent_writers() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let s = Arc::new(MetadataStore::new());
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let s = Arc::clone(&s);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    i += 1;
                    s.put("inv", "alpha", Json::Num(i as f64));
                    s.put("inv", "beta", Json::Num(i as f64));
                }
            })
        };
        for _ in 0..200 {
            let snap = s.snapshot();
            let r = MetadataStore::restore(&snap).unwrap();
            let a = r.get("inv", "alpha").map(|(_, v)| v.as_f64().unwrap()).unwrap_or(0.0);
            let b = r.get("inv", "beta").map(|(_, v)| v.as_f64().unwrap()).unwrap_or(0.0);
            assert!(a >= b, "snapshot saw beta={b} ahead of alpha={a}");
            assert!(a - b <= 1.0, "snapshot skew: alpha={a} beta={b}");
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
    }

    #[test]
    fn attached_wal_records_every_mutation_in_order() {
        use crate::durability::wal::{Wal, WalRecord};
        use std::sync::Arc;
        let dir = std::env::temp_dir().join(format!(
            "amt-store-wal-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let s = MetadataStore::new();
        s.put("t", "pre-wal", Json::Null); // before attach: unlogged
        s.attach_wal(Arc::new(Wal::create(&dir).unwrap()));
        s.put("t", "k", Json::Num(1.0));
        s.put_if("t", "k", Json::Num(2.0), Some(1)).unwrap();
        assert!(s.put_if("t", "k", Json::Num(9.0), Some(7)).is_err()); // unlogged
        s.delete("t", "k");
        assert!(!s.delete("t", "k")); // no-op delete: unlogged
        s.wal.get().unwrap().commit().unwrap();
        let scan = Wal::scan(&dir.join(crate::durability::wal::WAL_FILE)).unwrap();
        let recs: Vec<&WalRecord> = scan.records.iter().map(|(_, r)| r).collect();
        assert_eq!(recs.len(), 3);
        assert!(matches!(recs[0], WalRecord::Put { version: 1, .. }));
        assert!(matches!(recs[1], WalRecord::Put { version: 2, .. }));
        assert!(matches!(recs[2], WalRecord::Delete { .. }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `put_batch` must be observably identical to per-record ops: same
    /// versions returned, same store contents, same WAL bytes — with one
    /// shard-lock acquisition per distinct shard instead of one per op.
    #[test]
    fn put_batch_matches_per_record_reference() {
        use crate::durability::wal::Wal;
        let tmp = |tag: &str| {
            std::env::temp_dir().join(format!(
                "amt-store-batch-{tag}-{}-{}",
                std::process::id(),
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .unwrap()
                    .as_nanos()
            ))
        };
        let (dir_a, dir_b) = (tmp("a"), tmp("b"));
        let (one, batch) = (MetadataStore::new(), MetadataStore::new());
        one.attach_wal(Arc::new(Wal::create(&dir_a).unwrap()));
        batch.attach_wal(Arc::new(Wal::create(&dir_b).unwrap()));
        let vals: Vec<Json> = (0..24).map(|i| Json::Num(i as f64 * 0.5)).collect();
        // per-record reference: re-puts (version bumps), deletes of
        // existing and missing keys
        let mut ref_versions = Vec::new();
        for i in 0..24 {
            ref_versions.push(one.put("t", &format!("k{}", i % 9), vals[i].clone()));
        }
        ref_versions.push(if one.delete("t", "k0") { 0 } else { 0 });
        one.delete("t", "no-such-key");
        // the same sequence as one batch
        let mut ops: Vec<StoreBatchOp<'_>> = Vec::new();
        let keys: Vec<String> = (0..24).map(|i| format!("k{}", i % 9)).collect();
        for i in 0..24 {
            ops.push(StoreBatchOp::Put { table: "t", key: &keys[i], value: &vals[i] });
        }
        ops.push(StoreBatchOp::Delete { table: "t", key: "k0" });
        ops.push(StoreBatchOp::Delete { table: "t", key: "no-such-key" });
        let before = batch.shard_lock_acquisitions();
        let versions = batch.put_batch(&ops);
        let took = batch.shard_lock_acquisitions() - before;
        assert!(took <= batch.shard_count() as u64, "batch took {took} shard locks");
        assert!(took < ops.len() as u64);
        assert_eq!(&versions[..24], &ref_versions[..24]);
        assert_eq!(versions[24], 0);
        assert_eq!(versions[25], 0);
        assert_eq!(one.snapshot(), batch.snapshot(), "store contents diverged");
        assert_eq!(one.write_count(), batch.write_count());
        one.wal.get().unwrap().commit().unwrap();
        batch.wal.get().unwrap().commit().unwrap();
        assert_eq!(
            std::fs::read(one.wal.get().unwrap().path()).unwrap(),
            std::fs::read(batch.wal.get().unwrap().path()).unwrap(),
            "WAL bytes must be identical"
        );
        // PutRaw restores exact versions without logging (replay path)
        let raw = MetadataStore::new();
        raw.attach_wal(Arc::new(Wal::create(&tmp("raw")).unwrap()));
        raw.put_batch(&[StoreBatchOp::PutRaw {
            table: "t",
            key: "r",
            version: 7,
            value: &Json::Null,
        }]);
        assert_eq!(raw.get("t", "r").unwrap().0, 7);
        assert_eq!(raw.write_count(), 0);
        assert_eq!(raw.wal.get().unwrap().last_lsn(), 0, "raw inserts are unlogged");
        assert!(batch.put_batch(&[]).is_empty());
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn concurrent_writers_are_serialized() {
        use std::sync::Arc;
        let s = Arc::new(MetadataStore::new());
        let mut handles = Vec::new();
        for i in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for j in 0..50 {
                    s.put("t", &format!("k{i}-{j}"), Json::Num(j as f64));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.list_keys("t", "k").len(), 200);
        assert_eq!(s.write_count(), 200);
    }
}
