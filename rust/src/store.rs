//! Metadata store (§3.2): the DynamoDB stand-in.
//!
//! AMT keeps *only job metadata* here — never customer data (a §3.1
//! security requirement the store enforces by construction: values are
//! JSON job/state records produced by the service itself). Semantics
//! mirror what the backend needs from DynamoDB:
//!
//! * per-item version numbers with **conditional writes** (optimistic
//!   concurrency for the workflow engine's state transitions),
//! * prefix listing (List* APIs),
//! * JSON snapshot persistence (durability stand-in).
//!
//! The store is `Sync`; the API layer shares it across tuning-job worker
//! threads.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::json::{self, Json};

/// Version assigned to an item on each successful write.
pub type Version = u64;

/// Conditional-write failure.
#[derive(Debug, PartialEq, Eq)]
pub enum StoreError {
    /// Expected version did not match the stored item.
    VersionConflict { expected: Version, actual: Version },
    /// Conditional update of a missing item.
    NotFound,
    /// Snapshot (de)serialization problem.
    Corrupt(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for StoreError {}

#[derive(Default)]
struct Table {
    items: BTreeMap<String, (Version, Json)>,
}

/// In-memory, thread-safe metadata store with DynamoDB-like semantics.
#[derive(Default)]
pub struct MetadataStore {
    tables: Mutex<BTreeMap<String, Table>>,
    writes: std::sync::atomic::AtomicU64,
}

impl MetadataStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Unconditional put; returns the new version.
    pub fn put(&self, table: &str, key: &str, value: Json) -> Version {
        let mut tables = self.tables.lock().unwrap();
        let t = tables.entry(table.to_string()).or_default();
        let next = t.items.get(key).map(|(v, _)| v + 1).unwrap_or(1);
        t.items.insert(key.to_string(), (next, value));
        self.writes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        next
    }

    /// Conditional put: succeeds only if the stored version matches
    /// `expected` (`None` ⇒ item must not exist). The workflow engine uses
    /// this for exactly-once state transitions.
    pub fn put_if(
        &self,
        table: &str,
        key: &str,
        value: Json,
        expected: Option<Version>,
    ) -> Result<Version, StoreError> {
        let mut tables = self.tables.lock().unwrap();
        let t = tables.entry(table.to_string()).or_default();
        let actual = t.items.get(key).map(|(v, _)| *v);
        match (expected, actual) {
            (None, None) => {}
            (Some(e), Some(a)) if e == a => {}
            (Some(e), Some(a)) => {
                return Err(StoreError::VersionConflict { expected: e, actual: a })
            }
            (Some(_), None) => return Err(StoreError::NotFound),
            (None, Some(a)) => {
                return Err(StoreError::VersionConflict { expected: 0, actual: a })
            }
        }
        let next = actual.map(|v| v + 1).unwrap_or(1);
        t.items.insert(key.to_string(), (next, value));
        self.writes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(next)
    }

    /// Read an item with its version.
    pub fn get(&self, table: &str, key: &str) -> Option<(Version, Json)> {
        let tables = self.tables.lock().unwrap();
        tables.get(table)?.items.get(key).cloned()
    }

    /// Delete an item; true if it existed.
    pub fn delete(&self, table: &str, key: &str) -> bool {
        let mut tables = self.tables.lock().unwrap();
        tables
            .get_mut(table)
            .map(|t| t.items.remove(key).is_some())
            .unwrap_or(false)
    }

    /// Keys with the given prefix (List* API support).
    pub fn list_keys(&self, table: &str, prefix: &str) -> Vec<String> {
        let tables = self.tables.lock().unwrap();
        tables
            .get(table)
            .map(|t| {
                t.items
                    .keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// All (key, value) pairs with the given prefix.
    pub fn scan(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        let tables = self.tables.lock().unwrap();
        tables
            .get(table)
            .map(|t| {
                t.items
                    .iter()
                    .filter(|(k, _)| k.starts_with(prefix))
                    .map(|(k, (_, v))| (k.clone(), v.clone()))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Total successful writes (availability accounting for §6.5).
    pub fn write_count(&self) -> u64 {
        self.writes.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Serialize the whole store to pretty JSON.
    pub fn snapshot(&self) -> String {
        let tables = self.tables.lock().unwrap();
        let mut obj = BTreeMap::new();
        for (name, t) in tables.iter() {
            let mut items = BTreeMap::new();
            for (k, (ver, v)) in &t.items {
                items.insert(
                    k.clone(),
                    Json::obj(vec![("version", Json::Num(*ver as f64)), ("value", v.clone())]),
                );
            }
            obj.insert(name.clone(), Json::Obj(items));
        }
        Json::Obj(obj).to_pretty()
    }

    /// Restore a snapshot produced by [`MetadataStore::snapshot`].
    pub fn restore(text: &str) -> Result<MetadataStore, StoreError> {
        let parsed = json::parse(text).map_err(|e| StoreError::Corrupt(e.to_string()))?;
        let obj = parsed
            .as_obj()
            .ok_or_else(|| StoreError::Corrupt("top level must be object".into()))?;
        let store = MetadataStore::new();
        {
            let mut tables = store.tables.lock().unwrap();
            for (name, items) in obj {
                let mut table = Table::default();
                let items = items
                    .as_obj()
                    .ok_or_else(|| StoreError::Corrupt("table must be object".into()))?;
                for (k, entry) in items {
                    let ver = entry
                        .get("version")
                        .and_then(Json::as_i64)
                        .ok_or_else(|| StoreError::Corrupt("missing version".into()))?;
                    let value = entry
                        .get("value")
                        .cloned()
                        .ok_or_else(|| StoreError::Corrupt("missing value".into()))?;
                    table.items.insert(k.clone(), (ver as Version, value));
                }
                tables.insert(name.clone(), table);
            }
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip_and_versions() {
        let s = MetadataStore::new();
        let v1 = s.put("jobs", "a", Json::Num(1.0));
        let v2 = s.put("jobs", "a", Json::Num(2.0));
        assert_eq!((v1, v2), (1, 2));
        let (ver, val) = s.get("jobs", "a").unwrap();
        assert_eq!(ver, 2);
        assert_eq!(val, Json::Num(2.0));
        assert!(s.get("jobs", "b").is_none());
        assert!(s.get("other", "a").is_none());
    }

    #[test]
    fn conditional_writes_enforce_versions() {
        let s = MetadataStore::new();
        assert_eq!(s.put_if("t", "k", Json::Bool(true), None), Ok(1));
        // create-if-absent fails on existing
        assert!(matches!(
            s.put_if("t", "k", Json::Bool(false), None),
            Err(StoreError::VersionConflict { .. })
        ));
        // stale version fails
        s.put("t", "k", Json::Num(2.0));
        assert!(matches!(
            s.put_if("t", "k", Json::Num(3.0), Some(1)),
            Err(StoreError::VersionConflict { expected: 1, actual: 2 })
        ));
        // matching version succeeds
        assert_eq!(s.put_if("t", "k", Json::Num(3.0), Some(2)), Ok(3));
        // conditional update of missing item
        assert_eq!(
            s.put_if("t", "missing", Json::Null, Some(1)),
            Err(StoreError::NotFound)
        );
    }

    #[test]
    fn list_and_scan_by_prefix() {
        let s = MetadataStore::new();
        s.put("jobs", "tune-1", Json::Num(1.0));
        s.put("jobs", "tune-2", Json::Num(2.0));
        s.put("jobs", "train-1", Json::Num(3.0));
        assert_eq!(s.list_keys("jobs", "tune-"), vec!["tune-1", "tune-2"]);
        assert_eq!(s.scan("jobs", "train-").len(), 1);
        assert!(s.list_keys("nope", "").is_empty());
    }

    #[test]
    fn delete_removes() {
        let s = MetadataStore::new();
        s.put("t", "k", Json::Null);
        assert!(s.delete("t", "k"));
        assert!(!s.delete("t", "k"));
        assert!(s.get("t", "k").is_none());
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let s = MetadataStore::new();
        s.put("jobs", "a", Json::obj(vec![("x", Json::Num(1.5))]));
        s.put("jobs", "b", Json::Str("hello \"world\"".into()));
        s.put("state", "a", Json::Arr(vec![Json::Bool(true), Json::Null]));
        s.put("jobs", "a", Json::obj(vec![("x", Json::Num(2.5))])); // bump version
        let snap = s.snapshot();
        let r = MetadataStore::restore(&snap).unwrap();
        assert_eq!(r.get("jobs", "a"), s.get("jobs", "a"));
        assert_eq!(r.get("jobs", "b"), s.get("jobs", "b"));
        assert_eq!(r.get("state", "a"), s.get("state", "a"));
        // versions preserved ⇒ conditional writes keep working post-restore
        assert_eq!(r.get("jobs", "a").unwrap().0, 2);
    }

    #[test]
    fn restore_rejects_garbage() {
        assert!(MetadataStore::restore("not json").is_err());
        assert!(MetadataStore::restore("[1,2]").is_err());
        assert!(MetadataStore::restore(r#"{"t": {"k": {"value": 1}}}"#).is_err());
    }

    #[test]
    fn concurrent_writers_are_serialized() {
        use std::sync::Arc;
        let s = Arc::new(MetadataStore::new());
        let mut handles = Vec::new();
        for i in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for j in 0..50 {
                    s.put("t", &format!("k{i}-{j}"), Json::Num(j as f64));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.list_keys("t", "k").len(), 200);
        assert_eq!(s.write_count(), 200);
    }
}
