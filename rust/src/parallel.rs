//! Order-stable data parallelism on std scoped threads, plus the
//! long-lived [`WorkerPool`] the tuning scheduler runs on.
//!
//! The offline vendored crate set does not include rayon, so the hot path
//! parallelizes with `std::thread::scope` instead: items are split into
//! contiguous chunks, one worker per chunk, and results are re-assembled
//! in index order. Every item is computed by a pure function of its input,
//! and all reductions downstream consume the results in index order, so
//! parallel output is bit-identical to sequential output regardless of
//! worker count (DESIGN.md §5 "parallelism & determinism").
//!
//! Worker count defaults to the machine's available parallelism and can be
//! pinned with `AMT_THREADS` (e.g. `AMT_THREADS=1` forces the sequential
//! path for A/B determinism checks and profiling).

use std::sync::{Arc, OnceLock};

/// Maximum worker threads for data-parallel regions (≥ 1).
pub fn max_threads() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        if let Ok(v) = std::env::var("AMT_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Map `f` over `items` in parallel, preserving item order in the output.
///
/// Chunked static scheduling: each worker owns one contiguous chunk, and
/// the chunks are re-joined in order, so the result is exactly
/// `items.iter().map(f).collect()` — independent of thread count.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let workers = max_threads().min(n);
    if workers <= 1 {
        return items.iter().map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|c| scope.spawn(move || c.iter().map(f).collect::<Vec<R>>()))
            .collect();
        for h in handles {
            out.extend(h.join().expect("parallel worker panicked"));
        }
    });
    out
}

/// A fixed pool of named, long-lived OS threads.
///
/// Unlike [`par_map`] (fork/join over one batch), a `WorkerPool` runs one
/// caller-supplied worker function per thread for the pool's whole
/// lifetime — the execution substrate of [`crate::scheduler::Scheduler`],
/// which multiplexes N tuning jobs over `workers` threads instead of
/// spawning a thread per job. The worker function receives its worker
/// index and is expected to loop until an external shutdown signal.
pub struct WorkerPool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers.max(1)` threads named `<name>-<i>`, each running
    /// `f(i)` to completion.
    pub fn spawn<F>(name: &str, workers: usize, f: F) -> WorkerPool
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles = (0..workers.max(1))
            .map(|i| {
                let f = Arc::clone(&f);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || f(i))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        WorkerPool { handles }
    }

    /// Number of threads in the pool.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    /// True if the pool has no threads (never the case after `spawn`).
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Block until every worker function returns. Panics from workers are
    /// propagated.
    pub fn join(self) {
        for h in self.handles {
            h.join().expect("pool worker panicked");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let seq: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        let par = par_map(&items, |&x| x * x + 1);
        assert_eq!(seq, par);
    }

    #[test]
    fn par_map_handles_small_inputs() {
        assert_eq!(par_map::<u32, u32, _>(&[], |&x| x), Vec::<u32>::new());
        assert_eq!(par_map(&[7], |&x: &u32| x + 1), vec![8]);
        assert_eq!(par_map(&[1, 2], |&x: &u32| x * 10), vec![10, 20]);
    }

    #[test]
    fn par_map_float_reduction_is_deterministic() {
        // identical bits across repeated runs (order-stable reduction)
        let items: Vec<f64> = (0..257).map(|i| (i as f64).sin()).collect();
        let a: f64 = par_map(&items, |&x| x.exp()).iter().sum();
        let b: f64 = par_map(&items, |&x| x.exp()).iter().sum();
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn max_threads_is_at_least_one() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn worker_pool_runs_every_worker_and_joins() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        let pool = WorkerPool::spawn("test-pool", 4, move |i| {
            c.fetch_add(i + 1, Ordering::SeqCst);
        });
        assert_eq!(pool.len(), 4);
        pool.join();
        // 1 + 2 + 3 + 4
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
