//! Workflow engine (§3.2): the Step-Functions/Lambda stand-in.
//!
//! "AWS Cloudwatch Events, AWS Step Functions and AWS Lambda are used in
//! the AMT workflows engine, which is responsible for kicking off the
//! evaluation of hyperparameter configurations ..., starting training jobs,
//! tracking their progress and repeating the process until the stopping
//! criterion is met." This module provides that engine: a named-state
//! machine with per-state **retry policies with exponential backoff**
//! (§3.3's "built-in retry mechanism to guarantee robustness") executing on
//! the virtual clock, recording a full execution history for the
//! Describe API.
//!
//! Executions are **resumable**: [`StateMachine::begin`] creates an
//! [`ExecutionState`] cursor and [`StateMachine::step`] advances it by one
//! handler invocation, returning control to the caller after every state.
//! `Wait` transitions and retry backoffs *park* the execution
//! ([`StepOutcome::Parked`]) instead of looping, so a scheduler can
//! multiplex many executions over a bounded worker pool and order parked
//! ones on a virtual-time event heap ([`crate::scheduler`]).
//! [`StateMachine::execute`] is the run-to-completion convenience wrapper
//! over the same step loop.

use crate::json::Json;

/// Outcome returned by a state handler.
#[derive(Clone, Debug, PartialEq)]
pub enum Transition {
    /// Move to the named state.
    Next(String),
    /// Sleep `seconds` of virtual time, then move to the named state.
    Wait { seconds: f64, then: String },
    /// Terminal success.
    Succeed,
    /// Terminal failure (unretryable).
    Fail(String),
    /// Transient error: retry this state per its policy.
    Retryable(String),
}

/// Retry policy for a state (Step Functions' `Retry` block).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Attempts including the first (1 = no retries).
    pub max_attempts: u32,
    /// First backoff interval (virtual seconds).
    pub interval_seconds: f64,
    /// Backoff multiplier per retry.
    pub backoff_rate: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, interval_seconds: 5.0, backoff_rate: 2.0 }
    }
}

impl RetryPolicy {
    /// No retries at all.
    pub fn none() -> Self {
        RetryPolicy { max_attempts: 1, interval_seconds: 0.0, backoff_rate: 1.0 }
    }
}

/// One state of the machine.
pub struct State<C> {
    /// Unique state name.
    pub name: String,
    /// Handler invoked on entry; receives the shared context.
    pub handler: Box<dyn FnMut(&mut C, f64) -> Transition + Send>,
    /// Retry policy applied to `Transition::Retryable`.
    pub retry: RetryPolicy,
}

/// A recorded step of an execution (Describe API material).
#[derive(Clone, Debug, PartialEq)]
pub struct StepRecord {
    /// State name.
    pub state: String,
    /// 1-based attempt number.
    pub attempt: u32,
    /// Virtual time the attempt started.
    pub time: f64,
    /// Stringified outcome.
    pub outcome: String,
}

/// Terminal result of an execution.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecutionStatus {
    /// Reached `Succeed`.
    Succeeded,
    /// Reached `Fail` or exhausted retries.
    Failed(String),
}

/// Full execution report.
#[derive(Clone, Debug)]
pub struct Execution {
    /// Terminal status.
    pub status: ExecutionStatus,
    /// Ordered step history.
    pub steps: Vec<StepRecord>,
    /// Virtual time at completion.
    pub finished_at: f64,
}

impl Execution {
    /// Total retries performed across all states (steps that were re-attempts).
    pub fn total_retries(&self) -> u32 {
        self.steps.iter().filter(|s| s.attempt > 1).count() as u32
    }
}

/// Outcome of advancing an execution by one [`StateMachine::step`].
#[derive(Debug)]
pub enum StepOutcome {
    /// The next state is immediately runnable; step again when convenient.
    Ready,
    /// The execution parked itself for `seconds` of virtual time (a `Wait`
    /// transition or a retry backoff). The cursor's clock has already been
    /// advanced; a scheduler may use `seconds` to order parked executions.
    Parked {
        /// Virtual seconds of the wait that just started.
        seconds: f64,
    },
    /// The execution reached a terminal state.
    Done(Execution),
}

/// Resumable cursor over one execution of a [`StateMachine`].
///
/// Owns everything that used to live on `execute`'s stack — current state,
/// attempt counter, step history and the virtual clock — so an execution
/// can be advanced one state at a time and suspended in between.
pub struct ExecutionState {
    current: usize,
    attempt: u32,
    transitions: usize,
    steps: Vec<StepRecord>,
    /// Virtual clock local to this execution (seconds).
    pub clock: f64,
    finished: Option<Execution>,
}

impl ExecutionState {
    /// True once the execution reached a terminal state.
    pub fn is_finished(&self) -> bool {
        self.finished.is_some()
    }

    /// Terminal status and finish time, once finished. The full step
    /// history is carried by the [`StepOutcome::Done`] of the step that
    /// reached the terminal state, not retained here.
    pub fn result(&self) -> Option<&Execution> {
        self.finished.as_ref()
    }

    /// JSON wire form of the cursor — what a [`crate::durability`] WAL
    /// checkpoint carries. The step history is deliberately *not*
    /// serialized (it can run to `max_transitions` records and is
    /// delivered exactly once with the finishing step); only its length
    /// is recorded, so a checkpoint stays O(1) no matter how long the
    /// execution has run. Recovery uses these cursors for progress
    /// reporting — resumption itself replays deterministically.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("current", Json::Num(self.current as f64)),
            ("attempt", Json::Num(self.attempt as f64)),
            ("transitions", Json::Num(self.transitions as f64)),
            ("clock", Json::Num(self.clock)),
            ("steps_recorded", Json::Num(self.steps.len() as f64)),
            (
                "finished",
                match &self.finished {
                    None => Json::Null,
                    Some(e) => {
                        let status = match &e.status {
                            ExecutionStatus::Succeeded => Json::Str("Succeeded".into()),
                            ExecutionStatus::Failed(msg) => {
                                Json::obj(vec![("Failed", Json::Str(msg.clone()))])
                            }
                        };
                        Json::obj(vec![
                            ("status", status),
                            ("finished_at", Json::Num(e.finished_at)),
                        ])
                    }
                },
            ),
        ])
    }

    /// Rebuild a cursor from its wire form. The step history comes back
    /// empty (see [`ExecutionState::to_json`]); everything that governs
    /// where the execution stands — state index, attempt counter,
    /// transition count, virtual clock, terminal marker — round-trips
    /// exactly (`clock` bit-exactly: the JSON writer prints the shortest
    /// representation that re-parses to the same f64).
    pub fn from_json(j: &Json) -> Option<ExecutionState> {
        let finished = match j.get("finished") {
            None | Some(Json::Null) => None,
            Some(f) => {
                let status = match f.get("status")? {
                    Json::Str(s) if s == "Succeeded" => ExecutionStatus::Succeeded,
                    other => ExecutionStatus::Failed(
                        other.get("Failed")?.as_str()?.to_string(),
                    ),
                };
                Some(Execution {
                    status,
                    steps: Vec::new(),
                    finished_at: f.get("finished_at")?.as_f64()?,
                })
            }
        };
        Some(ExecutionState {
            current: j.get("current")?.as_i64()? as usize,
            attempt: j.get("attempt")?.as_i64()? as u32,
            transitions: j.get("transitions")?.as_i64()? as usize,
            steps: Vec::new(),
            clock: j.get("clock")?.as_f64()?,
            finished,
        })
    }
}

/// A named-state workflow.
pub struct StateMachine<C> {
    states: Vec<State<C>>,
    start: String,
    /// Safety valve against runaway loops.
    pub max_transitions: usize,
}

impl<C> StateMachine<C> {
    /// Build a machine starting at `start`.
    pub fn new(start: &str) -> Self {
        StateMachine { states: Vec::new(), start: start.to_string(), max_transitions: 100_000 }
    }

    /// Register a state.
    pub fn state<F>(mut self, name: &str, retry: RetryPolicy, handler: F) -> Self
    where
        F: FnMut(&mut C, f64) -> Transition + Send + 'static,
    {
        self.states.push(State { name: name.to_string(), handler: Box::new(handler), retry });
        self
    }

    fn index_of(&self, name: &str) -> Option<usize> {
        self.states.iter().position(|s| s.name == name)
    }

    /// Begin a resumable execution with its virtual clock at `clock`.
    pub fn begin(&self, clock: f64) -> ExecutionState {
        let mut exec = ExecutionState {
            current: 0,
            attempt: 1,
            transitions: 0,
            steps: Vec::new(),
            clock,
            finished: None,
        };
        match self.index_of(&self.start) {
            Some(i) => exec.current = i,
            None => {
                exec.finished = Some(Execution {
                    status: ExecutionStatus::Failed(format!(
                        "start state '{}' not found",
                        self.start
                    )),
                    steps: Vec::new(),
                    finished_at: clock,
                });
            }
        }
        exec
    }

    fn finish(exec: &mut ExecutionState, status: ExecutionStatus) -> StepOutcome {
        let done = Execution {
            status,
            steps: std::mem::take(&mut exec.steps),
            finished_at: exec.clock,
        };
        // keep only a lightweight terminal marker: the full step history
        // (up to max_transitions records) is delivered exactly once, to
        // the caller of the step that finished — no doubled allocation
        exec.finished = Some(Execution {
            status: done.status.clone(),
            steps: Vec::new(),
            finished_at: done.finished_at,
        });
        StepOutcome::Done(done)
    }

    /// Advance `exec` by exactly one handler invocation.
    ///
    /// Returns [`StepOutcome::Ready`] when the next state can run
    /// immediately, [`StepOutcome::Parked`] when the execution entered a
    /// wait/backoff (its clock already advanced past it), and
    /// [`StepOutcome::Done`] at a terminal state. The full step history is
    /// carried by the `Done` of the step that finished; stepping an
    /// already-finished execution returns `Done` again with the terminal
    /// status and time but an empty history.
    pub fn step(&mut self, exec: &mut ExecutionState, ctx: &mut C) -> StepOutcome {
        if let Some(done) = &exec.finished {
            return StepOutcome::Done(done.clone());
        }
        if exec.transitions >= self.max_transitions {
            return Self::finish(
                exec,
                ExecutionStatus::Failed("transition budget exhausted".into()),
            );
        }
        exec.transitions += 1;
        let name = self.states[exec.current].name.clone();
        let retry = self.states[exec.current].retry;
        let tr = (self.states[exec.current].handler)(ctx, exec.clock);
        exec.steps.push(StepRecord {
            state: name.clone(),
            attempt: exec.attempt,
            time: exec.clock,
            outcome: format!("{tr:?}"),
        });
        match tr {
            Transition::Succeed => Self::finish(exec, ExecutionStatus::Succeeded),
            Transition::Fail(e) => Self::finish(exec, ExecutionStatus::Failed(e)),
            Transition::Next(next) => {
                exec.attempt = 1;
                match self.index_of(&next) {
                    Some(i) => {
                        exec.current = i;
                        StepOutcome::Ready
                    }
                    None => Self::finish(
                        exec,
                        ExecutionStatus::Failed(format!("unknown state '{next}'")),
                    ),
                }
            }
            Transition::Wait { seconds, then } => {
                let seconds = seconds.max(0.0);
                exec.clock += seconds;
                exec.attempt = 1;
                match self.index_of(&then) {
                    Some(i) => {
                        exec.current = i;
                        StepOutcome::Parked { seconds }
                    }
                    None => Self::finish(
                        exec,
                        ExecutionStatus::Failed(format!("unknown state '{then}'")),
                    ),
                }
            }
            Transition::Retryable(err) => {
                if exec.attempt >= retry.max_attempts {
                    return Self::finish(
                        exec,
                        ExecutionStatus::Failed(format!(
                            "state '{name}' exhausted {} attempts: {err}",
                            retry.max_attempts
                        )),
                    );
                }
                let backoff =
                    retry.interval_seconds * retry.backoff_rate.powi(exec.attempt as i32 - 1);
                exec.clock += backoff;
                exec.attempt += 1;
                StepOutcome::Parked { seconds: backoff }
            }
        }
    }

    /// Run to a terminal state, advancing `clock` through waits/backoffs.
    /// Equivalent to driving [`StateMachine::step`] in a tight loop; kept
    /// for callers that own a whole timeline (tests, direct runners).
    pub fn execute(&mut self, ctx: &mut C, clock: &mut f64) -> Execution {
        let mut exec = self.begin(*clock);
        loop {
            match self.step(&mut exec, ctx) {
                StepOutcome::Ready | StepOutcome::Parked { .. } => {}
                StepOutcome::Done(done) => {
                    *clock = exec.clock;
                    return done;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_flow_succeeds() {
        let mut m: StateMachine<Vec<&'static str>> = StateMachine::new("a")
            .state("a", RetryPolicy::none(), |ctx: &mut Vec<&'static str>, _| {
                ctx.push("a");
                Transition::Next("b".into())
            })
            .state("b", RetryPolicy::none(), |ctx: &mut Vec<&'static str>, _| {
                ctx.push("b");
                Transition::Succeed
            });
        let mut trace = Vec::new();
        let mut clock = 0.0;
        let ex = m.execute(&mut trace, &mut clock);
        assert_eq!(ex.status, ExecutionStatus::Succeeded);
        assert_eq!(trace, vec!["a", "b"]);
        assert_eq!(ex.steps.len(), 2);
    }

    #[test]
    fn retries_with_exponential_backoff() {
        struct Ctx {
            failures_left: u32,
        }
        let mut m: StateMachine<Ctx> = StateMachine::new("flaky").state(
            "flaky",
            RetryPolicy { max_attempts: 4, interval_seconds: 10.0, backoff_rate: 2.0 },
            |ctx: &mut Ctx, _| {
                if ctx.failures_left > 0 {
                    ctx.failures_left -= 1;
                    Transition::Retryable("boom".into())
                } else {
                    Transition::Succeed
                }
            },
        );
        let mut ctx = Ctx { failures_left: 3 };
        let mut clock = 0.0f64;
        let ex = m.execute(&mut ctx, &mut clock);
        assert_eq!(ex.status, ExecutionStatus::Succeeded);
        // backoff: 10 + 20 + 40
        assert!((clock - 70.0).abs() < 1e-9, "clock = {clock}");
        assert_eq!(ex.steps.len(), 4);
        assert_eq!(ex.total_retries(), 3);
    }

    #[test]
    fn exhausted_retries_fail() {
        let mut m: StateMachine<()> = StateMachine::new("s").state(
            "s",
            RetryPolicy { max_attempts: 2, interval_seconds: 1.0, backoff_rate: 1.0 },
            |_, _| Transition::Retryable("always".into()),
        );
        let mut clock = 0.0;
        let ex = m.execute(&mut (), &mut clock);
        assert!(matches!(ex.status, ExecutionStatus::Failed(ref e) if e.contains("exhausted")));
    }

    #[test]
    fn wait_advances_clock() {
        let mut m: StateMachine<()> = StateMachine::new("a")
            .state("a", RetryPolicy::none(), |_, _| {
                Transition::Wait { seconds: 30.0, then: "b".into() }
            })
            .state("b", RetryPolicy::none(), |_, t| {
                assert!(t >= 30.0);
                Transition::Succeed
            });
        let mut clock = 0.0;
        let ex = m.execute(&mut (), &mut clock);
        assert_eq!(ex.status, ExecutionStatus::Succeeded);
        assert_eq!(clock, 30.0);
    }

    #[test]
    fn unknown_state_fails_cleanly() {
        let mut m: StateMachine<()> = StateMachine::new("a").state(
            "a",
            RetryPolicy::none(),
            |_, _| Transition::Next("ghost".into()),
        );
        let mut clock = 0.0;
        let ex = m.execute(&mut (), &mut clock);
        assert!(matches!(ex.status, ExecutionStatus::Failed(ref e) if e.contains("ghost")));
    }

    #[test]
    fn step_parks_on_wait_and_resumes() {
        let mut m: StateMachine<u32> = StateMachine::new("a")
            .state("a", RetryPolicy::none(), |c: &mut u32, _| {
                *c += 1;
                Transition::Wait { seconds: 12.5, then: "b".into() }
            })
            .state("b", RetryPolicy::none(), |c: &mut u32, t| {
                assert!(t >= 12.5);
                *c += 10;
                Transition::Succeed
            });
        let mut ctx = 0u32;
        let mut exec = m.begin(0.0);
        // first step runs "a" and parks for the wait
        match m.step(&mut exec, &mut ctx) {
            StepOutcome::Parked { seconds } => assert_eq!(seconds, 12.5),
            other => panic!("expected Parked, got {other:?}"),
        }
        assert!(!exec.is_finished());
        assert_eq!(exec.clock, 12.5);
        assert_eq!(ctx, 1);
        // resuming later runs "b" to completion
        match m.step(&mut exec, &mut ctx) {
            StepOutcome::Done(done) => {
                assert_eq!(done.status, ExecutionStatus::Succeeded);
                assert_eq!(done.steps.len(), 2);
            }
            other => panic!("expected Done, got {other:?}"),
        }
        assert_eq!(ctx, 11);
        assert!(exec.is_finished());
        assert_eq!(exec.result().unwrap().status, ExecutionStatus::Succeeded);
    }

    #[test]
    fn step_parks_on_retry_backoff() {
        struct Ctx {
            failures_left: u32,
        }
        let mut m: StateMachine<Ctx> = StateMachine::new("flaky").state(
            "flaky",
            RetryPolicy { max_attempts: 3, interval_seconds: 4.0, backoff_rate: 2.0 },
            |ctx: &mut Ctx, _| {
                if ctx.failures_left > 0 {
                    ctx.failures_left -= 1;
                    Transition::Retryable("boom".into())
                } else {
                    Transition::Succeed
                }
            },
        );
        let mut ctx = Ctx { failures_left: 2 };
        let mut exec = m.begin(0.0);
        let mut parked = Vec::new();
        loop {
            match m.step(&mut exec, &mut ctx) {
                StepOutcome::Parked { seconds } => parked.push(seconds),
                StepOutcome::Ready => {}
                StepOutcome::Done(done) => {
                    assert_eq!(done.status, ExecutionStatus::Succeeded);
                    break;
                }
            }
        }
        // exponential backoff: 4, then 8, each returned as a park
        assert_eq!(parked, vec![4.0, 8.0]);
        assert_eq!(exec.clock, 12.0);
    }

    #[test]
    fn step_and_execute_agree() {
        // same machine driven both ways produces identical histories
        let build = || -> StateMachine<Vec<u32>> {
            StateMachine::new("a")
                .state("a", RetryPolicy::none(), |c: &mut Vec<u32>, _| {
                    c.push(1);
                    Transition::Wait { seconds: 3.0, then: "b".into() }
                })
                .state("b", RetryPolicy::none(), |c: &mut Vec<u32>, _| {
                    c.push(2);
                    if c.len() < 5 {
                        Transition::Next("b".into())
                    } else {
                        Transition::Succeed
                    }
                })
        };
        let mut direct_ctx = Vec::new();
        let mut clock = 0.0;
        let direct = build().execute(&mut direct_ctx, &mut clock);

        let mut stepped_ctx = Vec::new();
        let mut m = build();
        let mut exec = m.begin(0.0);
        let stepped = loop {
            if let StepOutcome::Done(done) = m.step(&mut exec, &mut stepped_ctx) {
                break done;
            }
        };
        assert_eq!(direct_ctx, stepped_ctx);
        assert_eq!(direct.status, stepped.status);
        assert_eq!(direct.steps, stepped.steps);
        assert_eq!(direct.finished_at, stepped.finished_at);
        assert_eq!(clock, exec.clock);
    }

    #[test]
    fn stepping_finished_execution_is_stable() {
        let mut m: StateMachine<()> =
            StateMachine::new("a").state("a", RetryPolicy::none(), |_, _| Transition::Succeed);
        let mut exec = m.begin(0.0);
        let first = match m.step(&mut exec, &mut ()) {
            StepOutcome::Done(d) => d,
            other => panic!("expected Done, got {other:?}"),
        };
        match m.step(&mut exec, &mut ()) {
            StepOutcome::Done(second) => {
                assert_eq!(first.status, second.status);
                assert_eq!(first.finished_at, second.finished_at);
                // the full history was delivered with the finishing step
                assert_eq!(first.steps.len(), 1);
                assert!(second.steps.is_empty());
            }
            other => panic!("expected Done, got {other:?}"),
        }
    }

    #[test]
    fn execution_state_json_roundtrip() {
        let mut m: StateMachine<u32> = StateMachine::new("a")
            .state("a", RetryPolicy::none(), |c: &mut u32, _| {
                *c += 1;
                Transition::Wait { seconds: 12.25, then: "b".into() }
            })
            .state("b", RetryPolicy::none(), |_, _| Transition::Succeed);
        let mut ctx = 0u32;
        let mut exec = m.begin(0.0);
        assert!(matches!(m.step(&mut exec, &mut ctx), StepOutcome::Parked { .. }));

        // mid-flight cursor round-trips, clock bit-exactly
        let j = exec.to_json();
        let back = ExecutionState::from_json(&crate::json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(back.clock.to_bits(), exec.clock.to_bits());
        assert!(!back.is_finished());
        assert_eq!(j.get("steps_recorded").unwrap().as_i64(), Some(1));
        // the rebuilt cursor resumes on the same machine
        let mut back = back;
        assert!(matches!(m.step(&mut back, &mut ctx), StepOutcome::Done(_)));

        // terminal cursors round-trip status + finish time
        m.step(&mut exec, &mut ctx);
        let j = exec.to_json();
        let back = ExecutionState::from_json(&crate::json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert!(back.is_finished());
        assert_eq!(back.result().unwrap().status, ExecutionStatus::Succeeded);
        assert_eq!(back.result().unwrap().finished_at, 12.25);

        // failed executions keep their message
        let mut fm: StateMachine<()> =
            StateMachine::new("x").state("x", RetryPolicy::none(), |_, _| {
                Transition::Fail("boom".into())
            });
        let mut fexec = fm.begin(0.0);
        fm.step(&mut fexec, &mut ());
        let back = ExecutionState::from_json(&fexec.to_json()).unwrap();
        assert!(
            matches!(back.result().unwrap().status, ExecutionStatus::Failed(ref e) if e == "boom")
        );
    }

    #[test]
    fn runaway_loops_bounded() {
        let mut m: StateMachine<()> =
            StateMachine::new("a").state("a", RetryPolicy::none(), |_, _| {
                Transition::Next("a".into())
            });
        m.max_transitions = 100;
        let mut clock = 0.0;
        let ex = m.execute(&mut (), &mut clock);
        assert!(matches!(ex.status, ExecutionStatus::Failed(ref e) if e.contains("budget")));
        assert_eq!(ex.steps.len(), 100);
    }
}
