//! Workflow engine (§3.2): the Step-Functions/Lambda stand-in.
//!
//! "AWS Cloudwatch Events, AWS Step Functions and AWS Lambda are used in
//! the AMT workflows engine, which is responsible for kicking off the
//! evaluation of hyperparameter configurations ..., starting training jobs,
//! tracking their progress and repeating the process until the stopping
//! criterion is met." This module provides that engine: a named-state
//! machine with per-state **retry policies with exponential backoff**
//! (§3.3's "built-in retry mechanism to guarantee robustness") executing on
//! the virtual clock, recording a full execution history for the
//! Describe API.

/// Outcome returned by a state handler.
#[derive(Clone, Debug, PartialEq)]
pub enum Transition {
    /// Move to the named state.
    Next(String),
    /// Sleep `seconds` of virtual time, then move to the named state.
    Wait { seconds: f64, then: String },
    /// Terminal success.
    Succeed,
    /// Terminal failure (unretryable).
    Fail(String),
    /// Transient error: retry this state per its policy.
    Retryable(String),
}

/// Retry policy for a state (Step Functions' `Retry` block).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Attempts including the first (1 = no retries).
    pub max_attempts: u32,
    /// First backoff interval (virtual seconds).
    pub interval_seconds: f64,
    /// Backoff multiplier per retry.
    pub backoff_rate: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, interval_seconds: 5.0, backoff_rate: 2.0 }
    }
}

impl RetryPolicy {
    /// No retries at all.
    pub fn none() -> Self {
        RetryPolicy { max_attempts: 1, interval_seconds: 0.0, backoff_rate: 1.0 }
    }
}

/// One state of the machine.
pub struct State<C> {
    /// Unique state name.
    pub name: String,
    /// Handler invoked on entry; receives the shared context.
    pub handler: Box<dyn FnMut(&mut C, f64) -> Transition + Send>,
    /// Retry policy applied to `Transition::Retryable`.
    pub retry: RetryPolicy,
}

/// A recorded step of an execution (Describe API material).
#[derive(Clone, Debug, PartialEq)]
pub struct StepRecord {
    /// State name.
    pub state: String,
    /// 1-based attempt number.
    pub attempt: u32,
    /// Virtual time the attempt started.
    pub time: f64,
    /// Stringified outcome.
    pub outcome: String,
}

/// Terminal result of an execution.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecutionStatus {
    /// Reached `Succeed`.
    Succeeded,
    /// Reached `Fail` or exhausted retries.
    Failed(String),
}

/// Full execution report.
#[derive(Clone, Debug)]
pub struct Execution {
    /// Terminal status.
    pub status: ExecutionStatus,
    /// Ordered step history.
    pub steps: Vec<StepRecord>,
    /// Virtual time at completion.
    pub finished_at: f64,
}

impl Execution {
    /// Total retries performed across all states (steps that were re-attempts).
    pub fn total_retries(&self) -> u32 {
        self.steps.iter().filter(|s| s.attempt > 1).count() as u32
    }
}

/// A named-state workflow.
pub struct StateMachine<C> {
    states: Vec<State<C>>,
    start: String,
    /// Safety valve against runaway loops.
    pub max_transitions: usize,
}

impl<C> StateMachine<C> {
    /// Build a machine starting at `start`.
    pub fn new(start: &str) -> Self {
        StateMachine { states: Vec::new(), start: start.to_string(), max_transitions: 100_000 }
    }

    /// Register a state.
    pub fn state<F>(mut self, name: &str, retry: RetryPolicy, handler: F) -> Self
    where
        F: FnMut(&mut C, f64) -> Transition + Send + 'static,
    {
        self.states.push(State { name: name.to_string(), handler: Box::new(handler), retry });
        self
    }

    fn index_of(&self, name: &str) -> Option<usize> {
        self.states.iter().position(|s| s.name == name)
    }

    /// Run to a terminal state, advancing `clock` through waits/backoffs.
    pub fn execute(&mut self, ctx: &mut C, clock: &mut f64) -> Execution {
        let mut steps = Vec::new();
        let mut current = match self.index_of(&self.start.clone()) {
            Some(i) => i,
            None => {
                return Execution {
                    status: ExecutionStatus::Failed(format!(
                        "start state '{}' not found",
                        self.start
                    )),
                    steps,
                    finished_at: *clock,
                }
            }
        };
        let mut attempt = 1u32;
        for _ in 0..self.max_transitions {
            let name = self.states[current].name.clone();
            let retry = self.states[current].retry;
            let tr = (self.states[current].handler)(ctx, *clock);
            steps.push(StepRecord {
                state: name.clone(),
                attempt,
                time: *clock,
                outcome: format!("{tr:?}"),
            });
            match tr {
                Transition::Succeed => {
                    return Execution {
                        status: ExecutionStatus::Succeeded,
                        steps,
                        finished_at: *clock,
                    }
                }
                Transition::Fail(e) => {
                    return Execution {
                        status: ExecutionStatus::Failed(e),
                        steps,
                        finished_at: *clock,
                    }
                }
                Transition::Next(next) => {
                    attempt = 1;
                    match self.index_of(&next) {
                        Some(i) => current = i,
                        None => {
                            return Execution {
                                status: ExecutionStatus::Failed(format!(
                                    "unknown state '{next}'"
                                )),
                                steps,
                                finished_at: *clock,
                            }
                        }
                    }
                }
                Transition::Wait { seconds, then } => {
                    *clock += seconds.max(0.0);
                    attempt = 1;
                    match self.index_of(&then) {
                        Some(i) => current = i,
                        None => {
                            return Execution {
                                status: ExecutionStatus::Failed(format!(
                                    "unknown state '{then}'"
                                )),
                                steps,
                                finished_at: *clock,
                            }
                        }
                    }
                }
                Transition::Retryable(err) => {
                    if attempt >= retry.max_attempts {
                        return Execution {
                            status: ExecutionStatus::Failed(format!(
                                "state '{name}' exhausted {} attempts: {err}",
                                retry.max_attempts
                            )),
                            steps,
                            finished_at: *clock,
                        };
                    }
                    *clock += retry.interval_seconds
                        * retry.backoff_rate.powi(attempt as i32 - 1);
                    attempt += 1;
                }
            }
        }
        Execution {
            status: ExecutionStatus::Failed("transition budget exhausted".into()),
            steps,
            finished_at: *clock,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_flow_succeeds() {
        let mut m: StateMachine<Vec<&'static str>> = StateMachine::new("a")
            .state("a", RetryPolicy::none(), |ctx: &mut Vec<&'static str>, _| {
                ctx.push("a");
                Transition::Next("b".into())
            })
            .state("b", RetryPolicy::none(), |ctx: &mut Vec<&'static str>, _| {
                ctx.push("b");
                Transition::Succeed
            });
        let mut trace = Vec::new();
        let mut clock = 0.0;
        let ex = m.execute(&mut trace, &mut clock);
        assert_eq!(ex.status, ExecutionStatus::Succeeded);
        assert_eq!(trace, vec!["a", "b"]);
        assert_eq!(ex.steps.len(), 2);
    }

    #[test]
    fn retries_with_exponential_backoff() {
        struct Ctx {
            failures_left: u32,
        }
        let mut m: StateMachine<Ctx> = StateMachine::new("flaky").state(
            "flaky",
            RetryPolicy { max_attempts: 4, interval_seconds: 10.0, backoff_rate: 2.0 },
            |ctx: &mut Ctx, _| {
                if ctx.failures_left > 0 {
                    ctx.failures_left -= 1;
                    Transition::Retryable("boom".into())
                } else {
                    Transition::Succeed
                }
            },
        );
        let mut ctx = Ctx { failures_left: 3 };
        let mut clock = 0.0f64;
        let ex = m.execute(&mut ctx, &mut clock);
        assert_eq!(ex.status, ExecutionStatus::Succeeded);
        // backoff: 10 + 20 + 40
        assert!((clock - 70.0).abs() < 1e-9, "clock = {clock}");
        assert_eq!(ex.steps.len(), 4);
        assert_eq!(ex.total_retries(), 3);
    }

    #[test]
    fn exhausted_retries_fail() {
        let mut m: StateMachine<()> = StateMachine::new("s").state(
            "s",
            RetryPolicy { max_attempts: 2, interval_seconds: 1.0, backoff_rate: 1.0 },
            |_, _| Transition::Retryable("always".into()),
        );
        let mut clock = 0.0;
        let ex = m.execute(&mut (), &mut clock);
        assert!(matches!(ex.status, ExecutionStatus::Failed(ref e) if e.contains("exhausted")));
    }

    #[test]
    fn wait_advances_clock() {
        let mut m: StateMachine<()> = StateMachine::new("a")
            .state("a", RetryPolicy::none(), |_, _| {
                Transition::Wait { seconds: 30.0, then: "b".into() }
            })
            .state("b", RetryPolicy::none(), |_, t| {
                assert!(t >= 30.0);
                Transition::Succeed
            });
        let mut clock = 0.0;
        let ex = m.execute(&mut (), &mut clock);
        assert_eq!(ex.status, ExecutionStatus::Succeeded);
        assert_eq!(clock, 30.0);
    }

    #[test]
    fn unknown_state_fails_cleanly() {
        let mut m: StateMachine<()> = StateMachine::new("a").state(
            "a",
            RetryPolicy::none(),
            |_, _| Transition::Next("ghost".into()),
        );
        let mut clock = 0.0;
        let ex = m.execute(&mut (), &mut clock);
        assert!(matches!(ex.status, ExecutionStatus::Failed(ref e) if e.contains("ghost")));
    }

    #[test]
    fn runaway_loops_bounded() {
        let mut m: StateMachine<()> =
            StateMachine::new("a").state("a", RetryPolicy::none(), |_, _| {
                Transition::Next("a".into())
            });
        m.max_transitions = 100;
        let mut clock = 0.0;
        let ex = m.execute(&mut (), &mut clock);
        assert!(matches!(ex.status, ExecutionStatus::Failed(ref e) if e.contains("budget")));
        assert_eq!(ex.steps.len(), 100);
    }
}
