//! # sagemaker-amt — reproduction of *Amazon SageMaker Automatic Model
//! Tuning: Scalable Gradient-Free Optimization* (KDD 2021)
//!
//! A fully managed, fault-tolerant hyperparameter-optimization service:
//! an API layer over a metadata store and a workflow engine that drives
//! training jobs on a (simulated) training platform, with candidate
//! configurations chosen by GP-based Bayesian optimization (Matérn-5/2 ARD,
//! Kumaraswamy input warping, slice-sampled GP hyperparameters, expected
//! improvement over Sobol anchors), random/grid search baselines, median-rule
//! early stopping and warm starting.
//!
//! The GP compute hot path (Gram matrices, posterior moments, EI scoring) is
//! AOT-compiled from JAX + Pallas into HLO artifacts and executed through
//! PJRT by [`runtime`]; a pure-Rust mirror of the same math lives in [`gp`]
//! and is cross-checked against the artifacts in integration tests.
//!
//! Training data flows through the stack as one contiguous row-major
//! [`gp::Dataset`]; likelihood queries reuse a [`gp::GramScratch`]
//! workspace (zero allocations in the slice-sampling inner loop); and GPHP
//! fitting / anchor scoring fan out over [`parallel`] with order-stable,
//! bit-deterministic reduction. See `DESIGN.md` §2–§5.
//!
//! The service layer is multi-tenant: tuning jobs run as resumable
//! [`coordinator::JobActor`]s multiplexed over the bounded worker pool of
//! [`scheduler`] with weighted fair-share ordering, backed by the
//! lock-striped sharded [`store`] and [`metrics`] services. See
//! `DESIGN.md` §9.
//!
//! The service is crash-recoverable: [`durability`] provides a
//! group-committed write-ahead log of every store/metrics mutation,
//! per-shard point-in-time snapshots (with WAL compaction keeping the
//! log bounded), and recovery-on-open ([`api::AmtService::open`]) that
//! resumes in-flight tuning jobs with bit-identical trajectories —
//! O(remaining work), not O(job so far): every checkpoint is a
//! versioned [`coordinator::ResumeSnapshot`] carrying the full
//! strategy/platform state, so resumed jobs re-execute zero past
//! proposals. See `DESIGN.md` §10/§12.
//!
//! The service scales past one process: [`distributed`] puts a framed,
//! crc-checked wire protocol — whose delta payloads are literal WAL
//! records — between the scheduler and a pool of remote workers
//! ([`distributed::leader::RemoteWorkerPool`]), with lease-based
//! liveness, surrogate-backend pinning for mixed fleets, and
//! requeue-from-snapshot on worker death. See `DESIGN.md` §11/§12.
//!
//! The whole stack is continuously exercised by [`load`] — a declarative
//! load & chaos observatory: JSON-specified mixed workloads with per-op
//! SLO histograms and invariant observers riding the elastic-fleet and
//! recovery machinery. See `DESIGN.md` §16.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the reproduced figures.

pub mod acquisition;
pub mod api;
pub mod config;
pub mod coordinator;
pub mod distributed;
pub mod durability;
pub mod earlystop;
pub mod gp;
pub mod harness;
pub mod json;
pub mod linalg;
pub mod load;
pub mod metrics;
pub mod multiobjective;
pub mod objectives;
pub mod parallel;
pub mod platform;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod sobol;
pub mod space;
pub mod store;
pub mod strategies;
pub mod telemetry;
pub mod warmstart;
pub mod workflow;

/// Crate-wide result type (service-level errors).
pub type Result<T> = anyhow::Result<T>;
