//! Warm starting from previous tuning jobs (§5.3).
//!
//! AMT's design point, reproduced here: a *light-weight* transfer purely
//! based on past hyperparameter evaluations — no dataset meta-features.
//! Parent-job observations are remapped into the child job's search space
//! and injected into the BO history, so the surrogate is informed from
//! evaluation one ("the new tuning job quickly detects good hyperparameter
//! configurations thanks to the knowledge from the parent job").
//!
//! Remapping handles the edge cases §6.2 reports from production:
//! a parent value that is invalid under the child's scaling (e.g. 0.0
//! explored under linear scaling, then log scaling enabled in the child) is
//! clamped into the child range; parameters added in the child are filled
//! with range midpoints; parameters dropped from the child are ignored.

use crate::space::SearchSpace;
use crate::strategies::Observation;

/// A parent tuning job's transferable state.
#[derive(Clone, Debug)]
pub struct ParentJob {
    /// Parent job identifier (for provenance in logs).
    pub name: String,
    /// The parent's search space (may differ from the child's).
    pub space: SearchSpace,
    /// Finished evaluations, values already in the child's minimization
    /// orientation.
    pub observations: Vec<Observation>,
}

/// Transfer policy options.
#[derive(Clone, Copy, Debug)]
pub struct TransferOptions {
    /// Cap on transferred observations per parent (most recent kept). The
    /// paper notes users chain jobs with ~500 evaluations each to sidestep
    /// the cubic GP cost; the cap keeps the child's fit tractable.
    pub max_per_parent: usize,
    /// Drop parent observations whose configuration cannot be expressed in
    /// the child space at all (instead of clamping).
    pub strict: bool,
}

impl Default for TransferOptions {
    fn default() -> Self {
        TransferOptions { max_per_parent: 256, strict: false }
    }
}

/// Remap parent observations into the child space.
///
/// Returns the observations ready for
/// [`crate::strategies::BayesianOptimization::add_transferred`].
pub fn transfer(
    parents: &[ParentJob],
    child_space: &SearchSpace,
    options: &TransferOptions,
) -> Vec<Observation> {
    let mut out = Vec::new();
    for parent in parents {
        let tail_start = parent.observations.len().saturating_sub(options.max_per_parent);
        for obs in &parent.observations[tail_start..] {
            if !obs.value.is_finite() {
                continue; // failed parent evaluations carry no signal
            }
            // already valid in the child space?
            if child_space.encode(&obs.config).is_ok() {
                out.push(Observation { config: obs.config.clone(), value: obs.value });
                continue;
            }
            if options.strict {
                continue;
            }
            // clamp into the child space (the §6.2 log-scaling edge case)
            let clamped = child_space.clamp(&obs.config);
            if child_space.encode(&clamped).is_ok() {
                out.push(Observation { config: clamped, value: obs.value });
            }
        }
    }
    out
}

/// Identical-data transfer mode (paper's "same algorithm and dataset"
/// use case): all parents share the metric scale, so raw values transfer.
/// For transfer across *transformed* datasets ("augmented dataset" case)
/// the metric may shift; [`rank_normalize`] maps each parent's values onto
/// their within-parent standard scores, preserving ordering information
/// while discarding the task-specific offset — the light-weight analogue of
/// quantile-based HP transfer the paper cites.
pub fn rank_normalize(parents: &mut [ParentJob]) {
    for parent in parents {
        let n = parent.observations.len();
        if n < 2 {
            continue;
        }
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            parent.observations[a]
                .value
                .partial_cmp(&parent.observations[b].value)
                .unwrap()
        });
        // map to normal-ish scores in (-2, 2): 4 * (rank/(n-1) - 0.5)
        let mut scores = vec![0.0; n];
        for (rank, &i) in idx.iter().enumerate() {
            scores[i] = 4.0 * (rank as f64 / (n as f64 - 1.0) - 0.5);
        }
        for (obs, s) in parent.observations.iter_mut().zip(scores) {
            obs.value = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{continuous, Config, Scaling, Value};

    fn obs(pairs: &[(&str, f64)], value: f64) -> Observation {
        let config: Config = pairs
            .iter()
            .map(|(k, v)| (k.to_string(), Value::Float(*v)))
            .collect();
        Observation { config, value }
    }

    fn linear_space() -> SearchSpace {
        SearchSpace::new(vec![continuous("wd", 0.0, 1.0, Scaling::Linear)]).unwrap()
    }

    fn log_space() -> SearchSpace {
        SearchSpace::new(vec![continuous("wd", 1e-6, 1.0, Scaling::Logarithmic)]).unwrap()
    }

    #[test]
    fn compatible_observations_pass_through() {
        let parent = ParentJob {
            name: "p".into(),
            space: linear_space(),
            observations: vec![obs(&[("wd", 0.5)], 1.0), obs(&[("wd", 0.9)], 2.0)],
        };
        let t = transfer(&[parent], &linear_space(), &TransferOptions::default());
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].value, 1.0);
    }

    #[test]
    fn log_scaling_zero_edge_case_is_clamped() {
        // §6.2: parent explored wd = 0.0 under linear scaling; child
        // switches to log scaling where 0 is invalid.
        let parent = ParentJob {
            name: "p".into(),
            space: linear_space(),
            observations: vec![obs(&[("wd", 0.0)], 0.7)],
        };
        let t = transfer(&[parent], &log_space(), &TransferOptions::default());
        assert_eq!(t.len(), 1);
        let v = t[0].config.get("wd").unwrap().as_f64().unwrap();
        assert!(v >= 1e-6, "must be clamped to child minimum, got {v}");
        assert!(log_space().encode(&t[0].config).is_ok());
    }

    #[test]
    fn strict_mode_drops_incompatible() {
        let parent = ParentJob {
            name: "p".into(),
            space: linear_space(),
            observations: vec![obs(&[("wd", 0.0)], 0.7), obs(&[("wd", 0.5)], 0.3)],
        };
        let t = transfer(
            &[parent],
            &log_space(),
            &TransferOptions { strict: true, ..Default::default() },
        );
        assert_eq!(t.len(), 1); // only the valid one survives
    }

    #[test]
    fn added_and_removed_parameters_are_handled() {
        // child adds "lr" and keeps "wd"
        let child = SearchSpace::new(vec![
            continuous("wd", 0.0, 1.0, Scaling::Linear),
            continuous("lr", 1e-4, 1.0, Scaling::Logarithmic),
        ])
        .unwrap();
        let parent = ParentJob {
            name: "p".into(),
            space: linear_space(),
            observations: vec![obs(&[("wd", 0.25)], 0.1)],
        };
        let t = transfer(&[parent], &child, &TransferOptions::default());
        assert_eq!(t.len(), 1);
        assert!(child.encode(&t[0].config).is_ok());
        // removed parameter: child only has wd, parent had wd + extra
        let parent2 = ParentJob {
            name: "p2".into(),
            space: child.clone(),
            observations: vec![obs(&[("wd", 0.25), ("lr", 0.01)], 0.1)],
        };
        let t2 = transfer(&[parent2], &linear_space(), &TransferOptions::default());
        assert_eq!(t2.len(), 1);
        assert!(linear_space().encode(&t2[0].config).is_ok());
    }

    #[test]
    fn non_finite_values_are_dropped() {
        let parent = ParentJob {
            name: "p".into(),
            space: linear_space(),
            observations: vec![obs(&[("wd", 0.4)], f64::NAN), obs(&[("wd", 0.6)], 1.0)],
        };
        let t = transfer(&[parent], &linear_space(), &TransferOptions::default());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn max_per_parent_keeps_most_recent() {
        let observations: Vec<Observation> =
            (0..10).map(|i| obs(&[("wd", i as f64 / 10.0)], i as f64)).collect();
        let parent = ParentJob { name: "p".into(), space: linear_space(), observations };
        let t = transfer(
            &[parent],
            &linear_space(),
            &TransferOptions { max_per_parent: 3, ..Default::default() },
        );
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].value, 7.0); // tail kept
    }

    #[test]
    fn rank_normalize_preserves_order_and_centers() {
        let mut parents = vec![ParentJob {
            name: "p".into(),
            space: linear_space(),
            observations: vec![
                obs(&[("wd", 0.1)], 100.0),
                obs(&[("wd", 0.2)], -5.0),
                obs(&[("wd", 0.3)], 40.0),
            ],
        }];
        rank_normalize(&mut parents);
        let vals: Vec<f64> =
            parents[0].observations.iter().map(|o| o.value).collect();
        // order preserved: obs1 (100) worst, obs2 (−5) best
        assert!(vals[1] < vals[2] && vals[2] < vals[0]);
        assert!((vals.iter().sum::<f64>()).abs() < 1e-9); // centered
    }
}
