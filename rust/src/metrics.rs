//! Metrics service (§3.2): the CloudWatch stand-in.
//!
//! Training jobs publish their intermediate objective values here (the
//! paper: "each training job provides customers with ... logs and metrics
//! persisted in CloudWatch"); the workflow engine reads them back to feed
//! the early stopper, and the figure harnesses query time series to plot
//! best-so-far curves. Timestamps are virtual-clock seconds.
//!
//! Like [`crate::store::MetadataStore`], the sink is lock-striped: streams
//! hash to one of K shards, so per-epoch emissions from many concurrent
//! tuning jobs on the scheduler's worker pool do not contend on a single
//! mutex. Cross-stream queries (`list_streams`) merge the shards and sort.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::durability::wal::{Wal, WalRecord};

/// Lock stripes for the stream map.
const METRIC_SHARDS: usize = 8;

/// One metric observation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DataPoint {
    /// Virtual time (seconds since tuning-job start).
    pub time: f64,
    /// Metric value.
    pub value: f64,
}

/// Aggregate statistics over a metric stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricStats {
    /// Number of data points.
    pub count: usize,
    /// Minimum value.
    pub min: f64,
    /// Maximum value.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Value of the latest point.
    pub last: f64,
}

/// Thread-safe, lock-striped metric sink keyed by `namespace/metric`
/// streams.
pub struct MetricsService {
    shards: Vec<Mutex<BTreeMap<String, Vec<DataPoint>>>>,
    /// This service's metric registry (per-instance; names under
    /// `metrics.*`).
    telemetry: crate::telemetry::Registry,
    /// Shard-guard acquisitions made by mutation paths (emit/remove/raw
    /// inserts/batches) — same batching observable as
    /// [`crate::store::MetadataStore::shard_lock_acquisitions`].
    /// Registry name: `metrics.shard_lock_acquisitions`.
    shard_locks: Arc<crate::telemetry::Counter>,
    /// Optional write-ahead log (see [`crate::durability`]): once
    /// attached, every emission appends a record inside its shard
    /// critical section, so per-stream WAL order equals series order.
    wal: OnceLock<Arc<Wal>>,
}

impl Default for MetricsService {
    fn default() -> Self {
        let reg = crate::telemetry::Registry::new();
        MetricsService {
            shards: (0..METRIC_SHARDS).map(|_| Mutex::new(BTreeMap::new())).collect(),
            shard_locks: reg.counter("metrics.shard_lock_acquisitions"),
            telemetry: reg,
            wal: OnceLock::new(),
        }
    }
}

impl MetricsService {
    /// Empty service.
    pub fn new() -> Self {
        Self::default()
    }

    /// Deterministic FNV-1a shard index of a stream name (same hash as the
    /// metadata store's shard routing).
    fn shard_of(&self, stream: &str) -> usize {
        let h = crate::store::fnv1a(&[stream.as_bytes()]);
        (h % self.shards.len() as u64) as usize
    }

    /// Attach a write-ahead log. Emissions from this point on emit WAL
    /// records; at most one WAL can ever be attached (later calls no-op).
    pub fn attach_wal(&self, wal: Arc<Wal>) {
        let _ = self.wal.set(wal);
    }

    /// Acquire one shard guard on a mutation path, counting it in
    /// [`MetricsService::shard_lock_acquisitions`].
    fn lock_shard(&self, idx: usize) -> MutexGuard<'_, BTreeMap<String, Vec<DataPoint>>> {
        self.shard_locks.inc();
        self.shards[idx].lock().unwrap()
    }

    /// Shard-guard acquisitions made by mutation paths so far — the
    /// observable [`MetricsService::emit_batch`] reduces (one
    /// acquisition per distinct shard per batch instead of one per
    /// point). Shim over registry metric
    /// `metrics.shard_lock_acquisitions`; prefer
    /// [`MetricsService::telemetry_metrics`].
    pub fn shard_lock_acquisitions(&self) -> u64 {
        self.shard_locks.get()
    }

    /// Point-in-time snapshot of this service's metric registry (names
    /// under `metrics.*`) — one part of
    /// [`crate::api::AmtService::telemetry_snapshot`].
    pub fn telemetry_metrics(&self) -> Vec<crate::telemetry::MetricSnapshot> {
        self.telemetry.snapshot()
    }

    /// Insert one point into its series — the single insertion rule
    /// (`emit` and `emit_batch` share it, so series contents cannot
    /// drift between the per-point and batched paths).
    fn insert_point(s: &mut Vec<DataPoint>, time: f64, value: f64) {
        match s.last() {
            Some(last) if last.time > time => {
                let idx = s.partition_point(|p| p.time <= time);
                s.insert(idx, DataPoint { time, value });
            }
            _ => s.push(DataPoint { time, value }),
        }
    }

    /// Publish one point to `stream` (points must be in time order per
    /// producer; out-of-order points are inserted by timestamp).
    pub fn emit(&self, stream: &str, time: f64, value: f64) {
        let mut streams = self.lock_shard(self.shard_of(stream));
        if let Some(w) = self.wal.get() {
            w.append(&WalRecord::Emit { stream: stream.to_string(), time, value });
        }
        let s = streams.entry(stream.to_string()).or_default();
        Self::insert_point(s, time, value);
    }

    /// Publish a batch of `(stream, time, value)` points — observably
    /// identical to emitting them one at a time in order (same series
    /// contents, same WAL records in the same order), but each distinct
    /// shard is locked once per batch and the WAL records land in one
    /// locked extend ([`Wal::append_batch`]). Guards are acquired in
    /// ascending shard-index order (the subset discipline of
    /// `remove_streams`' all-guards acquisition, so multi-guard holders
    /// cannot deadlock); the WAL append happens with every touched guard
    /// held, keeping per-stream WAL order equal to series order.
    pub fn emit_batch(&self, points: &[(&str, f64, f64)]) {
        if points.is_empty() {
            return;
        }
        let idxs: Vec<usize> = points.iter().map(|(s, _, _)| self.shard_of(s)).collect();
        let mut unique = idxs.clone();
        unique.sort_unstable();
        unique.dedup();
        let mut guards: BTreeMap<usize, MutexGuard<'_, BTreeMap<String, Vec<DataPoint>>>> =
            unique.iter().map(|&i| (i, self.lock_shard(i))).collect();
        if let Some(w) = self.wal.get() {
            let recs: Vec<WalRecord> = points
                .iter()
                .map(|(stream, time, value)| WalRecord::Emit {
                    stream: (*stream).to_string(),
                    time: *time,
                    value: *value,
                })
                .collect();
            w.append_batch(&recs);
        }
        for ((stream, time, value), idx) in points.iter().zip(&idxs) {
            let streams = guards.get_mut(idx).unwrap();
            let s = streams.entry((*stream).to_string()).or_default();
            Self::insert_point(s, *time, *value);
        }
    }

    /// Remove every stream whose name starts with `prefix`; returns how
    /// many were dropped. Used by crash recovery to reset a resumed job's
    /// partial series before deterministic replay. All shard guards are
    /// held across the WAL append *and* the removals, so a concurrent
    /// snapshot capture (which also takes every guard) observes either
    /// none of the removal (record past its high-water mark ⇒ replayed)
    /// or all of it (record at or below the mark ⇒ contained) — the
    /// removed streams can never resurrect on recovery.
    pub fn remove_streams(&self, prefix: &str) -> usize {
        self.shard_locks
            .fetch_add(self.shards.len() as u64, std::sync::atomic::Ordering::Relaxed);
        let mut guards: Vec<_> = self.shards.iter().map(|s| s.lock().unwrap()).collect();
        if let Some(w) = self.wal.get() {
            w.append(&WalRecord::RemoveStreams { prefix: prefix.to_string() });
        }
        let mut removed = 0;
        for streams in guards.iter_mut() {
            let doomed: Vec<String> =
                streams.keys().filter(|k| k.starts_with(prefix)).cloned().collect();
            removed += doomed.len();
            for k in doomed {
                streams.remove(&k);
            }
        }
        removed
    }

    /// Raw whole-series insert: the snapshot-restore path. Bypasses the
    /// WAL (recovery must not re-log what it replays).
    pub(crate) fn insert_raw_stream(&self, stream: &str, points: Vec<DataPoint>) {
        let mut streams = self.lock_shard(self.shard_of(stream));
        streams.insert(stream.to_string(), points);
    }

    /// Point-in-time capture for per-shard snapshots: clones every
    /// shard's streams while **all** shard guards are held, reading the
    /// WAL high-water mark under the same guards (see
    /// [`crate::store::MetadataStore::capture_for_snapshot`]).
    pub(crate) fn capture_for_snapshot(
        &self,
    ) -> (Vec<BTreeMap<String, Vec<DataPoint>>>, u64) {
        let guards: Vec<_> = self.shards.iter().map(|s| s.lock().unwrap()).collect();
        let hwm = self.wal.get().map(|w| w.last_lsn()).unwrap_or(0);
        let data = guards.iter().map(|g| (*g).clone()).collect();
        (data, hwm)
    }

    /// Full series for a stream.
    pub fn series(&self, stream: &str) -> Vec<DataPoint> {
        self.shards[self.shard_of(stream)]
            .lock()
            .unwrap()
            .get(stream)
            .cloned()
            .unwrap_or_default()
    }

    /// Stream names with a prefix, sorted (merged across shards).
    pub fn list_streams(&self, prefix: &str) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for shard in &self.shards {
            let streams = shard.lock().unwrap();
            names.extend(streams.keys().filter(|k| k.starts_with(prefix)).cloned());
        }
        names.sort();
        names
    }

    /// Summary statistics, if the stream has data.
    pub fn stats(&self, stream: &str) -> Option<MetricStats> {
        let streams = self.shards[self.shard_of(stream)].lock().unwrap();
        let s = streams.get(stream)?;
        if s.is_empty() {
            return None;
        }
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut sum = 0.0;
        for p in s {
            min = min.min(p.value);
            max = max.max(p.value);
            sum += p.value;
        }
        Some(MetricStats {
            count: s.len(),
            min,
            max,
            mean: sum / s.len() as f64,
            last: s.last().unwrap().value,
        })
    }

    /// Running best (minimum if `minimize`, else maximum) as a step series —
    /// the "best model score so far over time" curves of Figs 3–5.
    pub fn best_so_far(&self, stream: &str, minimize: bool) -> Vec<DataPoint> {
        let series = self.series(stream);
        let mut best = if minimize { f64::INFINITY } else { f64::NEG_INFINITY };
        let mut out = Vec::with_capacity(series.len());
        for p in series {
            best = if minimize { best.min(p.value) } else { best.max(p.value) };
            out.push(DataPoint { time: p.time, value: best });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_and_query() {
        let m = MetricsService::new();
        m.emit("job/loss", 1.0, 0.9);
        m.emit("job/loss", 2.0, 0.5);
        let s = m.series("job/loss");
        assert_eq!(s.len(), 2);
        assert_eq!(s[1].value, 0.5);
        assert!(m.series("missing").is_empty());
    }

    #[test]
    fn out_of_order_points_sorted() {
        let m = MetricsService::new();
        m.emit("s", 5.0, 1.0);
        m.emit("s", 2.0, 2.0);
        m.emit("s", 3.0, 3.0);
        let times: Vec<f64> = m.series("s").iter().map(|p| p.time).collect();
        assert_eq!(times, vec![2.0, 3.0, 5.0]);
    }

    #[test]
    fn stats_and_listing() {
        let m = MetricsService::new();
        m.emit("a/x", 0.0, 1.0);
        m.emit("a/x", 1.0, 3.0);
        m.emit("b/y", 0.0, -1.0);
        let st = m.stats("a/x").unwrap();
        assert_eq!(st.count, 2);
        assert_eq!(st.min, 1.0);
        assert_eq!(st.max, 3.0);
        assert_eq!(st.mean, 2.0);
        assert_eq!(st.last, 3.0);
        assert_eq!(m.list_streams("a/"), vec!["a/x"]);
        assert!(m.stats("missing").is_none());
    }

    #[test]
    fn list_streams_sorted_across_shards() {
        let m = MetricsService::new();
        // enough streams to land on several shards
        for i in (0..40).rev() {
            m.emit(&format!("job/{i:02}"), 0.0, i as f64);
        }
        let names = m.list_streams("job/");
        assert_eq!(names.len(), 40);
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        // per-stream reads route to the right shard
        assert_eq!(m.series("job/07")[0].value, 7.0);
    }

    #[test]
    fn remove_streams_by_prefix() {
        let m = MetricsService::new();
        for i in 0..20 {
            m.emit(&format!("job-a-train-{i:02}/loss"), 0.0, i as f64);
        }
        m.emit("job-a/evaluations", 0.0, 1.0);
        m.emit("job-b/evaluations", 0.0, 1.0);
        assert_eq!(m.remove_streams("job-a-train-"), 20);
        assert_eq!(m.remove_streams("job-a/"), 1);
        assert_eq!(m.remove_streams("job-a-train-"), 0);
        assert!(m.list_streams("job-a").is_empty());
        assert_eq!(m.list_streams("job-b/"), vec!["job-b/evaluations"]);
    }

    /// `emit_batch` must be observably identical to per-point `emit`s:
    /// same series (out-of-order inserts included), same WAL bytes, and
    /// one shard-lock acquisition per distinct shard instead of one per
    /// point.
    #[test]
    fn emit_batch_matches_per_point_emits() {
        use crate::durability::wal::Wal;
        use std::sync::Arc;
        let tmp = |tag: &str| {
            std::env::temp_dir().join(format!(
                "amt-metrics-batch-{tag}-{}-{}",
                std::process::id(),
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .unwrap()
                    .as_nanos()
            ))
        };
        let (dir_a, dir_b) = (tmp("a"), tmp("b"));
        let (one, batch) = (MetricsService::new(), MetricsService::new());
        one.attach_wal(Arc::new(Wal::create(&dir_a).unwrap()));
        batch.attach_wal(Arc::new(Wal::create(&dir_b).unwrap()));
        let points: Vec<(String, f64, f64)> = (0..40)
            .map(|i| (format!("job/{}", i % 7), (40 - i) as f64, i as f64 * 0.25))
            .collect();
        for (s, t, v) in &points {
            one.emit(s, *t, *v);
        }
        let before = batch.shard_lock_acquisitions();
        let borrowed: Vec<(&str, f64, f64)> =
            points.iter().map(|(s, t, v)| (s.as_str(), *t, *v)).collect();
        batch.emit_batch(&borrowed);
        let took = batch.shard_lock_acquisitions() - before;
        assert!(took <= METRIC_SHARDS as u64, "batch took {took} shard locks");
        assert!(took < points.len() as u64);
        assert_eq!(one.list_streams(""), batch.list_streams(""));
        for s in one.list_streams("") {
            assert_eq!(one.series(&s), batch.series(&s), "series {s} diverged");
        }
        one.wal.get().unwrap().commit().unwrap();
        batch.wal.get().unwrap().commit().unwrap();
        assert_eq!(
            std::fs::read(one.wal.get().unwrap().path()).unwrap(),
            std::fs::read(batch.wal.get().unwrap().path()).unwrap(),
            "WAL bytes must be identical"
        );
        batch.emit_batch(&[]); // empty batch is a no-op
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn best_so_far_monotone() {
        let m = MetricsService::new();
        for (t, v) in [(0.0, 5.0), (1.0, 3.0), (2.0, 4.0), (3.0, 1.0)] {
            m.emit("s", t, v);
        }
        let mins: Vec<f64> = m.best_so_far("s", true).iter().map(|p| p.value).collect();
        assert_eq!(mins, vec![5.0, 3.0, 3.0, 1.0]);
        let maxs: Vec<f64> = m.best_so_far("s", false).iter().map(|p| p.value).collect();
        assert_eq!(maxs, vec![5.0, 5.0, 5.0, 5.0]);
    }
}
