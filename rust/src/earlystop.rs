//! Early stopping of training jobs (§5.2) and successive-halving baselines
//! (§2.3).
//!
//! AMT's production rule is the **median rule** [Golovin et al., Google
//! Vizier]: stop an evaluation at iteration r when its intermediate metric
//! is worse than the median of previously evaluated configurations *at the
//! same iteration r*. Two resilience refinements from the paper are
//! implemented faithfully:
//!
//! 1. stopping decisions are made only after a dynamic iteration threshold
//!    derived from the duration of fully completed evaluations (poor early
//!    fidelities are not always representative of final values);
//! 2. the "always complete 10 evaluations first" safeguard the authors
//!    evaluated and discarded is available as an option for the ablation
//!    bench (`min_completed_jobs`).
//!
//! All curves at this layer are in minimization orientation.

/// Record of a finished (completed or stopped) evaluation's curve.
#[derive(Clone, Debug)]
pub struct FinishedCurve {
    /// Intermediate metric values, epochs 1..=len.
    pub values: Vec<f64>,
    /// Whether the job ran to its full epoch budget.
    pub completed: bool,
}

/// History of finished curves a stopping policy can condition on.
#[derive(Clone, Debug, Default)]
pub struct CurveHistory {
    /// All finished curves (stopped ones included — their prefixes count
    /// toward the per-iteration medians, as in Vizier).
    pub curves: Vec<FinishedCurve>,
}

impl CurveHistory {
    /// Add a finished curve.
    pub fn push(&mut self, values: Vec<f64>, completed: bool) {
        self.curves.push(FinishedCurve { values, completed });
    }

    /// Number of *fully completed* evaluations.
    pub fn num_completed(&self) -> usize {
        self.curves.iter().filter(|c| c.completed).count()
    }

    /// Values observed at 1-based epoch `r` across finished curves.
    pub fn values_at(&self, r: u32) -> Vec<f64> {
        self.curves
            .iter()
            .filter_map(|c| c.values.get(r as usize - 1).copied())
            .collect()
    }

    /// JSON wire form: the full band history every stopping policy
    /// conditions on, frozen into [`crate::coordinator`] resume
    /// snapshots (curve values round-trip bit-exactly, so a resumed
    /// job's stopping decisions are identical to the uninterrupted
    /// run's).
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        Json::Arr(
            self.curves
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        (
                            "values",
                            Json::Arr(c.values.iter().map(|&v| Json::Num(v)).collect()),
                        ),
                        ("completed", Json::Bool(c.completed)),
                    ])
                })
                .collect(),
        )
    }

    /// Parse the JSON wire form.
    pub fn from_json(j: &crate::json::Json) -> Option<CurveHistory> {
        use crate::json::Json;
        let mut curves = Vec::new();
        for c in j.as_arr()? {
            curves.push(FinishedCurve {
                values: c
                    .get("values")?
                    .as_arr()?
                    .iter()
                    .map(Json::as_f64)
                    .collect::<Option<_>>()?,
                completed: c.get("completed")?.as_bool()?,
            });
        }
        Some(CurveHistory { curves })
    }

    /// Median epoch count among completed runs (the paper's dynamic
    /// activation signal: "determined dynamically based on the duration of
    /// the fully completed hyperparameter evaluations").
    pub fn median_completed_epochs(&self) -> Option<f64> {
        let mut lens: Vec<f64> = self
            .curves
            .iter()
            .filter(|c| c.completed)
            .map(|c| c.values.len() as f64)
            .collect();
        if lens.is_empty() {
            return None;
        }
        lens.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(median_sorted(&lens))
    }
}

fn median_sorted(sorted: &[f64]) -> f64 {
    let n = sorted.len();
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    }
}

/// Median of an unsorted slice.
pub fn median(values: &[f64]) -> f64 {
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    median_sorted(&v)
}

/// A decision point for a running evaluation.
pub trait StoppingPolicy: Send + Sync {
    /// Policy name for logs.
    fn name(&self) -> &'static str;
    /// Decide after 1-based epoch `epoch` with the running job's curve so
    /// far; `history` holds finished curves of sibling evaluations.
    fn should_stop(&self, curve_so_far: &[f64], epoch: u32, history: &CurveHistory) -> bool;
}

/// Never stop (the "without early stopping" arm of Fig 4).
pub struct NoStopping;

impl StoppingPolicy for NoStopping {
    fn name(&self) -> &'static str {
        "off"
    }
    fn should_stop(&self, _c: &[f64], _e: u32, _h: &CurveHistory) -> bool {
        false
    }
}

/// AMT's median rule with dynamic activation (§5.2).
#[derive(Clone, Debug)]
pub struct MedianRule {
    /// Fraction of the median completed-run length before stopping
    /// decisions activate.
    pub activation_fraction: f64,
    /// Hard floor on the activation epoch.
    pub min_epochs: u32,
    /// Optional safeguard: require this many *completed* evaluations before
    /// stopping anything (paper evaluated 10 and discarded it; kept for the
    /// ablation bench).
    pub min_completed_jobs: usize,
}

impl Default for MedianRule {
    fn default() -> Self {
        MedianRule { activation_fraction: 0.25, min_epochs: 2, min_completed_jobs: 0 }
    }
}

impl MedianRule {
    /// The dynamic activation epoch given current history.
    pub fn activation_epoch(&self, history: &CurveHistory) -> u32 {
        match history.median_completed_epochs() {
            Some(m) => ((m * self.activation_fraction).ceil() as u32).max(self.min_epochs),
            None => u32::MAX, // nothing completed yet ⇒ never stop
        }
    }
}

impl StoppingPolicy for MedianRule {
    fn name(&self) -> &'static str {
        "median"
    }
    fn should_stop(&self, curve_so_far: &[f64], epoch: u32, history: &CurveHistory) -> bool {
        if history.num_completed() < self.min_completed_jobs.max(1) {
            return false;
        }
        if epoch < self.activation_epoch(history) {
            return false;
        }
        let peers = history.values_at(epoch);
        if peers.len() < 2 {
            return false;
        }
        let cur = match curve_so_far.get(epoch as usize - 1) {
            Some(v) => *v,
            None => return false,
        };
        cur > median(&peers)
    }
}

/// Linear learning-curve extrapolation baseline (§5.2 compares the median
/// rule against model-based prediction; this is the linear predictor).
#[derive(Clone, Debug)]
pub struct LinearExtrapolation {
    /// Points of the running curve used for the fit.
    pub window: usize,
    /// Epoch budget to extrapolate to.
    pub horizon: u32,
    /// Activate only after this many epochs.
    pub min_epochs: u32,
}

impl Default for LinearExtrapolation {
    fn default() -> Self {
        LinearExtrapolation { window: 5, horizon: 0, min_epochs: 4 }
    }
}

impl StoppingPolicy for LinearExtrapolation {
    fn name(&self) -> &'static str {
        "linear_extrapolation"
    }
    fn should_stop(&self, curve_so_far: &[f64], epoch: u32, history: &CurveHistory) -> bool {
        if epoch < self.min_epochs || curve_so_far.len() < self.window {
            return false;
        }
        // best completed final value so far
        let best_final = history
            .curves
            .iter()
            .filter(|c| c.completed)
            .filter_map(|c| c.values.last().copied())
            .fold(f64::INFINITY, f64::min);
        if !best_final.is_finite() {
            return false;
        }
        // least-squares line through the last `window` points
        let tail = &curve_so_far[curve_so_far.len() - self.window..];
        let n = tail.len() as f64;
        let tbar = (n - 1.0) / 2.0;
        let ybar = tail.iter().sum::<f64>() / n;
        let mut num = 0.0;
        let mut den = 0.0;
        for (i, y) in tail.iter().enumerate() {
            num += (i as f64 - tbar) * (y - ybar);
            den += (i as f64 - tbar).powi(2);
        }
        let slope = if den > 0.0 { num / den } else { 0.0 };
        let horizon = if self.horizon > 0 {
            self.horizon
        } else {
            history
                .median_completed_epochs()
                .map(|m| m as u32)
                .unwrap_or(epoch)
        };
        let steps_left = horizon.saturating_sub(epoch) as f64;
        let predicted_final = tail[tail.len() - 1] + slope.min(0.0) * steps_left;
        predicted_final > best_final
    }
}

/// Asynchronous successive halving (ASHA, §2.3): stop at rung boundaries
/// (min_r · ηᵏ) unless the running value is within the top 1/η of observed
/// values at that rung. Configurations are chosen by any [`crate::strategies::Strategy`]
/// (classically random), making this the multi-fidelity baseline the paper
/// cites.
#[derive(Clone, Debug)]
pub struct AshaRule {
    /// Smallest rung resource (epochs).
    pub min_resource: u32,
    /// Reduction factor η.
    pub eta: u32,
}

impl Default for AshaRule {
    fn default() -> Self {
        AshaRule { min_resource: 1, eta: 3 }
    }
}

impl AshaRule {
    /// Whether `epoch` is a rung boundary.
    pub fn is_rung(&self, epoch: u32) -> bool {
        let mut r = self.min_resource;
        while r <= epoch {
            if r == epoch {
                return true;
            }
            r *= self.eta;
        }
        false
    }
}

impl StoppingPolicy for AshaRule {
    fn name(&self) -> &'static str {
        "asha"
    }
    fn should_stop(&self, curve_so_far: &[f64], epoch: u32, history: &CurveHistory) -> bool {
        if !self.is_rung(epoch) {
            return false;
        }
        let mut peers = history.values_at(epoch);
        if peers.len() < self.eta as usize {
            return false;
        }
        let cur = match curve_so_far.get(epoch as usize - 1) {
            Some(v) => *v,
            None => return false,
        };
        peers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut = peers[(peers.len() / self.eta as usize).saturating_sub(1).min(peers.len() - 1)];
        cur > cut
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn history_with(curves: &[&[f64]]) -> CurveHistory {
        let mut h = CurveHistory::default();
        for c in curves {
            h.push(c.to_vec(), true);
        }
        h
    }

    #[test]
    fn median_helper() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn median_rule_stops_bad_job() {
        let h = history_with(&[
            &[0.9, 0.5, 0.3, 0.2],
            &[0.8, 0.6, 0.4, 0.3],
            &[0.7, 0.4, 0.2, 0.1],
        ]);
        let rule = MedianRule::default();
        // activation: median completed epochs = 4, fraction 0.25 ⇒ epoch 2
        assert_eq!(rule.activation_epoch(&h), 2);
        // running job much worse than the median at epoch 2 (0.5)
        assert!(rule.should_stop(&[0.95, 0.9], 2, &h));
        // and a good one survives
        assert!(!rule.should_stop(&[0.6, 0.3], 2, &h));
    }

    #[test]
    fn median_rule_inactive_before_threshold() {
        let h = history_with(&[&[0.9; 20], &[0.8; 20]]);
        let rule = MedianRule::default();
        // activation = ceil(20 * 0.25) = 5
        assert_eq!(rule.activation_epoch(&h), 5);
        assert!(!rule.should_stop(&[10.0, 10.0, 10.0, 10.0], 4, &h));
        assert!(rule.should_stop(&[10.0; 5], 5, &h));
    }

    #[test]
    fn median_rule_never_stops_without_completed_jobs() {
        let h = CurveHistory::default();
        let rule = MedianRule::default();
        assert!(!rule.should_stop(&[100.0; 10], 10, &h));
    }

    #[test]
    fn min_completed_jobs_safeguard() {
        let h = history_with(&[&[0.1, 0.1], &[0.1, 0.1]]);
        let rule = MedianRule { min_completed_jobs: 10, ..Default::default() };
        assert!(!rule.should_stop(&[9.9, 9.9], 2, &h));
        let rule = MedianRule { min_completed_jobs: 2, ..Default::default() };
        assert!(rule.should_stop(&[9.9, 9.9], 2, &h));
    }

    #[test]
    fn curve_history_json_roundtrip_is_bit_exact() {
        let mut h = CurveHistory::default();
        h.push(vec![0.5, 1.0 / 3.0, 1e-300], true);
        h.push(vec![0.9], false);
        let text = h.to_json().to_string();
        let back = CurveHistory::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.curves.len(), 2);
        for (a, b) in h.curves.iter().zip(&back.curves) {
            assert_eq!(a.completed, b.completed);
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a.values), bits(&b.values));
        }
    }

    #[test]
    fn stopped_prefixes_count_toward_medians() {
        let mut h = CurveHistory::default();
        h.push(vec![0.5, 0.4, 0.3, 0.2], true);
        h.push(vec![0.9, 0.9], false); // stopped early
        assert_eq!(h.values_at(2).len(), 2);
        assert_eq!(h.num_completed(), 1);
    }

    #[test]
    fn linear_extrapolation_stops_flat_bad_curves() {
        let mut h = CurveHistory::default();
        h.push(vec![0.9, 0.5, 0.3, 0.25, 0.2, 0.18, 0.17, 0.16], true);
        let rule = LinearExtrapolation::default();
        // running curve plateaued at 0.6 — cannot reach 0.16
        let flat = vec![0.9, 0.8, 0.65, 0.62, 0.61, 0.6];
        assert!(rule.should_stop(&flat, 6, &h));
        // steeply improving curve is spared
        let steep = vec![0.9, 0.5, 0.4, 0.3, 0.2, 0.15];
        assert!(!rule.should_stop(&steep, 6, &h));
    }

    #[test]
    fn asha_rungs_and_cuts() {
        let rule = AshaRule { min_resource: 1, eta: 3 };
        assert!(rule.is_rung(1));
        assert!(rule.is_rung(3));
        assert!(rule.is_rung(9));
        assert!(!rule.is_rung(2));
        assert!(!rule.is_rung(6));

        let h = history_with(&[
            &[0.1, 0.1, 0.1],
            &[0.2, 0.2, 0.2],
            &[0.3, 0.3, 0.3],
            &[0.4, 0.4, 0.4],
            &[0.5, 0.5, 0.5],
            &[0.6, 0.6, 0.6],
        ]);
        // top 1/3 at rung 3 is ~0.2; a 0.55 value must stop, 0.15 survives
        assert!(rule.should_stop(&[0.55, 0.55, 0.55], 3, &h));
        assert!(!rule.should_stop(&[0.15, 0.15, 0.15], 3, &h));
        // non-rung epoch: never stop
        assert!(!rule.should_stop(&[0.99, 0.99], 2, &h));
    }

    #[test]
    fn no_stopping_is_inert() {
        let h = history_with(&[&[0.0; 5]]);
        assert!(!NoStopping.should_stop(&[f64::INFINITY; 5], 5, &h));
    }
}
