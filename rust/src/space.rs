//! Hyperparameter search-space definition and encoding (§4.1, §5.1).
//!
//! A [`SearchSpace`] is an ordered list of parameter ranges — continuous,
//! integer, or categorical. For the surrogate model every configuration is
//! encoded into `[0, 1]^D`: numeric parameters map through their *scaling*
//! (linear, logarithmic, or reverse-logarithmic — §5.1 "log scaling") and
//! categoricals are one-hot encoded, exactly as the paper describes
//! (integers are handled in the continuous space and rounded on decode).
//!
//! Random search samples uniformly **in the transformed space**, which is
//! what makes log scaling useful for model-free search too (§5.1).

use std::collections::BTreeMap;

use crate::json::Json;
use crate::rng::Rng;

/// Numeric-parameter scaling (SageMaker's `ScalingType`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Scaling {
    /// Pick automatically: logarithmic when the range spans ≥ 3 decades and
    /// is strictly positive, linear otherwise.
    #[default]
    Auto,
    Linear,
    Logarithmic,
    /// For ranges inside [0, 1) whose interesting region hugs 1 (e.g. decay
    /// rates): log-transform the distance to 1.
    ReverseLogarithmic,
}

/// One tunable hyperparameter.
#[derive(Clone, Debug, PartialEq)]
pub enum ParameterRange {
    Continuous { name: String, min_value: f64, max_value: f64, scaling: Scaling },
    Integer { name: String, min_value: i64, max_value: i64, scaling: Scaling },
    Categorical { name: String, values: Vec<String> },
}

impl ParameterRange {
    /// Parameter name.
    pub fn name(&self) -> &str {
        match self {
            ParameterRange::Continuous { name, .. } => name,
            ParameterRange::Integer { name, .. } => name,
            ParameterRange::Categorical { name, .. } => name,
        }
    }

    /// Number of encoded dimensions this parameter occupies.
    pub fn encoded_width(&self) -> usize {
        match self {
            ParameterRange::Categorical { values, .. } => values.len(),
            _ => 1,
        }
    }
}

/// A concrete hyperparameter value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Float(f64),
    Int(i64),
    Cat(String),
}

impl Value {
    /// Numeric view (errors for categoricals).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            Value::Cat(_) => None,
        }
    }

    /// Categorical view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Cat(s) => Some(s),
            _ => None,
        }
    }
}

/// A full hyperparameter configuration, keyed by parameter name.
pub type Config = BTreeMap<String, Value>;

/// Errors raised by space validation / encoding.
#[derive(Debug, PartialEq, Eq)]
pub enum SpaceError {
    EmptySpace,
    DuplicateName(String),
    InvalidRange(String),
    LogRequiresPositive(String),
    ReverseLogRequiresUnit(String),
    EmptyCategories(String),
    MissingParameter(String),
    TypeMismatch(String),
    OutOfRange(String),
    UnknownCategory(String),
}

impl std::fmt::Display for SpaceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for SpaceError {}

/// Ordered collection of parameter ranges.
#[derive(Clone, Debug, PartialEq)]
pub struct SearchSpace {
    pub parameters: Vec<ParameterRange>,
}

impl SearchSpace {
    /// Build and validate a search space.
    pub fn new(parameters: Vec<ParameterRange>) -> Result<Self, SpaceError> {
        if parameters.is_empty() {
            return Err(SpaceError::EmptySpace);
        }
        let mut seen = std::collections::BTreeSet::new();
        for p in &parameters {
            if !seen.insert(p.name().to_string()) {
                return Err(SpaceError::DuplicateName(p.name().to_string()));
            }
            match p {
                ParameterRange::Continuous { name, min_value, max_value, scaling } => {
                    if !(min_value < max_value) || !min_value.is_finite() || !max_value.is_finite()
                    {
                        return Err(SpaceError::InvalidRange(name.clone()));
                    }
                    validate_scaling(name, *min_value, *max_value, *scaling)?;
                }
                ParameterRange::Integer { name, min_value, max_value, scaling } => {
                    if min_value >= max_value {
                        return Err(SpaceError::InvalidRange(name.clone()));
                    }
                    validate_scaling(name, *min_value as f64, *max_value as f64, *scaling)?;
                }
                ParameterRange::Categorical { name, values } => {
                    if values.is_empty() {
                        return Err(SpaceError::EmptyCategories(name.clone()));
                    }
                }
            }
        }
        Ok(SearchSpace { parameters })
    }

    /// Total encoded dimensionality D.
    pub fn encoded_dim(&self) -> usize {
        self.parameters.iter().map(|p| p.encoded_width()).sum()
    }

    /// Look up a parameter by name.
    pub fn parameter(&self, name: &str) -> Option<&ParameterRange> {
        self.parameters.iter().find(|p| p.name() == name)
    }

    /// Encode a configuration into `[0, 1]^D`.
    pub fn encode(&self, config: &Config) -> Result<Vec<f64>, SpaceError> {
        let mut out = Vec::with_capacity(self.encoded_dim());
        for p in &self.parameters {
            let v = config
                .get(p.name())
                .ok_or_else(|| SpaceError::MissingParameter(p.name().to_string()))?;
            match p {
                ParameterRange::Continuous { name, min_value, max_value, scaling } => {
                    let x = v.as_f64().ok_or_else(|| SpaceError::TypeMismatch(name.clone()))?;
                    if x < *min_value || x > *max_value {
                        return Err(SpaceError::OutOfRange(name.clone()));
                    }
                    out.push(to_unit(x, *min_value, *max_value, *scaling));
                }
                ParameterRange::Integer { name, min_value, max_value, scaling } => {
                    let x = v.as_f64().ok_or_else(|| SpaceError::TypeMismatch(name.clone()))?;
                    if x < *min_value as f64 || x > *max_value as f64 {
                        return Err(SpaceError::OutOfRange(name.clone()));
                    }
                    out.push(to_unit(x, *min_value as f64, *max_value as f64, *scaling));
                }
                ParameterRange::Categorical { name, values } => {
                    let s = v.as_str().ok_or_else(|| SpaceError::TypeMismatch(name.clone()))?;
                    let idx = values
                        .iter()
                        .position(|c| c == s)
                        .ok_or_else(|| SpaceError::UnknownCategory(name.clone()))?;
                    for i in 0..values.len() {
                        out.push(if i == idx { 1.0 } else { 0.0 });
                    }
                }
            }
        }
        Ok(out)
    }

    /// Decode a point of `[0, 1]^D` back into a configuration (clamping,
    /// integer rounding, categorical argmax).
    pub fn decode(&self, u: &[f64]) -> Config {
        assert!(u.len() >= self.encoded_dim(), "decode: point too short");
        let mut config = Config::new();
        let mut off = 0;
        for p in &self.parameters {
            match p {
                ParameterRange::Continuous { name, min_value, max_value, scaling } => {
                    let x = from_unit(u[off].clamp(0.0, 1.0), *min_value, *max_value, *scaling);
                    config.insert(name.clone(), Value::Float(x.clamp(*min_value, *max_value)));
                    off += 1;
                }
                ParameterRange::Integer { name, min_value, max_value, scaling } => {
                    let x = from_unit(
                        u[off].clamp(0.0, 1.0),
                        *min_value as f64,
                        *max_value as f64,
                        *scaling,
                    );
                    let r = (x.round() as i64).clamp(*min_value, *max_value);
                    config.insert(name.clone(), Value::Int(r));
                    off += 1;
                }
                ParameterRange::Categorical { name, values } => {
                    let slice = &u[off..off + values.len()];
                    let idx = slice
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    config.insert(name.clone(), Value::Cat(values[idx].clone()));
                    off += values.len();
                }
            }
        }
        config
    }

    /// Uniform sample **in the transformed space** (this is what the paper's
    /// log scaling does for random search).
    pub fn sample(&self, rng: &mut Rng) -> Config {
        let u: Vec<f64> = (0..self.encoded_dim()).map(|_| rng.uniform()).collect();
        self.decode(&u)
    }

    /// Cartesian grid with `k` values per numeric parameter (grid search,
    /// §2.1). Categorical parameters enumerate all categories.
    pub fn grid(&self, k: usize) -> Vec<Config> {
        assert!(k >= 2);
        let mut axes: Vec<Vec<Value>> = Vec::new();
        for p in &self.parameters {
            match p {
                ParameterRange::Continuous { min_value, max_value, scaling, .. } => {
                    axes.push(
                        (0..k)
                            .map(|i| {
                                let u = i as f64 / (k - 1) as f64;
                                Value::Float(from_unit(u, *min_value, *max_value, *scaling))
                            })
                            .collect(),
                    );
                }
                ParameterRange::Integer { min_value, max_value, scaling, .. } => {
                    let mut vals: Vec<i64> = (0..k)
                        .map(|i| {
                            let u = i as f64 / (k - 1) as f64;
                            from_unit(u, *min_value as f64, *max_value as f64, *scaling).round()
                                as i64
                        })
                        .collect();
                    vals.dedup();
                    axes.push(vals.into_iter().map(Value::Int).collect());
                }
                ParameterRange::Categorical { values, .. } => {
                    axes.push(values.iter().cloned().map(Value::Cat).collect());
                }
            }
        }
        let mut configs = vec![Config::new()];
        for (p, axis) in self.parameters.iter().zip(axes) {
            let mut next = Vec::with_capacity(configs.len() * axis.len());
            for c in &configs {
                for v in &axis {
                    let mut c2 = c.clone();
                    c2.insert(p.name().to_string(), v.clone());
                    next.push(c2);
                }
            }
            configs = next;
        }
        configs
    }

    /// Clamp a configuration into this space (used by warm start to remap
    /// parent-job configurations; handles the §6.2 lesson where a parent's
    /// linear-scale value 0 is invalid under a child's log scale).
    pub fn clamp(&self, config: &Config) -> Config {
        let mut out = Config::new();
        for p in &self.parameters {
            let v = config.get(p.name());
            match p {
                ParameterRange::Continuous { name, min_value, max_value, .. } => {
                    let x = v.and_then(Value::as_f64).unwrap_or((min_value + max_value) / 2.0);
                    out.insert(name.clone(), Value::Float(x.clamp(*min_value, *max_value)));
                }
                ParameterRange::Integer { name, min_value, max_value, .. } => {
                    let x = v
                        .and_then(Value::as_f64)
                        .unwrap_or((min_value + max_value) as f64 / 2.0);
                    out.insert(
                        name.clone(),
                        Value::Int((x.round() as i64).clamp(*min_value, *max_value)),
                    );
                }
                ParameterRange::Categorical { name, values } => {
                    let s = v
                        .and_then(Value::as_str)
                        .filter(|s| values.iter().any(|c| c == s))
                        .unwrap_or(&values[0]);
                    out.insert(name.clone(), Value::Cat(s.to_string()));
                }
            }
        }
        out
    }
}

fn validate_scaling(name: &str, min: f64, max: f64, scaling: Scaling) -> Result<(), SpaceError> {
    match scaling {
        Scaling::Logarithmic if min <= 0.0 => {
            Err(SpaceError::LogRequiresPositive(name.to_string()))
        }
        Scaling::ReverseLogarithmic if !(0.0 <= min && max < 1.0) => {
            Err(SpaceError::ReverseLogRequiresUnit(name.to_string()))
        }
        _ => Ok(()),
    }
}

/// Resolve `Auto` into a concrete scaling for a numeric range.
pub fn resolve_auto(min: f64, max: f64, scaling: Scaling) -> Scaling {
    match scaling {
        Scaling::Auto => {
            if min > 0.0 && max / min >= 1000.0 {
                Scaling::Logarithmic
            } else {
                Scaling::Linear
            }
        }
        s => s,
    }
}

/// Map a raw value into [0, 1] under the given scaling.
pub fn to_unit(x: f64, min: f64, max: f64, scaling: Scaling) -> f64 {
    match resolve_auto(min, max, scaling) {
        Scaling::Linear | Scaling::Auto => (x - min) / (max - min),
        Scaling::Logarithmic => (x.ln() - min.ln()) / (max.ln() - min.ln()),
        Scaling::ReverseLogarithmic => {
            ((1.0 - min).ln() - (1.0 - x).ln()) / ((1.0 - min).ln() - (1.0 - max).ln())
        }
    }
}

/// Inverse of [`to_unit`].
pub fn from_unit(u: f64, min: f64, max: f64, scaling: Scaling) -> f64 {
    match resolve_auto(min, max, scaling) {
        Scaling::Linear | Scaling::Auto => min + u * (max - min),
        Scaling::Logarithmic => (min.ln() + u * (max.ln() - min.ln())).exp(),
        Scaling::ReverseLogarithmic => {
            1.0 - ((1.0 - min).ln() - u * ((1.0 - min).ln() - (1.0 - max).ln())).exp()
        }
    }
}

// --------------------------- JSON conversions -----------------------------

impl Scaling {
    /// API string form (mirrors SageMaker's `ScalingType`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Scaling::Auto => "Auto",
            Scaling::Linear => "Linear",
            Scaling::Logarithmic => "Logarithmic",
            Scaling::ReverseLogarithmic => "ReverseLogarithmic",
        }
    }

    /// Parse the API string form.
    pub fn from_str_name(s: &str) -> Option<Scaling> {
        Some(match s {
            "Auto" => Scaling::Auto,
            "Linear" => Scaling::Linear,
            "Logarithmic" => Scaling::Logarithmic,
            "ReverseLogarithmic" => Scaling::ReverseLogarithmic,
            _ => return None,
        })
    }
}

impl Value {
    /// JSON form.
    pub fn to_json(&self) -> Json {
        match self {
            Value::Float(v) => Json::Num(*v),
            Value::Int(v) => Json::Num(*v as f64),
            Value::Cat(s) => Json::Str(s.clone()),
        }
    }

    /// From JSON (numbers become Float unless integral-and-int-typed later).
    pub fn from_json(j: &Json) -> Option<Value> {
        match j {
            Json::Num(n) => Some(Value::Float(*n)),
            Json::Str(s) => Some(Value::Cat(s.clone())),
            _ => None,
        }
    }
}

/// Type-tagged JSON form of a [`Value`]: unlike [`Value::to_json`] (whose
/// reader collapses ints to floats), `Int` is written as `{"int": n}` so
/// the round trip through [`value_from_json_typed`] is exact. This is the
/// encoding the durable `warm_start` table and the distributed wire
/// protocol use — a config shipped across a process boundary comes back
/// with *exactly* its original variants (f64s round-trip bit-exactly
/// through the JSON layer).
pub fn value_to_json_typed(v: &Value) -> Json {
    match v {
        Value::Float(f) => Json::Num(*f),
        Value::Int(i) => Json::obj(vec![("int", Json::Num(*i as f64))]),
        Value::Cat(s) => Json::Str(s.clone()),
    }
}

/// Reader for [`value_to_json_typed`].
pub fn value_from_json_typed(j: &Json) -> Option<Value> {
    match j {
        Json::Num(n) => Some(Value::Float(*n)),
        Json::Str(s) => Some(Value::Cat(s.clone())),
        Json::Obj(_) => Some(Value::Int(j.get("int")?.as_i64()?)),
        _ => None,
    }
}

/// Serialize a configuration with type-tagged values (exact round trip;
/// see [`value_to_json_typed`]).
pub fn config_to_json_typed(config: &Config) -> Json {
    Json::Obj(config.iter().map(|(k, v)| (k.clone(), value_to_json_typed(v))).collect())
}

/// Deserialize a type-tagged configuration.
pub fn config_from_json_typed(j: &Json) -> Option<Config> {
    let obj = j.as_obj()?;
    let mut cfg = Config::new();
    for (k, v) in obj {
        cfg.insert(k.clone(), value_from_json_typed(v)?);
    }
    Some(cfg)
}

/// Serialize a configuration.
pub fn config_to_json(config: &Config) -> Json {
    Json::Obj(config.iter().map(|(k, v)| (k.clone(), v.to_json())).collect())
}

/// Deserialize a configuration (numeric values become `Value::Float`; use
/// [`SearchSpace::clamp`] to coerce types against a space).
pub fn config_from_json(j: &Json) -> Option<Config> {
    let obj = j.as_obj()?;
    let mut cfg = Config::new();
    for (k, v) in obj {
        cfg.insert(k.clone(), Value::from_json(v)?);
    }
    Some(cfg)
}

impl ParameterRange {
    /// JSON form (tagged by `type`).
    pub fn to_json(&self) -> Json {
        match self {
            ParameterRange::Continuous { name, min_value, max_value, scaling } => Json::obj(vec![
                ("type", Json::Str("Continuous".into())),
                ("name", Json::Str(name.clone())),
                ("min_value", Json::Num(*min_value)),
                ("max_value", Json::Num(*max_value)),
                ("scaling", Json::Str(scaling.as_str().into())),
            ]),
            ParameterRange::Integer { name, min_value, max_value, scaling } => Json::obj(vec![
                ("type", Json::Str("Integer".into())),
                ("name", Json::Str(name.clone())),
                ("min_value", Json::Num(*min_value as f64)),
                ("max_value", Json::Num(*max_value as f64)),
                ("scaling", Json::Str(scaling.as_str().into())),
            ]),
            ParameterRange::Categorical { name, values } => Json::obj(vec![
                ("type", Json::Str("Categorical".into())),
                ("name", Json::Str(name.clone())),
                (
                    "values",
                    Json::Arr(values.iter().map(|v| Json::Str(v.clone())).collect()),
                ),
            ]),
        }
    }

    /// Parse the JSON form.
    pub fn from_json(j: &Json) -> Option<ParameterRange> {
        let ty = j.get("type")?.as_str()?;
        let name = j.get("name")?.as_str()?.to_string();
        match ty {
            "Continuous" => Some(ParameterRange::Continuous {
                name,
                min_value: j.get("min_value")?.as_f64()?,
                max_value: j.get("max_value")?.as_f64()?,
                scaling: Scaling::from_str_name(j.get("scaling")?.as_str()?)?,
            }),
            "Integer" => Some(ParameterRange::Integer {
                name,
                min_value: j.get("min_value")?.as_i64()?,
                max_value: j.get("max_value")?.as_i64()?,
                scaling: Scaling::from_str_name(j.get("scaling")?.as_str()?)?,
            }),
            "Categorical" => Some(ParameterRange::Categorical {
                name,
                values: j
                    .get("values")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_str().map(String::from))
                    .collect::<Option<Vec<_>>>()?,
            }),
            _ => None,
        }
    }
}

impl SearchSpace {
    /// JSON form.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.parameters.iter().map(|p| p.to_json()).collect())
    }

    /// Parse and validate the JSON form.
    pub fn from_json(j: &Json) -> Option<SearchSpace> {
        let params = j
            .as_arr()?
            .iter()
            .map(ParameterRange::from_json)
            .collect::<Option<Vec<_>>>()?;
        SearchSpace::new(params).ok()
    }
}

/// Convenience constructors.
pub fn continuous(name: &str, min: f64, max: f64, scaling: Scaling) -> ParameterRange {
    ParameterRange::Continuous { name: name.into(), min_value: min, max_value: max, scaling }
}

/// Integer range helper.
pub fn integer(name: &str, min: i64, max: i64, scaling: Scaling) -> ParameterRange {
    ParameterRange::Integer { name: name.into(), min_value: min, max_value: max, scaling }
}

/// Categorical range helper.
pub fn categorical(name: &str, values: &[&str]) -> ParameterRange {
    ParameterRange::Categorical {
        name: name.into(),
        values: values.iter().map(|s| s.to_string()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_space() -> SearchSpace {
        SearchSpace::new(vec![
            continuous("learning_rate", 1e-5, 1.0, Scaling::Logarithmic),
            integer("depth", 1, 16, Scaling::Linear),
            categorical("loss", &["hinge", "logistic", "huber"]),
        ])
        .unwrap()
    }

    #[test]
    fn encoded_dim_counts_onehot() {
        assert_eq!(demo_space().encoded_dim(), 1 + 1 + 3);
    }

    #[test]
    fn encode_decode_roundtrip_exact_for_int_and_cat() {
        let space = demo_space();
        let mut cfg = Config::new();
        cfg.insert("learning_rate".into(), Value::Float(0.01));
        cfg.insert("depth".into(), Value::Int(7));
        cfg.insert("loss".into(), Value::Cat("logistic".into()));
        let enc = space.encode(&cfg).unwrap();
        let dec = space.decode(&enc);
        assert_eq!(dec.get("depth"), Some(&Value::Int(7)));
        assert_eq!(dec.get("loss"), Some(&Value::Cat("logistic".into())));
        let lr = dec.get("learning_rate").unwrap().as_f64().unwrap();
        assert!((lr.ln() - 0.01f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn log_scaling_centers_geometric_mean() {
        // u = 0.5 must decode to the geometric mean of the range
        let x = from_unit(0.5, 1e-9, 1e9, Scaling::Logarithmic);
        assert!((x - 1.0).abs() < 1e-6, "{x}");
    }

    #[test]
    fn auto_resolves_to_log_for_wide_positive_ranges() {
        assert_eq!(resolve_auto(1e-6, 1.0, Scaling::Auto), Scaling::Logarithmic);
        assert_eq!(resolve_auto(0.0, 1.0, Scaling::Auto), Scaling::Linear);
        assert_eq!(resolve_auto(1.0, 10.0, Scaling::Auto), Scaling::Linear);
    }

    #[test]
    fn reverse_log_maps_bounds() {
        let (min, max) = (0.0, 0.999);
        assert!((to_unit(min, min, max, Scaling::ReverseLogarithmic)).abs() < 1e-12);
        assert!((to_unit(max, min, max, Scaling::ReverseLogarithmic) - 1.0).abs() < 1e-12);
        let mid = from_unit(0.5, min, max, Scaling::ReverseLogarithmic);
        assert!(mid > 0.9, "reverse log should hug 1: {mid}");
    }

    #[test]
    fn validation_rejects_bad_spaces() {
        assert_eq!(SearchSpace::new(vec![]).unwrap_err(), SpaceError::EmptySpace);
        assert!(matches!(
            SearchSpace::new(vec![continuous("x", 1.0, 1.0, Scaling::Linear)]).unwrap_err(),
            SpaceError::InvalidRange(_)
        ));
        assert!(matches!(
            SearchSpace::new(vec![continuous("x", 0.0, 1.0, Scaling::Logarithmic)]).unwrap_err(),
            SpaceError::LogRequiresPositive(_)
        ));
        assert!(matches!(
            SearchSpace::new(vec![
                continuous("x", 0.0, 1.0, Scaling::Linear),
                continuous("x", 0.0, 2.0, Scaling::Linear)
            ])
            .unwrap_err(),
            SpaceError::DuplicateName(_)
        ));
        assert!(matches!(
            SearchSpace::new(vec![categorical("c", &[])]).unwrap_err(),
            SpaceError::EmptyCategories(_)
        ));
    }

    #[test]
    fn encode_rejects_out_of_range_and_unknown() {
        let space = demo_space();
        let mut cfg = Config::new();
        cfg.insert("learning_rate".into(), Value::Float(10.0));
        cfg.insert("depth".into(), Value::Int(7));
        cfg.insert("loss".into(), Value::Cat("logistic".into()));
        assert!(matches!(space.encode(&cfg), Err(SpaceError::OutOfRange(_))));
        cfg.insert("learning_rate".into(), Value::Float(0.1));
        cfg.insert("loss".into(), Value::Cat("nope".into()));
        assert!(matches!(space.encode(&cfg), Err(SpaceError::UnknownCategory(_))));
    }

    #[test]
    fn sample_respects_log_scaling_distribution() {
        // under log scaling, ~half the samples of [1e-8, 1] land below 1e-4
        let space =
            SearchSpace::new(vec![continuous("c", 1e-8, 1.0, Scaling::Logarithmic)]).unwrap();
        let mut rng = Rng::new(5);
        let mut below = 0;
        for _ in 0..2000 {
            let c = space.sample(&mut rng);
            if c.get("c").unwrap().as_f64().unwrap() < 1e-4 {
                below += 1;
            }
        }
        let frac = below as f64 / 2000.0;
        assert!((frac - 0.5).abs() < 0.05, "{frac}");
    }

    #[test]
    fn grid_enumerates_cartesian_product() {
        let space = SearchSpace::new(vec![
            continuous("a", 0.0, 1.0, Scaling::Linear),
            categorical("c", &["x", "y"]),
        ])
        .unwrap();
        let g = space.grid(3);
        assert_eq!(g.len(), 6);
    }

    #[test]
    fn clamp_handles_log_scale_zero_edge_case() {
        // §6.2: parent explored 0.0 under linear scaling; child uses log
        // scaling on [1e-6, 1]. Clamping must produce a valid value.
        let child =
            SearchSpace::new(vec![continuous("wd", 1e-6, 1.0, Scaling::Logarithmic)]).unwrap();
        let mut parent_cfg = Config::new();
        parent_cfg.insert("wd".into(), Value::Float(0.0));
        let fixed = child.clamp(&parent_cfg);
        let v = fixed.get("wd").unwrap().as_f64().unwrap();
        assert!(v >= 1e-6);
        assert!(child.encode(&fixed).is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let space = demo_space();
        let s = space.to_json().to_string();
        let back = SearchSpace::from_json(&crate::json::parse(&s).unwrap()).unwrap();
        assert_eq!(space, back);
    }

    #[test]
    fn config_json_roundtrip() {
        let mut cfg = Config::new();
        cfg.insert("lr".into(), Value::Float(0.5));
        cfg.insert("opt".into(), Value::Cat("sgd".into()));
        let j = config_to_json(&cfg);
        let back = config_from_json(&crate::json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.get("opt"), Some(&Value::Cat("sgd".into())));
        assert_eq!(back.get("lr").unwrap().as_f64(), Some(0.5));
    }
}
