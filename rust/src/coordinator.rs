//! Tuning-job coordinator: the workflow that ties the Hyperparameter
//! Selection Service, the training platform, the metadata store, the
//! metrics service and the early stopper together (§3.2's "AMT workflows
//! engine ... kicking off the evaluation of hyperparameter configurations
//! from the Hyperparameter Selection Service, starting training jobs,
//! tracking their progress and repeating the process until the stopping
//! criterion is met").
//!
//! The coarse lifecycle (Validate → RunLoop → Finalize) runs on the
//! [`crate::workflow`] state machine; inside the loop the coordinator
//! maintains up to `max_parallel_jobs` in-flight training jobs
//! **asynchronously**: the moment one finishes, its observation updates the
//! strategy and a fresh candidate fills the free slot (§4.4), with failed
//! jobs retried per the §3.3 retry policy.
//!
//! Execution model: each tuning job is a **non-blocking [`JobActor`]** —
//! a resumable state-machine execution ([`crate::workflow::StateMachine::step`])
//! over its own platform timeline. [`JobActor::poll`] drains a bounded
//! slice of [`PlatformEvent`]s and returns control, so the multi-tenant
//! [`crate::scheduler::Scheduler`] can multiplex many jobs over a fixed
//! worker pool. [`TuningJobRunner`] is the single-tenant wrapper that
//! polls one actor to completion on the calling thread — its outcomes are
//! bit-identical to the actor driven through the scheduler.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::config::TuningJobRequest;
use crate::durability::wal::{Wal, WalRecord};
use crate::earlystop::{CurveHistory, StoppingPolicy};
use crate::metrics::MetricsService;
use crate::objectives::Objective;
use crate::platform::{
    JobId, PlatformEvent, TrainingJobSpec, TrainingJobStatus, TrainingPlatform,
};
use crate::space::Config;
use crate::store::MetadataStore;
use crate::strategies::{Observation, Strategy};
use crate::workflow::{
    Execution, ExecutionState, ExecutionStatus, RetryPolicy, StateMachine, StepOutcome,
    Transition,
};
use crate::json::Json;

/// Outcome of one hyperparameter evaluation.
#[derive(Clone, Debug)]
pub struct EvaluationRecord {
    /// Training-job name (unique within the tuning job).
    pub training_job_name: String,
    /// Evaluated configuration.
    pub config: Config,
    /// Intermediate metric values (raw objective orientation).
    pub curve: Vec<f64>,
    /// Final metric (raw orientation), if the job produced one.
    pub final_value: Option<f64>,
    /// Terminal platform status.
    pub status: TrainingJobStatus,
    /// True if the early stopper cut this evaluation short.
    pub stopped_early: bool,
    /// Launch attempts consumed (1 = no retries).
    pub attempts: u32,
    /// Virtual submission time of the first attempt.
    pub submitted_at: f64,
    /// Virtual terminal time.
    pub ended_at: f64,
    /// True when the outcome was served from the cross-job evaluation
    /// cache (DESIGN.md §17) — no training job ever ran for this record.
    pub cached: bool,
}

/// Result of a completed tuning job.
#[derive(Clone, Debug)]
pub struct TuningJobOutcome {
    /// Tuning-job name.
    pub name: String,
    /// All evaluations in completion order.
    pub evaluations: Vec<EvaluationRecord>,
    /// Best configuration and its raw metric value.
    pub best: Option<(Config, f64)>,
    /// Total virtual wall-clock seconds.
    pub total_seconds: f64,
    /// Sum of per-job billable seconds (the §5.2 cost metric).
    pub total_billable_seconds: f64,
    /// Workflow termination status.
    pub status: ExecutionStatus,
    /// Total training-job retries performed.
    pub retries: u32,
}

impl EvaluationRecord {
    /// JSON wire form (configs type-tagged, f64s bit-exact). Shared by
    /// the distributed outcome codec ([`crate::distributed::proto`]) and
    /// the resume-snapshot coordinator block (DESIGN.md §12).
    pub fn to_json(&self) -> Json {
        let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("name", Json::Str(self.training_job_name.clone())),
            ("config", crate::space::config_to_json_typed(&self.config)),
            ("curve", Json::Arr(self.curve.iter().map(|&v| Json::Num(v)).collect())),
            ("final_value", opt_num(self.final_value)),
            ("status", Json::Str(self.status.as_str().into())),
            ("stopped_early", Json::Bool(self.stopped_early)),
            ("attempts", Json::Num(self.attempts as f64)),
            ("submitted_at", Json::Num(self.submitted_at)),
            ("ended_at", Json::Num(self.ended_at)),
            ("cached", Json::Bool(self.cached)),
        ])
    }

    /// Parse the JSON wire form.
    pub fn from_json(j: &Json) -> Option<EvaluationRecord> {
        Some(EvaluationRecord {
            training_job_name: j.get("name")?.as_str()?.to_string(),
            config: crate::space::config_from_json_typed(j.get("config")?)?,
            curve: j.get("curve")?.as_arr()?.iter().map(Json::as_f64).collect::<Option<_>>()?,
            final_value: j.get("final_value").and_then(Json::as_f64),
            status: TrainingJobStatus::parse(j.get("status")?.as_str()?)?,
            stopped_early: j.get("stopped_early")?.as_bool()?,
            attempts: j.get("attempts")?.as_i64()? as u32,
            submitted_at: j.get("submitted_at")?.as_f64()?,
            ended_at: j.get("ended_at")?.as_f64()?,
            // absent on pre-cache records ⇒ not cached
            cached: j.get("cached").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

impl TuningJobOutcome {
    /// Best-so-far series over virtual time (raw orientation): one point
    /// per finished evaluation — the y-axis of Figs 3–5.
    pub fn best_over_time(&self, minimize: bool) -> Vec<(f64, f64)> {
        let mut evs: Vec<&EvaluationRecord> = self.evaluations.iter().collect();
        evs.sort_by(|a, b| a.ended_at.total_cmp(&b.ended_at));
        let mut best = if minimize { f64::INFINITY } else { f64::NEG_INFINITY };
        let mut out = Vec::new();
        for e in evs {
            if let Some(v) = e.final_value {
                best = if minimize { best.min(v) } else { best.max(v) };
                if best.is_finite() {
                    out.push((e.ended_at, best));
                }
            }
        }
        out
    }
}

struct InFlight {
    eval_index: usize,
    platform_id: JobId,
    /// curve in *minimization* orientation for the stopping policy
    curve_min: Vec<f64>,
}

struct LoopCtx {
    request: TuningJobRequest,
    objective: Arc<dyn Objective>,
    strategy: Box<dyn Strategy>,
    stopping: Box<dyn StoppingPolicy>,
    platform: TrainingPlatform,
    store: Arc<MetadataStore>,
    metrics: Arc<MetricsService>,
    stop_flag: Arc<AtomicBool>,
    sign: f64,
    launched: u32,
    history: Vec<Observation>,
    curve_history: CurveHistory,
    in_flight: HashMap<JobId, InFlight>,
    evaluations: Vec<EvaluationRecord>,
    retries: u32,
    /// per-eval remaining retry budget
    retry_budget: Vec<u32>,
    /// In-flight speculative proposal (DESIGN.md §17), populated by
    /// [`JobActor::speculate_step`] in the scheduler's idle tail and
    /// consumed (commit or discard) by the next [`LoopCtx::launch_new`].
    speculation: Option<crate::strategies::Speculation>,
}

/// Canonical evaluation-cache key: `"{objective}|{typed-config JSON}"`.
/// [`crate::space::config_to_json_typed`] is an exact (bit-preserving,
/// key-sorted) encoding, so two configs share a key iff they are the same
/// point of the same objective's space — and one objective's entries form
/// a contiguous, prefix-scannable range in the `eval_cache` table.
pub fn eval_cache_key(objective: &str, config: &Config) -> String {
    format!("{objective}|{}", crate::space::config_to_json_typed(config))
}

/// Schema version of the checkpoint payload [`JobActor::poll`] writes.
/// Legacy (v0) checkpoints carried the bare [`ExecutionState`] cursor;
/// v1 payloads are full [`ResumeSnapshot`]s.
pub const RESUME_SNAPSHOT_VERSION: i64 = 1;

/// A self-sufficient mid-job state capture (schema v1, DESIGN.md §12):
/// everything needed to rebuild a [`JobActor`] at a `Pending` boundary
/// without replaying a single past strategy proposal — the execution
/// cursor, the full strategy state ([`crate::strategies::StrategyState`]),
/// the platform simulator's discrete-event state, and the coordinator
/// run-loop state (observation history, early-stopping bands, in-flight
/// table, evaluation records, retry budgets). A job resumed from any such
/// snapshot produces a bit-identical remaining trajectory, evaluations,
/// metric series and store versions versus the uninterrupted run.
pub struct ResumeSnapshot {
    /// Serialized [`ExecutionState`] cursor.
    pub cursor: Json,
    /// Serialized strategy state (kind-tagged).
    pub strategy: Json,
    /// Serialized [`TrainingPlatform`] discrete-event state.
    pub platform: Json,
    /// Serialized coordinator run-loop state.
    pub coord: Json,
}

impl ResumeSnapshot {
    /// Parse a checkpoint payload; `None` for legacy v0 cursor-only
    /// payloads (which recover via scratch replay) or schema mismatches.
    pub fn from_json(j: &Json) -> Option<ResumeSnapshot> {
        if !is_resume_snapshot(j) {
            return None;
        }
        Some(ResumeSnapshot {
            cursor: j.get("cursor")?.clone(),
            strategy: j.get("strategy")?.clone(),
            platform: j.get("platform")?.clone(),
            coord: j.get("coord")?.clone(),
        })
    }
}

/// Borrowing schema-tag probe: true when a checkpoint payload is a v1
/// [`ResumeSnapshot`]. Hot paths (the leader's per-slice delta
/// application, recovery's gating scan) use this instead of
/// [`ResumeSnapshot::from_json`], which deep-clones the O(job state)
/// payload.
pub fn is_resume_snapshot(j: &Json) -> bool {
    j.get("v").and_then(Json::as_i64) == Some(RESUME_SNAPSHOT_VERSION)
}

/// Extract the execution cursor from a checkpoint payload of either
/// schema: a v1 [`ResumeSnapshot`]'s `cursor` field, or a legacy v0
/// bare-cursor payload — borrowing, no payload clone. Recovery uses
/// this for progress reporting regardless of which resume path the job
/// takes.
pub fn checkpoint_cursor(payload: &Json) -> Option<ExecutionState> {
    if is_resume_snapshot(payload) {
        ExecutionState::from_json(payload.get("cursor")?)
    } else {
        ExecutionState::from_json(payload)
    }
}

impl LoopCtx {
    /// Freeze the run-loop state into the `coord` block of a
    /// [`ResumeSnapshot`].
    fn coord_state_json(&self) -> Json {
        let mut in_flight: Vec<(JobId, &InFlight)> =
            self.in_flight.iter().map(|(id, fl)| (*id, fl)).collect();
        in_flight.sort_by_key(|(id, _)| *id);
        let mut out = Json::obj(vec![
            ("launched", Json::Num(self.launched as f64)),
            ("history", crate::strategies::observations_to_json(&self.history)),
            ("curve_history", self.curve_history.to_json()),
            (
                "in_flight",
                Json::Arr(
                    in_flight
                        .into_iter()
                        .map(|(id, fl)| {
                            Json::obj(vec![
                                ("id", Json::Num(id as f64)),
                                ("eval", Json::Num(fl.eval_index as f64)),
                                (
                                    "curve_min",
                                    Json::Arr(
                                        fl.curve_min.iter().map(|&v| Json::Num(v)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evaluations",
                Json::Arr(self.evaluations.iter().map(EvaluationRecord::to_json).collect()),
            ),
            ("retries", Json::Num(self.retries as f64)),
            (
                "retry_budget",
                Json::Arr(self.retry_budget.iter().map(|&v| Json::Num(v as f64)).collect()),
            ),
        ]);
        // the in-flight speculation (if any) freezes alongside the
        // already-advanced strategy state, so a thawed actor commits or
        // discards exactly like the uninterrupted one; absent on old
        // snapshots ⇒ no speculation (DESIGN.md §17)
        if let Some(spec) = &self.speculation {
            if let Json::Obj(fields) = &mut out {
                fields.insert("speculation".to_string(), spec.to_json());
            }
        }
        out
    }
}

impl LoopCtx {
    /// Configs of in-flight evaluations, in launch (eval-index) order.
    /// The deterministic order matters twice: strategies see a stable
    /// pending set across runs, and [`crate::strategies::Speculation::matches`]
    /// compares this vector against the speculated one verbatim.
    fn pending_configs(&self) -> Vec<Config> {
        let mut flights: Vec<&InFlight> = self.in_flight.values().collect();
        flights.sort_by_key(|f| f.eval_index);
        flights
            .iter()
            .map(|f| self.evaluations[f.eval_index].config.clone())
            .collect()
    }

    /// Produce the next proposal: commit the in-flight speculation when
    /// the real world turned out exactly as fantasized (zero recompute),
    /// otherwise roll the strategy back and recompute synchronously —
    /// bit-identical to a run without the pipeline (DESIGN.md §17).
    fn take_proposal(&mut self, pending: &[Config]) -> Config {
        if let Some(spec) = self.speculation.take() {
            if spec.matches(&self.history, pending) {
                self.store.registry().counter("strategy.speculation_hits").inc();
                return spec.config;
            }
            // Discard: restore_state thaws the exact pre-speculation
            // strategy state (it was captured from this same instance,
            // so the kind always matches), then fall through to the
            // synchronous path.
            let ok = self.strategy.restore_state(&spec.saved);
            debug_assert!(ok, "own saved strategy state must restore");
            self.store.registry().counter("strategy.speculation_misses").inc();
        }
        self.strategy.next_config(&self.history, pending)
    }

    /// Idle-tail speculation (DESIGN.md §17): with every parallel slot
    /// occupied and budget remaining, fantasize that the **oldest**
    /// in-flight evaluation (smallest eval index — the pinned
    /// deterministic rule) completes at the constant-liar value, and
    /// pre-compute the proposal that would fill its slot. The strategy
    /// state advances here; `take_proposal` later keeps it (commit) or
    /// rolls it back via the saved state (discard).
    fn speculate_step(&mut self) {
        if !self.request.speculative
            || self.speculation.is_some()
            || self.stop_flag.load(Ordering::Relaxed)
            || self.in_flight.is_empty()
            || self.launched >= self.request.max_training_jobs
            || self.in_flight.len() < self.request.max_parallel_jobs as usize
        {
            return;
        }
        let mut flights: Vec<&InFlight> = self.in_flight.values().collect();
        flights.sort_by_key(|f| f.eval_index);
        let fantasy_config = self.evaluations[flights[0].eval_index].config.clone();
        let pending_after: Vec<Config> = flights[1..]
            .iter()
            .map(|f| self.evaluations[f.eval_index].config.clone())
            .collect();
        let started = std::time::Instant::now();
        let spec = crate::strategies::speculate(
            self.strategy.as_mut(),
            &self.history,
            &pending_after,
            fantasy_config,
        );
        self.store
            .registry()
            .histogram("strategy.speculate_us")
            .record(started.elapsed().as_micros() as u64);
        self.speculation = Some(spec);
    }

    fn launch_new(&mut self) {
        let pending = self.pending_configs();
        let config = self.take_proposal(&pending);
        if self.request.eval_cache {
            let key = eval_cache_key(&self.request.objective, &config);
            if let Some(entry) = self.store.eval_cache_get(&key) {
                if self.record_cached_eval(&config, &entry) {
                    return;
                }
            }
        } else {
            self.store.eval_cache_bypass();
        }
        let idx = self.evaluations.len();
        let name = format!("{}-train-{:04}", self.request.name, idx);
        self.evaluations.push(EvaluationRecord {
            training_job_name: name.clone(),
            config: config.clone(),
            curve: Vec::new(),
            final_value: None,
            status: TrainingJobStatus::Provisioning,
            stopped_early: false,
            attempts: 1,
            submitted_at: self.platform.now(),
            ended_at: self.platform.now(),
            cached: false,
        });
        self.retry_budget.push(self.request.max_retries_per_job);
        self.launched += 1;
        self.submit(idx);
        self.persist_training_job(idx);
    }

    /// Serve one evaluation from a cache entry: the platform is never
    /// touched — the recorded metric series is replayed instantly at the
    /// current virtual time and the observation feeds the strategy and
    /// the early-stopping bands exactly like a live outcome. Returns
    /// false (caller launches for real) on a malformed entry.
    fn record_cached_eval(&mut self, config: &Config, entry: &Json) -> bool {
        let Some(curve) = entry
            .get("curve")
            .and_then(Json::as_arr)
            .and_then(|a| a.iter().map(Json::as_f64).collect::<Option<Vec<f64>>>())
        else {
            return false;
        };
        let Some(status) = entry
            .get("status")
            .and_then(Json::as_str)
            .and_then(TrainingJobStatus::parse)
        else {
            return false;
        };
        let final_value = entry.get("final_value").and_then(Json::as_f64);
        let stopped_early = entry
            .get("stopped_early")
            .and_then(Json::as_bool)
            .unwrap_or(false);
        let idx = self.evaluations.len();
        let name = format!("{}-train-{:04}", self.request.name, idx);
        let now = self.platform.now();
        for &v in &curve {
            self.metrics.emit(&format!("{name}/objective"), now, v);
        }
        if let Some(v) = final_value {
            self.metrics.emit(&format!("{name}/final"), now, v);
            self.metrics
                .emit(&format!("{}/evaluations", self.request.name), now, v);
            self.history.push(Observation {
                config: config.clone(),
                value: self.sign * v,
            });
        }
        let curve_min: Vec<f64> = curve.iter().map(|&v| self.sign * v).collect();
        self.curve_history
            .push(curve_min, status == TrainingJobStatus::Completed);
        self.evaluations.push(EvaluationRecord {
            training_job_name: name,
            config: config.clone(),
            curve,
            final_value,
            status,
            stopped_early,
            attempts: 0,
            submitted_at: now,
            ended_at: now,
            cached: true,
        });
        self.retry_budget.push(0);
        self.launched += 1;
        self.persist_training_job(idx);
        true
    }

    /// Record a terminal evaluation's outcome in the cross-job cache.
    /// Only successful outcomes (Completed, or Stopped with a recorded
    /// value) are cacheable — failures must re-run. First writer wins,
    /// so the entry is immutable once created.
    fn cache_outcome(&self, idx: usize) {
        if !self.request.eval_cache {
            return;
        }
        let e = &self.evaluations[idx];
        if e.cached || e.final_value.is_none() {
            return;
        }
        if !matches!(
            e.status,
            TrainingJobStatus::Completed | TrainingJobStatus::Stopped
        ) {
            return;
        }
        let key = eval_cache_key(&self.request.objective, &e.config);
        self.store.eval_cache_put(
            &key,
            Json::obj(vec![
                ("owner", Json::Str(self.request.name.clone())),
                ("objective", Json::Str(self.request.objective.clone())),
                (
                    "curve",
                    Json::Arr(e.curve.iter().map(|&v| Json::Num(v)).collect()),
                ),
                (
                    "final_value",
                    e.final_value.map(Json::Num).unwrap_or(Json::Null),
                ),
                ("status", Json::Str(e.status.as_str().into())),
                ("stopped_early", Json::Bool(e.stopped_early)),
            ]),
        );
    }

    fn submit(&mut self, eval_index: usize) {
        let e = &self.evaluations[eval_index];
        let id = self.platform.submit(TrainingJobSpec {
            name: e.training_job_name.clone(),
            config: e.config.clone(),
            objective: Arc::clone(&self.objective),
            seed: self.request.seed ^ (eval_index as u64).wrapping_mul(0x2545F4914F6CDD1D)
                ^ (e.attempts as u64) << 48,
            instance_count: self.request.instance_count,
        });
        self.store.registry().counter("platform.trains").inc();
        self.in_flight.insert(
            id,
            InFlight { eval_index, platform_id: id, curve_min: Vec::new() },
        );
    }

    fn persist_training_job(&self, idx: usize) {
        let e = &self.evaluations[idx];
        self.store.put(
            "training_jobs",
            &e.training_job_name,
            Json::obj(vec![
                ("tuning_job", Json::Str(self.request.name.clone())),
                ("config", crate::space::config_to_json(&e.config)),
                ("status", Json::Str(format!("{:?}", e.status))),
                ("final_value", e.final_value.map(Json::Num).unwrap_or(Json::Null)),
                ("stopped_early", Json::Bool(e.stopped_early)),
                ("attempts", Json::Num(e.attempts as f64)),
            ]),
        );
    }

    /// Handle one platform event. Returns false when the platform is idle.
    fn pump_one(&mut self) -> bool {
        let Some(event) = self.platform.next_event() else {
            return false;
        };
        match event {
            PlatformEvent::JobStarted { .. } => {}
            PlatformEvent::EpochCompleted { job, epoch, value, time } => {
                if let Some(fl) = self.in_flight.get_mut(&job) {
                    let idx = fl.eval_index;
                    fl.curve_min.push(self.sign * value);
                    self.evaluations[idx].curve.push(value);
                    let name = self.evaluations[idx].training_job_name.clone();
                    self.metrics.emit(&format!("{name}/objective"), time, value);
                    // early-stopping decision (§5.2)
                    let stop = self.stopping.should_stop(
                        &fl.curve_min.clone(),
                        epoch,
                        &self.curve_history,
                    );
                    if stop {
                        let fl = self.in_flight.remove(&job).unwrap();
                        self.platform.stop_job(fl.platform_id);
                        let e = &mut self.evaluations[idx];
                        e.status = TrainingJobStatus::Stopped;
                        e.stopped_early = true;
                        e.ended_at = self.platform.now();
                        // a stopped curve still informs future medians and
                        // counts as an observation at its last fidelity
                        e.final_value = e.curve.last().copied();
                        self.curve_history.push(fl.curve_min.clone(), false);
                        if let Some(v) = e.final_value {
                            self.history.push(Observation {
                                config: e.config.clone(),
                                value: self.sign * v,
                            });
                        }
                        self.persist_training_job(idx);
                        self.cache_outcome(idx);
                    }
                }
            }
            PlatformEvent::JobCompleted { job, final_value, time } => {
                if let Some(fl) = self.in_flight.remove(&job) {
                    let idx = fl.eval_index;
                    let e = &mut self.evaluations[idx];
                    e.status = TrainingJobStatus::Completed;
                    e.final_value = Some(final_value);
                    e.ended_at = time;
                    self.curve_history.push(fl.curve_min.clone(), true);
                    self.history.push(Observation {
                        config: e.config.clone(),
                        value: self.sign * final_value,
                    });
                    let name = e.training_job_name.clone();
                    self.metrics.emit(&format!("{name}/final"), time, final_value);
                    self.metrics.emit(
                        &format!("{}/evaluations", self.request.name),
                        time,
                        final_value,
                    );
                    self.persist_training_job(idx);
                    self.cache_outcome(idx);
                }
            }
            PlatformEvent::JobFailed { job, reason, time } => {
                if let Some(fl) = self.in_flight.remove(&job) {
                    let idx = fl.eval_index;
                    if self.retry_budget[idx] > 0 {
                        // §3.3 retry mechanism: re-launch the same config
                        self.retry_budget[idx] -= 1;
                        self.retries += 1;
                        self.evaluations[idx].attempts += 1;
                        self.evaluations[idx].curve.clear();
                        self.submit(idx);
                    } else {
                        let e = &mut self.evaluations[idx];
                        e.status = TrainingJobStatus::Failed;
                        e.ended_at = time;
                        self.metrics.emit(
                            &format!("{}/failures", self.request.name),
                            time,
                            1.0,
                        );
                        let _ = reason;
                        self.persist_training_job(idx);
                    }
                }
            }
        }
        true
    }

    fn finished_count(&self) -> usize {
        self.evaluations
            .iter()
            .filter(|e| {
                matches!(
                    e.status,
                    TrainingJobStatus::Completed
                        | TrainingJobStatus::Stopped
                        | TrainingJobStatus::Failed
                )
            })
            .count()
    }
}

/// Build the tuning-job lifecycle machine (Validate → RunLoop → Finalize).
/// Each `RunLoop` invocation handles at most one platform event, so a
/// single [`StateMachine::step`] is a bounded unit of work.
fn build_machine() -> StateMachine<LoopCtx> {
    let mut machine: StateMachine<LoopCtx> = StateMachine::new("Validate")
        .state("Validate", RetryPolicy::none(), |ctx: &mut LoopCtx, _| {
            match ctx.request.validate_with_custom_objective() {
                Ok(()) => {
                    ctx.store.put(
                        "tuning_jobs",
                        &ctx.request.name,
                        Json::obj(vec![
                            ("status", Json::Str("InProgress".into())),
                            ("request", ctx.request.to_json()),
                        ]),
                    );
                    Transition::Next("RunLoop".into())
                }
                Err(e) => Transition::Fail(format!("validation: {e}")),
            }
        })
        .state("RunLoop", RetryPolicy::default(), |ctx, _| {
            // user-initiated Stop API (§3.2)
            if ctx.stop_flag.load(Ordering::Relaxed) {
                let ids: Vec<JobId> = ctx.in_flight.keys().copied().collect();
                for id in ids {
                    ctx.platform.stop_job(id);
                }
                while ctx.pump_one() {}
                return Transition::Next("Finalize".into());
            }
            // fill free parallel slots (asynchronous scheduling, §4.4)
            while ctx.launched < ctx.request.max_training_jobs
                && ctx.in_flight.len() < ctx.request.max_parallel_jobs as usize
            {
                ctx.launch_new();
            }
            // advance the platform by one event
            let progressed = ctx.pump_one();
            let budget_done = ctx.launched >= ctx.request.max_training_jobs
                && ctx.in_flight.is_empty();
            if budget_done || (!progressed && ctx.in_flight.is_empty()) {
                Transition::Next("Finalize".into())
            } else {
                Transition::Next("RunLoop".into())
            }
        })
        .state("Finalize", RetryPolicy::none(), |ctx, _| {
            let status = if ctx.stop_flag.load(Ordering::Relaxed) {
                "Stopped"
            } else {
                "Completed"
            };
            ctx.store.put(
                "tuning_jobs",
                &ctx.request.name,
                Json::obj(vec![
                    ("status", Json::Str(status.into())),
                    ("request", ctx.request.to_json()),
                    (
                        "evaluations",
                        Json::Num(ctx.finished_count() as f64),
                    ),
                ]),
            );
            Transition::Succeed
        });
    machine.max_transitions = 4_000_000;
    machine
}

/// Assemble the terminal outcome from a finished execution's context.
fn finish_outcome(name: String, ctx: LoopCtx, execution: Execution) -> TuningJobOutcome {
    // compute best in raw orientation
    let minimize = ctx.sign > 0.0;
    let mut best: Option<(Config, f64)> = None;
    for e in &ctx.evaluations {
        if let Some(v) = e.final_value {
            // only fully completed evaluations compete for "best" when
            // maximizing? No: the paper counts stopped jobs' last values
            // too — they are real model scores.
            let better = match &best {
                None => true,
                Some((_, b)) => {
                    if minimize {
                        v < *b
                    } else {
                        v > *b
                    }
                }
            };
            if better {
                best = Some((e.config.clone(), v));
            }
        }
    }
    let total_billable = ctx
        .evaluations
        .iter()
        .map(|e| {
            // billable = spec-reported per training job (platform info)
            e.ended_at - e.submitted_at
        })
        .sum();

    TuningJobOutcome {
        name,
        best,
        total_seconds: ctx.platform.now(),
        total_billable_seconds: total_billable,
        evaluations: ctx.evaluations,
        status: execution.status,
        retries: ctx.retries,
    }
}

/// Result of one [`JobActor::poll`] work slice.
#[derive(Debug)]
pub enum ActorPoll {
    /// Not terminal. `due` is the actor's current virtual time (seconds on
    /// its own platform timeline); the scheduler's event heap uses it to
    /// order re-polls so parked executions yield to less-advanced jobs.
    Pending {
        /// Virtual re-poll time for the scheduler's event heap.
        due: f64,
    },
    /// Terminal: the finished outcome (boxed — it owns every evaluation).
    Complete(Box<TuningJobOutcome>),
}

/// One tuning job as a non-blocking actor: a resumable workflow execution
/// over a dedicated platform timeline, advanced in bounded slices by
/// [`JobActor::poll`]. N actors multiplex over the M-worker
/// [`crate::scheduler::Scheduler`] pool instead of N dedicated threads.
pub struct JobActor {
    name: String,
    machine: StateMachine<LoopCtx>,
    exec: ExecutionState,
    ctx: Option<LoopCtx>,
    /// Fair-share weight from the request (scheduler heap key).
    tenant_weight: u32,
    /// Tenant identity for in-flight quota accounting ("" = none).
    tenant: String,
    /// Concurrent-poll-slice cap for the tenant (0 = unlimited).
    max_in_flight: u32,
    /// Optional durability log: when attached, the actor checkpoints its
    /// [`ExecutionState`] cursor at every `Pending` boundary.
    wal: Option<Arc<Wal>>,
}

impl JobActor {
    /// Assemble an actor. The strategy and stopping policy are passed in
    /// pre-built (the API layer constructs them from the request, including
    /// warm-start transfer).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        request: TuningJobRequest,
        objective: Arc<dyn Objective>,
        strategy: Box<dyn Strategy>,
        stopping: Box<dyn StoppingPolicy>,
        platform: TrainingPlatform,
        store: Arc<MetadataStore>,
        metrics: Arc<MetricsService>,
        stop_flag: Arc<AtomicBool>,
    ) -> Self {
        let sign = if objective.minimize() { 1.0 } else { -1.0 };
        let name = request.name.clone();
        let tenant_weight = request.tenant_weight.max(1);
        let tenant = request.tenant.clone();
        let max_in_flight = request.max_in_flight;
        let machine = build_machine();
        let exec = machine.begin(0.0);
        JobActor {
            name,
            machine,
            exec,
            tenant_weight,
            tenant,
            max_in_flight,
            wal: None,
            ctx: Some(LoopCtx {
                request,
                objective,
                strategy,
                stopping,
                platform,
                store,
                metrics,
                stop_flag,
                sign,
                launched: 0,
                history: Vec::new(),
                curve_history: CurveHistory::default(),
                in_flight: HashMap::new(),
                evaluations: Vec::new(),
                retries: 0,
                retry_budget: Vec::new(),
                speculation: None,
            }),
        }
    }

    /// Rebuild a mid-flight actor from a v1 [`ResumeSnapshot`] — the
    /// O(remaining work) resume path. `strategy` must be freshly
    /// constructed for the same request (its frozen state, including any
    /// warm-start transfer observations, is thawed here). On any schema
    /// or kind mismatch the caller falls back to scratch replay.
    #[allow(clippy::too_many_arguments)]
    pub fn from_resume_snapshot(
        request: TuningJobRequest,
        objective: Arc<dyn Objective>,
        mut strategy: Box<dyn Strategy>,
        stopping: Box<dyn StoppingPolicy>,
        snapshot: &Json,
        store: Arc<MetadataStore>,
        metrics: Arc<MetricsService>,
        stop_flag: Arc<AtomicBool>,
    ) -> Result<JobActor, String> {
        let snap = ResumeSnapshot::from_json(snapshot)
            .ok_or_else(|| "not a v1 resume snapshot".to_string())?;
        let exec = ExecutionState::from_json(&snap.cursor)
            .ok_or_else(|| "unparseable execution cursor".to_string())?;
        if !strategy.restore_state(&snap.strategy) {
            return Err("strategy state kind/schema mismatch".to_string());
        }
        let platform = TrainingPlatform::from_state_json(&snap.platform)
            .ok_or_else(|| "unparseable platform state".to_string())?;

        let c = &snap.coord;
        let coord_err = || "unparseable coordinator state".to_string();
        let launched =
            c.get("launched").and_then(Json::as_i64).ok_or_else(coord_err)? as u32;
        let history = c
            .get("history")
            .and_then(crate::strategies::observations_from_json)
            .ok_or_else(coord_err)?;
        let curve_history = c
            .get("curve_history")
            .and_then(CurveHistory::from_json)
            .ok_or_else(coord_err)?;
        let mut in_flight = HashMap::new();
        for fl in c.get("in_flight").and_then(Json::as_arr).ok_or_else(coord_err)? {
            let id = fl.get("id").and_then(Json::as_i64).ok_or_else(coord_err)? as JobId;
            let eval_index =
                fl.get("eval").and_then(Json::as_i64).ok_or_else(coord_err)? as usize;
            let curve_min: Vec<f64> = fl
                .get("curve_min")
                .and_then(Json::as_arr)
                .ok_or_else(coord_err)?
                .iter()
                .map(Json::as_f64)
                .collect::<Option<_>>()
                .ok_or_else(coord_err)?;
            in_flight.insert(id, InFlight { eval_index, platform_id: id, curve_min });
        }
        let mut evaluations = Vec::new();
        for e in c.get("evaluations").and_then(Json::as_arr).ok_or_else(coord_err)? {
            evaluations.push(EvaluationRecord::from_json(e).ok_or_else(coord_err)?);
        }
        let retries =
            c.get("retries").and_then(Json::as_i64).ok_or_else(coord_err)? as u32;
        let retry_budget: Vec<u32> = c
            .get("retry_budget")
            .and_then(Json::as_arr)
            .ok_or_else(coord_err)?
            .iter()
            .map(|v| v.as_i64().map(|n| n as u32))
            .collect::<Option<_>>()
            .ok_or_else(coord_err)?;
        if retry_budget.len() != evaluations.len() {
            return Err(coord_err());
        }
        // optional: snapshots taken before the pipeline existed (or with
        // no speculation in flight) simply thaw with none
        let speculation = c
            .get("speculation")
            .and_then(crate::strategies::Speculation::from_json);

        let sign = if objective.minimize() { 1.0 } else { -1.0 };
        let name = request.name.clone();
        let tenant_weight = request.tenant_weight.max(1);
        let tenant = request.tenant.clone();
        let max_in_flight = request.max_in_flight;
        Ok(JobActor {
            name,
            machine: build_machine(),
            exec,
            tenant_weight,
            tenant,
            max_in_flight,
            wal: None,
            ctx: Some(LoopCtx {
                request,
                objective,
                strategy,
                stopping,
                platform,
                store,
                metrics,
                stop_flag,
                sign,
                launched,
                history,
                curve_history,
                in_flight,
                evaluations,
                retries,
                retry_budget,
                speculation,
            }),
        })
    }

    /// Tuning-job name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The actor's current virtual due time (the scheduler's heap key):
    /// where a resumed job re-enters the event heap.
    pub fn due(&self) -> f64 {
        let platform_now = self.ctx.as_ref().map(|c| c.platform.now()).unwrap_or(0.0);
        platform_now.max(self.exec.clock)
    }

    /// Fair-share weight from the request (≥ 1).
    pub fn tenant_weight(&self) -> u32 {
        self.tenant_weight
    }

    /// Tenant identity from the request (empty = no shared quota).
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Tenant in-flight quota from the request (0 = unlimited).
    pub fn max_in_flight(&self) -> u32 {
        self.max_in_flight
    }

    /// Attach the durability WAL: every subsequent `Pending` boundary
    /// appends a `Checkpoint` record with the serialized execution
    /// cursor. The scheduler wires this automatically for durable
    /// services.
    pub fn set_wal(&mut self, wal: Arc<Wal>) {
        self.wal = Some(wal);
    }

    /// Advance the execution by at most `max_steps` state-machine steps
    /// (≈ platform events), yielding early when the workflow parks itself.
    ///
    /// Must not be called again after it returned
    /// [`ActorPoll::Complete`].
    pub fn poll(&mut self, max_steps: usize) -> ActorPoll {
        for _ in 0..max_steps.max(1) {
            let ctx = self.ctx.as_mut().expect("JobActor polled after completion");
            match self.machine.step(&mut self.exec, ctx) {
                StepOutcome::Ready => {}
                StepOutcome::Parked { .. } => break,
                StepOutcome::Done(execution) => {
                    let ctx = self.ctx.take().expect("context present at completion");
                    return ActorPoll::Complete(Box::new(finish_outcome(
                        self.name.clone(),
                        ctx,
                        execution,
                    )));
                }
            }
        }
        // checkpoint at the Parked/Pending boundary (§3.3 robustness):
        // a v1 ResumeSnapshot makes the checkpoint self-sufficient, so
        // durable recovery and the distributed worker-death requeue
        // rebuild the actor here and resume with O(remaining work) —
        // zero strategy proposals are ever re-executed (DESIGN.md §12)
        if let Some(wal) = &self.wal {
            wal.append(&WalRecord::Checkpoint {
                job: self.name.clone(),
                exec: self.resume_snapshot_json(),
            });
        }
        ActorPoll::Pending { due: self.due() }
    }

    /// Idle-tail hook for the scheduler worker loop and the distributed
    /// worker: run at most one speculation step (DESIGN.md §17). No-op
    /// for non-pipelined requests, terminal actors, or when a
    /// speculation is already queued. Deliberately *not* part of
    /// [`JobActor::poll`] — callers invoke it after the timed slice
    /// closed, so speculative compute never inflates
    /// `scheduler.poll_slice_us`, and after the `Pending` checkpoint, so
    /// a crash in between simply re-speculates deterministically on
    /// resume.
    pub fn speculate_step(&mut self) {
        if let Some(ctx) = self.ctx.as_mut() {
            ctx.speculate_step();
        }
    }

    /// Freeze the whole actor into a v1 [`ResumeSnapshot`] payload. Only
    /// valid while the actor is non-terminal (context present) — which
    /// holds at every `Pending` boundary where [`JobActor::poll`] emits
    /// checkpoints.
    fn resume_snapshot_json(&self) -> Json {
        let ctx = self.ctx.as_ref().expect("pending actor has context");
        Json::obj(vec![
            ("v", Json::Num(RESUME_SNAPSHOT_VERSION as f64)),
            ("cursor", self.exec.to_json()),
            ("strategy", ctx.strategy.state_to_json()),
            ("platform", ctx.platform.state_to_json()),
            ("coord", ctx.coord_state_json()),
        ])
    }
}

/// Drives one tuning job to completion on a dedicated platform timeline —
/// the single-tenant wrapper over [`JobActor`] used by tests, benches and
/// direct embedding. Produces outcomes bit-identical to the same actor
/// driven through the scheduler.
pub struct TuningJobRunner {
    actor: JobActor,
}

impl TuningJobRunner {
    /// Assemble a runner (see [`JobActor::new`] for the parameters).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        request: TuningJobRequest,
        objective: Arc<dyn Objective>,
        strategy: Box<dyn Strategy>,
        stopping: Box<dyn StoppingPolicy>,
        platform: TrainingPlatform,
        store: Arc<MetadataStore>,
        metrics: Arc<MetricsService>,
        stop_flag: Arc<AtomicBool>,
    ) -> Self {
        TuningJobRunner {
            actor: JobActor::new(
                request, objective, strategy, stopping, platform, store, metrics, stop_flag,
            ),
        }
    }

    /// Execute the tuning job to completion.
    pub fn run(mut self) -> TuningJobOutcome {
        loop {
            match self.actor.poll(usize::MAX) {
                ActorPoll::Pending { .. } => {}
                ActorPoll::Complete(outcome) => return *outcome,
            }
        }
    }
}

/// Rebuild a mid-flight [`JobActor`] entirely from a validated request
/// plus a v1 [`ResumeSnapshot`] payload — the **single** snapshot-resume
/// construction path, shared by durable recovery-on-open
/// ([`crate::api::AmtService::open`]) and remote workers receiving a
/// re-`Assign` after a worker death ([`crate::distributed::worker`]).
/// Like [`crate::strategies::for_request`], cross-path bit-identity
/// depends on both callers wiring the rebuild exactly the same way, so
/// changes belong here. The strategy is built fresh (with no transfer
/// observations — the snapshot's frozen strategy state carries them) and
/// thawed from the snapshot.
pub fn actor_from_snapshot(
    request: TuningJobRequest,
    snapshot: &Json,
    backend: Arc<dyn crate::gp::SurrogateBackend>,
    store: Arc<MetadataStore>,
    metrics: Arc<MetricsService>,
    stop_flag: Arc<AtomicBool>,
) -> Result<JobActor, String> {
    let objective = crate::objectives::by_name(&request.objective)
        .ok_or_else(|| format!("unknown objective '{}'", request.objective))?;
    let objective: Arc<dyn Objective> = objective.into();
    let strategy = crate::strategies::for_request(
        &request.strategy,
        &objective.space(),
        backend,
        request.seed,
        Vec::new(),
    )
    .ok_or_else(|| format!("unknown strategy '{}'", request.strategy))?;
    let stopping = stopping_by_name(&request.early_stopping)
        .ok_or_else(|| format!("unknown early stopping '{}'", request.early_stopping))?;
    JobActor::from_resume_snapshot(
        request, objective, strategy, stopping, snapshot, store, metrics, stop_flag,
    )
}

/// Build the stopping policy named in a request (§5.2 modes).
pub fn stopping_by_name(name: &str) -> Option<Box<dyn StoppingPolicy>> {
    use crate::earlystop::*;
    Some(match name {
        "off" => Box::new(NoStopping),
        "median" => Box::new(MedianRule::default()),
        "linear" => Box::new(LinearExtrapolation::default()),
        "asha" => Box::new(AshaRule::default()),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::NativeBackend;
    use crate::platform::PlatformConfig;
    use crate::strategies::RandomSearch;

    fn run_job(
        objective: &str,
        strategy: &str,
        early: &str,
        max_jobs: u32,
        parallel: u32,
        platform_config: PlatformConfig,
        seed: u64,
    ) -> TuningJobOutcome {
        let request = TuningJobRequest {
            name: format!("t-{objective}-{seed}"),
            objective: objective.into(),
            strategy: strategy.into(),
            early_stopping: early.into(),
            max_training_jobs: max_jobs,
            max_parallel_jobs: parallel,
            seed,
            ..Default::default()
        };
        let obj = crate::objectives::by_name(objective).unwrap();
        let obj: Arc<dyn Objective> = obj.into();
        let strat: Box<dyn Strategy> = crate::strategies::by_name(
            strategy,
            &obj.space(),
            Arc::new(NativeBackend),
            seed,
        )
        .unwrap();
        let stopping = stopping_by_name(early).unwrap();
        let runner = TuningJobRunner::new(
            request,
            obj,
            strat,
            stopping,
            TrainingPlatform::new(platform_config, seed),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        );
        runner.run()
    }

    #[test]
    fn random_tuning_job_completes_budget() {
        let out = run_job("branin", "random", "off", 8, 2, PlatformConfig::noiseless(), 1);
        assert_eq!(out.status, ExecutionStatus::Succeeded);
        assert_eq!(out.evaluations.len(), 8);
        assert!(out
            .evaluations
            .iter()
            .all(|e| e.status == TrainingJobStatus::Completed));
        assert!(out.best.is_some());
        assert!(out.total_seconds > 0.0);
    }

    #[test]
    fn parallelism_limit_respected_and_speeds_up() {
        let seq = run_job("branin", "random", "off", 6, 1, PlatformConfig::noiseless(), 2);
        let par = run_job("branin", "random", "off", 6, 3, PlatformConfig::noiseless(), 2);
        assert!(par.total_seconds < seq.total_seconds * 0.7,
            "parallel {} vs sequential {}", par.total_seconds, seq.total_seconds);
    }

    #[test]
    fn failures_are_retried_then_recorded() {
        let cfg = PlatformConfig {
            provisioning_failure_rate: 0.4,
            ..PlatformConfig::noiseless()
        };
        let out = run_job("branin", "random", "off", 10, 2, cfg, 3);
        assert_eq!(out.status, ExecutionStatus::Succeeded);
        assert_eq!(out.evaluations.len(), 10);
        // with retries most evaluations should still complete
        let completed = out
            .evaluations
            .iter()
            .filter(|e| e.status == TrainingJobStatus::Completed)
            .count();
        assert!(completed >= 7, "only {completed}/10 completed");
        assert!(out.retries > 0, "retry mechanism unused");
    }

    #[test]
    fn early_stopping_cuts_time_not_quality_much() {
        let base = run_job("gdelt_single", "random", "off", 12, 1, PlatformConfig::noiseless(), 4);
        let es = run_job("gdelt_single", "random", "median", 12, 1, PlatformConfig::noiseless(), 4);
        assert!(es.total_seconds < base.total_seconds, "early stopping saved no time");
        let stopped = es.evaluations.iter().filter(|e| e.stopped_early).count();
        assert!(stopped > 0, "median rule never fired");
        assert_eq!(es.evaluations.len(), 12, "budget must still be honored");
    }

    #[test]
    fn stop_flag_halts_job() {
        let request = TuningJobRequest {
            name: "stop-test".into(),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 1000,
            max_parallel_jobs: 1,
            ..Default::default()
        };
        let obj: Arc<dyn Objective> = crate::objectives::by_name("branin").unwrap().into();
        let strat = Box::new(RandomSearch::new(obj.space(), 1));
        let flag = Arc::new(AtomicBool::new(true)); // stop immediately
        let runner = TuningJobRunner::new(
            request,
            obj,
            strat,
            stopping_by_name("off").unwrap(),
            TrainingPlatform::new(PlatformConfig::noiseless(), 1),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            flag,
        );
        let out = runner.run();
        assert!(out.evaluations.len() < 1000);
    }

    #[test]
    fn store_records_jobs_and_metrics_emitted() {
        let store = Arc::new(MetadataStore::new());
        let metrics = Arc::new(MetricsService::new());
        let request = TuningJobRequest {
            name: "persist-test".into(),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 3,
            ..Default::default()
        };
        let obj: Arc<dyn Objective> = crate::objectives::by_name("branin").unwrap().into();
        let strat = Box::new(RandomSearch::new(obj.space(), 5));
        let runner = TuningJobRunner::new(
            request,
            obj,
            strat,
            stopping_by_name("off").unwrap(),
            TrainingPlatform::new(PlatformConfig::noiseless(), 5),
            Arc::clone(&store),
            Arc::clone(&metrics),
            Arc::new(AtomicBool::new(false)),
        );
        let out = runner.run();
        assert_eq!(out.evaluations.len(), 3);
        // tuning job record flipped to Completed
        let (_, job) = store.get("tuning_jobs", "persist-test").unwrap();
        assert_eq!(job.get("status").unwrap().as_str(), Some("Completed"));
        // per-training-job records exist
        assert_eq!(store.list_keys("training_jobs", "persist-test-train-").len(), 3);
        // per-epoch metrics were published
        assert!(!metrics.list_streams("persist-test-train-0000/").is_empty());
        assert_eq!(metrics.series("persist-test/evaluations").len(), 3);
    }

    #[test]
    fn bo_tuning_job_end_to_end() {
        let out = run_job("branin", "bayesian", "off", 10, 1, PlatformConfig::noiseless(), 6);
        assert_eq!(out.status, ExecutionStatus::Succeeded);
        assert_eq!(out.evaluations.len(), 10);
        let (_, best) = out.best.unwrap();
        assert!(best < 40.0, "BO on branin should find something decent: {best}");
    }

    fn bo_actor(seed: u64) -> (TuningJobRequest, JobActor) {
        let request = TuningJobRequest {
            name: format!("snap-{seed}"),
            objective: "branin".into(),
            strategy: "bayesian".into(),
            max_training_jobs: 5,
            max_parallel_jobs: 2,
            seed,
            ..Default::default()
        };
        let obj: Arc<dyn Objective> = crate::objectives::by_name("branin").unwrap().into();
        let strat = crate::strategies::for_request(
            "bayesian",
            &obj.space(),
            Arc::new(NativeBackend),
            seed,
            Vec::new(),
        )
        .unwrap();
        let actor = JobActor::new(
            request.clone(),
            obj,
            strat,
            stopping_by_name("off").unwrap(),
            TrainingPlatform::new(PlatformConfig::noiseless(), seed),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        );
        (request, actor)
    }

    fn drive_to_completion(mut actor: JobActor) -> TuningJobOutcome {
        loop {
            if let ActorPoll::Complete(outcome) = actor.poll(16) {
                return *outcome;
            }
        }
    }

    /// Tentpole invariant at the unit level: freeze a BO actor at a
    /// Pending boundary, thaw through `actor_from_snapshot` (the shared
    /// rebuild path), and the remaining run is bit-identical to the
    /// uninterrupted actor's.
    #[test]
    fn actor_resumed_from_snapshot_matches_uninterrupted_run() {
        let (_, reference_actor) = bo_actor(33);
        let reference = drive_to_completion(reference_actor);

        let (request, mut actor) = bo_actor(33);
        let mut slices = 0;
        let frozen = loop {
            match actor.poll(16) {
                ActorPoll::Pending { .. } => {
                    slices += 1;
                    if slices == 5 {
                        break actor.resume_snapshot_json();
                    }
                }
                ActorPoll::Complete(_) => panic!("job finished before the freeze point"),
            }
        };
        // through the JSON text round trip, like a real WAL record
        let parsed = crate::json::parse(&frozen.to_string()).unwrap();
        let resumed_actor = actor_from_snapshot(
            request,
            &parsed,
            Arc::new(NativeBackend),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        )
        .unwrap();
        assert!(resumed_actor.due() > 0.0, "resumed actor must re-enter at its clock");
        let resumed = drive_to_completion(resumed_actor);

        assert_eq!(reference.evaluations.len(), resumed.evaluations.len());
        for (a, b) in reference.evaluations.iter().zip(&resumed.evaluations) {
            assert_eq!(a.training_job_name, b.training_job_name);
            assert_eq!(a.config, b.config);
            assert_eq!(a.final_value.map(f64::to_bits), b.final_value.map(f64::to_bits));
            assert_eq!(a.ended_at.to_bits(), b.ended_at.to_bits());
            assert_eq!(a.status, b.status);
        }
        assert_eq!(reference.total_seconds.to_bits(), resumed.total_seconds.to_bits());
        assert_eq!(reference.retries, resumed.retries);
        assert_eq!(reference.status, resumed.status);
    }

    /// Legacy v0 payloads (bare cursors) parse through
    /// `checkpoint_cursor` but are rejected by the snapshot path.
    #[test]
    fn checkpoint_cursor_reads_both_schemas() {
        let (_, mut actor) = bo_actor(35);
        assert!(matches!(actor.poll(8), ActorPoll::Pending { .. }));
        let v1 = actor.resume_snapshot_json();
        assert!(ResumeSnapshot::from_json(&v1).is_some());
        let cursor = checkpoint_cursor(&v1).expect("v1 cursor parses");
        let v0 = cursor.to_json();
        assert!(ResumeSnapshot::from_json(&v0).is_none(), "v0 must not fast-path");
        assert!(checkpoint_cursor(&v0).is_some(), "v0 cursor still parses");
    }

    #[test]
    fn best_over_time_is_monotone() {
        let out = run_job("branin", "random", "off", 8, 2, PlatformConfig::noiseless(), 7);
        let series = out.best_over_time(true);
        assert!(!series.is_empty());
        for w in series.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
            assert!(w[1].0 >= w[0].0);
        }
    }

    fn pipelined_actor(
        strategy: &str,
        seed: u64,
        parallel: u32,
        speculative: bool,
        store: Arc<MetadataStore>,
    ) -> (TuningJobRequest, JobActor) {
        let request = TuningJobRequest {
            name: format!("pipe-{strategy}-{seed}-{speculative}"),
            objective: "branin".into(),
            strategy: strategy.into(),
            max_training_jobs: 8,
            max_parallel_jobs: parallel,
            seed,
            speculative,
            ..Default::default()
        };
        let obj: Arc<dyn Objective> = crate::objectives::by_name("branin").unwrap().into();
        let strat = crate::strategies::for_request(
            strategy,
            &obj.space(),
            Arc::new(NativeBackend),
            seed,
            Vec::new(),
        )
        .unwrap();
        let actor = JobActor::new(
            request.clone(),
            obj,
            strat,
            stopping_by_name("off").unwrap(),
            TrainingPlatform::new(PlatformConfig::noiseless(), seed),
            store,
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        );
        (request, actor)
    }

    /// Drive an actor the way the scheduler does with the pipeline on:
    /// speculate in the idle tail of every Pending slice.
    fn drive_pipelined(mut actor: JobActor) -> TuningJobOutcome {
        loop {
            match actor.poll(16) {
                ActorPoll::Pending { .. } => actor.speculate_step(),
                ActorPoll::Complete(outcome) => return *outcome,
            }
        }
    }

    fn assert_outcomes_bit_identical(a: &TuningJobOutcome, b: &TuningJobOutcome) {
        assert_eq!(a.evaluations.len(), b.evaluations.len());
        for (x, y) in a.evaluations.iter().zip(&b.evaluations) {
            assert_eq!(x.training_job_name, y.training_job_name);
            assert_eq!(x.config, y.config);
            assert_eq!(x.final_value.map(f64::to_bits), y.final_value.map(f64::to_bits));
            assert_eq!(x.ended_at.to_bits(), y.ended_at.to_bits());
            assert_eq!(x.status, y.status);
        }
        assert_eq!(a.total_seconds.to_bits(), b.total_seconds.to_bits());
        assert_eq!(a.status, b.status);
    }

    /// Value-free strategy + one slot: every speculation fantasizes the
    /// only in-flight evaluation, so every proposal after warm-up is a
    /// committed speculation — and the run is bit-identical to the
    /// synchronous reference.
    #[test]
    fn pipelined_random_commits_speculations_bit_identically() {
        let (_, sync_actor) =
            pipelined_actor("random", 41, 1, false, Arc::new(MetadataStore::new()));
        let reference = drive_pipelined(sync_actor); // speculate_step is a no-op here

        let store = Arc::new(MetadataStore::new());
        let (_, actor) = pipelined_actor("random", 41, 1, true, Arc::clone(&store));
        let pipelined = drive_pipelined(actor);

        assert_outcomes_bit_identical(&reference, &pipelined);
        let hits = store.registry().counter("strategy.speculation_hits").get();
        let misses = store.registry().counter("strategy.speculation_misses").get();
        assert!(hits > 0, "value-free pipeline never committed a speculation");
        assert_eq!(misses, 0, "value-free speculation must never discard");
    }

    /// BO flips to value-dependent proposals once the surrogate fits:
    /// those speculations are discarded (fantasy != real value) and the
    /// synchronous fallback keeps the run bit-identical.
    #[test]
    fn pipelined_bo_discards_value_dependent_speculations_bit_identically() {
        let (_, sync_actor) =
            pipelined_actor("bayesian", 43, 1, false, Arc::new(MetadataStore::new()));
        let reference = drive_pipelined(sync_actor);

        let store = Arc::new(MetadataStore::new());
        let (_, actor) = pipelined_actor("bayesian", 43, 1, true, Arc::clone(&store));
        let pipelined = drive_pipelined(actor);

        assert_outcomes_bit_identical(&reference, &pipelined);
        let hits = store.registry().counter("strategy.speculation_hits").get();
        let misses = store.registry().counter("strategy.speculation_misses").get();
        assert!(hits > 0, "initial-design speculations are value-free and must commit");
        assert!(misses > 0, "fit-based speculations must discard on real outcomes");
    }

    /// Bit-identity must also hold when the fantasized (oldest) flight is
    /// not necessarily the first to land: with two slots a younger eval
    /// can finish first, forcing the discard path mid-stream.
    #[test]
    fn pipelined_two_slot_run_matches_synchronous_reference() {
        let (_, sync_actor) =
            pipelined_actor("bayesian", 47, 2, false, Arc::new(MetadataStore::new()));
        let reference = drive_pipelined(sync_actor);
        let (_, actor) =
            pipelined_actor("bayesian", 47, 2, true, Arc::new(MetadataStore::new()));
        assert_outcomes_bit_identical(&reference, &drive_pipelined(actor));
    }

    /// A speculation in flight at the freeze point must thaw with the
    /// actor: freeze right after an idle-tail speculate_step, rebuild via
    /// `actor_from_snapshot`, and the rest of the pipelined run is
    /// bit-identical to the uninterrupted pipelined run.
    #[test]
    fn speculation_survives_resume_snapshot_bit_identically() {
        let (_, reference_actor) =
            pipelined_actor("bayesian", 51, 1, true, Arc::new(MetadataStore::new()));
        let reference = drive_pipelined(reference_actor);

        let (request, mut actor) =
            pipelined_actor("bayesian", 51, 1, true, Arc::new(MetadataStore::new()));
        let mut slices = 0;
        let frozen = loop {
            match actor.poll(16) {
                ActorPoll::Pending { .. } => {
                    actor.speculate_step();
                    slices += 1;
                    if slices == 4 {
                        break actor.resume_snapshot_json();
                    }
                }
                ActorPoll::Complete(_) => panic!("job finished before the freeze point"),
            }
        };
        let parsed = crate::json::parse(&frozen.to_string()).unwrap();
        let resumed_actor = actor_from_snapshot(
            request,
            &parsed,
            Arc::new(NativeBackend),
            Arc::new(MetadataStore::new()),
            Arc::new(MetricsService::new()),
            Arc::new(AtomicBool::new(false)),
        )
        .unwrap();
        assert_outcomes_bit_identical(&reference, &drive_pipelined(resumed_actor));
    }

    /// Cache hits replay the recorded outcome without touching the
    /// platform: a second identical job trains nothing new and its
    /// final values are bit-identical to the recorded ones.
    #[test]
    fn eval_cache_short_circuits_identical_job_bit_identically() {
        let store = Arc::new(MetadataStore::new());
        let mut request = TuningJobRequest {
            name: "cache-a".into(),
            objective: "branin".into(),
            strategy: "random".into(),
            max_training_jobs: 6,
            max_parallel_jobs: 2,
            seed: 61,
            eval_cache: true,
            ..Default::default()
        };
        let build = |request: TuningJobRequest, store: Arc<MetadataStore>| {
            let obj: Arc<dyn Objective> =
                crate::objectives::by_name("branin").unwrap().into();
            let strat = crate::strategies::for_request(
                "random",
                &obj.space(),
                Arc::new(NativeBackend),
                request.seed,
                Vec::new(),
            )
            .unwrap();
            JobActor::new(
                request,
                obj,
                strat,
                stopping_by_name("off").unwrap(),
                TrainingPlatform::new(PlatformConfig::noiseless(), 61),
                store,
                Arc::new(MetricsService::new()),
                Arc::new(AtomicBool::new(false)),
            )
        };
        let first = drive_to_completion(build(request.clone(), Arc::clone(&store)));
        assert_eq!(store.eval_cache_hits(), 0);
        let trains = store.registry().counter("platform.trains").get();
        assert_eq!(trains, 6);

        // same seed + same space ⇒ identical proposal stream ⇒ all hits
        request.name = "cache-b".into();
        let second = drive_to_completion(build(request, Arc::clone(&store)));
        assert_eq!(
            store.registry().counter("platform.trains").get(),
            trains,
            "second job must train nothing"
        );
        assert_eq!(store.eval_cache_hits(), 6);
        assert_eq!(second.evaluations.len(), first.evaluations.len());
        for (a, b) in first.evaluations.iter().zip(&second.evaluations) {
            assert_eq!(a.config, b.config);
            assert_eq!(a.final_value.map(f64::to_bits), b.final_value.map(f64::to_bits));
            assert!(b.cached);
            assert_eq!(b.attempts, 0);
        }
    }
}
